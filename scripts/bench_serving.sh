#!/usr/bin/env bash
# Regenerate BENCH_serving.json (serving throughput + prefix-cache
# benchmark). CPU-only; run from anywhere.
set -euo pipefail
cd "$(dirname "$0")/.."
JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}" \
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.serving "$@"
