#!/usr/bin/env python
"""Diff key BENCH_serving.json ratios against the committed baseline.

The serving bench writes absolute tokens/s (machine-dependent) but its
RATIOS — paged-vs-legacy speedup, prefix-cache prefill speedup, qmc-vs-
fp32 throughput, qmc-vs-fp32 modeled bytes/token — are the trajectory
the roadmap's open items are judged by. This script compares a freshly
produced bench JSON against the committed baseline snapshot
(``benchmarks/baselines/serving.json`` — the generated
``BENCH_serving.json`` itself is gitignored) and prints a WARN line
per ratio that moved more than ``--tolerance`` (relative).

Two ratio families are **gated**, not warn-only: the serving wins the
paper's thesis stands on (``weights.qmc_vs_fp32_tokens_per_s`` and
``prefix_cache.slots.*.prefill_speedup``) FAIL the check (exit 1) when
the current value drops below baseline by more than
``--gate-tolerance`` (relative, direction-aware: improvements never
fail). Everything else stays warn-only (exit 0) so noisy CI runners
never block a merge on incidental ratios; ``--strict`` additionally
exits 1 on any warning, for local gatekeeping.

  python scripts/check_bench_drift.py --current /tmp/bench_current.json
"""
from __future__ import annotations

import argparse
import json
import sys

# dotted paths into the bench JSON -> short display name. A path missing
# on either side (e.g. a BENCH_SECTIONS subset run) is skipped, not an
# error — the check covers whatever both files report.
KEY_RATIOS = {
    "slots.4.speedup": "paged_vs_legacy_speedup_s4",
    "slots.8.speedup": "paged_vs_legacy_speedup_s8",
    "prefix_cache.slots.4.prefill_speedup": "prefix_prefill_speedup_s4",
    "prefix_cache.slots.8.prefill_speedup": "prefix_prefill_speedup_s8",
    "weights.qmc_vs_fp32_tokens_per_s": "qmc_vs_fp32_tokens_per_s",
    "cost_attribution.qmc_vs_fp32_modeled_bytes_per_token":
        "qmc_vs_fp32_modeled_bytes_per_token",
    # warn-only: on the tiny CPU bench model the verify rung costs about
    # as much as the C=1 step, so this hovers near 1.0 and is tracked
    # for trajectory, not gated
    "speculative.tokens_per_s_vs_greedy":
        "speculative_tokens_per_s_vs_greedy",
    # warn-only: host and device work share the same cores on the CPU
    # bench host, so the dispatch/retire overlap win is muted and noisy
    # there — tracked for trajectory (an accelerator backend is where
    # the ratio earns a gate)
    "pipeline.tokens_per_s_vs_sync": "pipeline_tokens_per_s_vs_sync",
}

# higher-is-better ratios that fail the check when they regress below
# baseline beyond --gate-tolerance (improvements never fail)
GATED = {
    "prefix_cache.slots.4.prefill_speedup",
    "prefix_cache.slots.8.prefill_speedup",
    "weights.qmc_vs_fp32_tokens_per_s",
}


def lookup(doc: dict, path: str):
    cur = doc
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur if isinstance(cur, (int, float)) else None


def compare(current: dict, baseline: dict, tolerance: float,
            gate_tolerance: float):
    """Yields (name, base, cur, rel_change, warn, fail) per comparable
    ratio. ``fail`` is set only for GATED ratios that dropped below
    baseline by more than ``gate_tolerance``."""
    for path, name in KEY_RATIOS.items():
        base = lookup(baseline, path)
        cur = lookup(current, path)
        if base is None or cur is None:
            continue
        rel = (cur - base) / base if base else float("inf")
        fail = path in GATED and rel < -gate_tolerance
        yield name, base, cur, rel, abs(rel) > tolerance, fail


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--current", required=True,
                    help="freshly produced bench JSON")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/serving.json",
                    help="committed baseline JSON")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="relative change that triggers a WARN "
                         "(default 0.25 = 25%%)")
    ap.add_argument("--gate-tolerance", type=float, default=0.15,
                    help="relative DROP below baseline that FAILS a "
                         "gated ratio (default 0.15 = 15%%; sized to "
                         "the paired-median run-to-run noise of the "
                         "~50 ms bench walls)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 when any ratio warned")
    args = ap.parse_args()

    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    warned = failed = compared = 0
    for name, base, cur, rel, warn, fail in compare(
            current, baseline, args.tolerance, args.gate_tolerance):
        compared += 1
        tag = "FAIL" if fail else ("WARN" if warn else "ok  ")
        if fail:
            failed += 1
        elif warn:
            warned += 1
        print(f"{tag} {name}: baseline={base:.4f} current={cur:.4f} "
              f"({rel:+.1%})")
    if compared == 0:
        print("WARN no comparable ratios between the two files "
              "(section mismatch?)")
        warned += 1
    print(f"bench-drift: {failed} gated regressions, {warned}/"
          f"{max(compared, 1)} ratios moved more than "
          f"{args.tolerance:.0%}")
    if failed:
        return 1
    return 1 if args.strict and warned else 0


if __name__ == "__main__":
    sys.exit(main())
