#!/usr/bin/env python
"""Microbenchmark for the QMC qmm dispatch (``kernels/ops.qmm``).

Times x @ W for the serving-relevant M widths — decode (M=1..8), small
chunk (M=16) and training/prefill width (M=128) — through every
dispatch path ``kernels.ops.qmm_plan`` can pick:

  * ``ref``        — full ``qmm_ref`` dequant + dense matmul (oracle)
  * ``xla``        — ``qmm(x, qt)``: the plan's XLA route (skinny-M
                     stream einsum at M <= 2, ref above)
  * ``pallas``     — ``qmm(x, qt, use_pallas=True)``: decode-width
                     tiling for skinny M, column-strip at M % 128 == 0
  * ``dense``      — fp32 ``x @ w`` (what the serving weight plan
                     executes per call after its one-time dequant)

On CPU the Pallas paths run ``interpret=True`` — those columns validate
the tiling architecture, not kernel speed; compare them on a real TPU
backend. Prints the standard ``name,us_per_call,derived`` CSV rows and
writes ``BENCH_qmm.json`` (``BENCH_QMM_OUT`` overrides; ``BENCH_QMM_MS``
narrows the M sweep, e.g. ``BENCH_QMM_MS=1,8``).

  PYTHONPATH=src python scripts/bench_qmm.py
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qconfig import QMCConfig
from repro.core.qtensor import dequantize_qtensor, quantize_qtensor
from repro.kernels import ops as kops
from repro.kernels.ref import qmm_ref

K, N = 128, 256
MS = tuple(int(m) for m in os.environ.get(
    "BENCH_QMM_MS", "1,3,8,16,128").split(","))
OUT = os.environ.get(
    "BENCH_QMM_OUT",
    os.path.join(os.path.dirname(__file__), "..", "BENCH_qmm.json"))


def _time(fn, iters: int, warmup: int = 2) -> float:
    """Seconds per call, min over iters (lower envelope — see the
    serving bench's REPEATS note on noisy shared hosts)."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def run() -> dict:
    w = jax.random.normal(jax.random.PRNGKey(0), (K, N), jnp.float32)
    qt = quantize_qtensor(w, QMCConfig(rho=0.3, granularity="subtile"))
    w_exec = dequantize_qtensor(qt, jnp.float32)   # the weight plan's form
    rows = []
    for m in MS:
        x = jax.random.normal(jax.random.PRNGKey(m), (m, K), jnp.float32)
        plan_x = kops.qmm_plan(m, K, N, qt.subtile)
        plan_p = kops.qmm_plan(m, K, N, qt.subtile, use_pallas=True)
        # jit each route once so dispatch overhead, not tracing, is timed
        ref = jax.jit(lambda x: qmm_ref(x, qt))
        xla = jax.jit(lambda x: kops.qmm(x, qt))
        pal = jax.jit(lambda x: kops.qmm(x, qt, use_pallas=True))
        dense = jax.jit(lambda x: x @ w_exec)
        cells = {"ref": _time(lambda: ref(x), 20),
                 "xla": _time(lambda: xla(x), 20),
                 # interpret-mode Pallas is orders slower on CPU — a few
                 # iterations bound the runtime without losing the shape
                 # of the comparison
                 "pallas": _time(lambda: pal(x), 3),
                 "dense": _time(lambda: dense(x), 20)}
        row = {"m": m, "k": K, "n": N,
               "path_xla": plan_x["path"], "path_pallas": plan_p["path"],
               "us_per_call": {k: v * 1e6 for k, v in cells.items()},
               "xla_vs_ref": cells["ref"] / max(cells["xla"], 1e-12),
               "dense_vs_ref": cells["ref"] / max(cells["dense"], 1e-12)}
        rows.append(row)
        print(f"qmm/m{m}_{plan_x['path']},"
              f"{row['us_per_call']['xla']:.1f},"
              f"xla_vs_ref={row['xla_vs_ref']:.2f}x "
              f"dense={row['us_per_call']['dense']:.1f}us "
              f"pallas[{plan_p['path']}]="
              f"{row['us_per_call']['pallas']:.0f}us(interp)")
    out = {"config": {"k": K, "n": N, "backend": jax.default_backend(),
                      "pallas_interpret": jax.default_backend() != "tpu"},
           "rows": rows}
    with open(OUT, "w") as f:
        json.dump(out, f, indent=2)
    print(f"qmm/json,0,{os.path.abspath(OUT)}")
    return out


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
