"""Int8 gradient compression with error feedback, for the slow pod edge.

The paper's insight — aggressive low-bit quantization with outlier
protection — applied to *distributed training traffic*: cross-pod gradient
all-reduce is the bandwidth-starved link (ICI within a pod, DCI between
pods), so gradients are quantized to int8 per-tensor-chunk before the
cross-pod psum and dequantized after, with an error-feedback accumulator
preserving convergence (residual of the quantization is added to the next
step's gradient).

Used by the trainer when mesh has a "pod" axis and cfg enables compression.
The compress/decompress pair is pure jnp so GSPMD places the quantized
(4x smaller) tensor on the wire.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

CHUNK = 2048


def _quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    flat = g.reshape(-1)
    pad = (-flat.size) % CHUNK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(-1, CHUNK)
    scale = jnp.max(jnp.abs(chunks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(chunks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize_int8(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress(grads: Any, err: Any) -> Tuple[Any, Any]:
    """grads + error-feedback -> (quantized pytree {q, scale}, new_err)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = _quantize_int8(g32)
        deq = _dequantize_int8(q, s, g.shape)
        return {"q": q, "scale": s}, (g32 - deq)
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(err)
    qs, es = [], []
    for g, e in zip(flat_g, flat_e):
        qq, ee = one(g, e)
        qs.append(qq)
        es.append(ee)
    return (jax.tree_util.tree_unflatten(tdef, qs),
            jax.tree_util.tree_unflatten(tdef, es))


def decompress(qtree: Any, shapes: Any) -> Any:
    def one(q, ref):
        return _dequantize_int8(q["q"], q["scale"], ref.shape).astype(
            ref.dtype)
    flat_q, tdef = jax.tree_util.tree_flatten(
        qtree, is_leaf=lambda x: isinstance(x, dict) and "q" in x)
    flat_s = jax.tree_util.tree_leaves(shapes)
    return jax.tree_util.tree_unflatten(
        tdef, [one(q, r) for q, r in zip(flat_q, flat_s)])


def init_error(params) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
