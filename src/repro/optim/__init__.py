"""optim subsystem."""
