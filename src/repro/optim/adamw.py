"""AdamW in pure JAX with configurable moment dtype.

Moments default to fp32; the 314B/398B train cells use bf16 moments so the
optimizer state fits 256 x 16 GB with FSDP (state inherits each param's
sharding spec — ZeRO-3). No master copy: params are the canonical values
(bf16 or fp32 per model dtype); the update math runs in fp32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"      # "bfloat16" for the giant models


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init(params, cfg: AdamWConfig) -> OptState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree_util.tree_map(zeros, params),
                    v=jax.tree_util.tree_map(zeros, params))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def update(grads, state: OptState, params, cfg: AdamWConfig,
           lr_scale: jax.Array = 1.0) -> Tuple[Any, OptState, dict]:
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else 1.0
    dt = jnp.dtype(cfg.moment_dtype)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * clip
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mh, vh = m32 / c1, v32 / c2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/bias exempt)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(dt), v32.astype(dt))

    out = jax.tree_util.tree_map(upd, grads, state.m, state.v, params)
    new_p = jax.tree_util.tree_map(lambda t: t[0], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm}


def lr_schedule(step: jax.Array, *, warmup: int = 100,
                total: int = 10_000, min_frac: float = 0.1) -> jax.Array:
    """Linear warmup + cosine decay multiplier."""
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / max(warmup, 1), 1.0)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return warm * cos
