"""train subsystem."""
