"""Builds the pjit'd train step: FSDP+TP sharded, microbatched, remat'd,

with optional int8 cross-pod gradient compression (error feedback).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch import mesh as meshlib
from repro.launch import sharding as shd
from repro.models.config import ModelConfig
from repro.models.model import train_loss
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig, OptState


def _grads_fn(model_cfg: ModelConfig, microbatches: int,
              scan_layers: bool = True):
    def loss_fn(p, mb):
        return train_loss(model_cfg, p, mb, remat=True,
                          scan_layers=scan_layers)

    def compute(params, batch):
        if microbatches <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads

        def split(x):
            return x.reshape((microbatches, x.shape[0] // microbatches)
                             + x.shape[1:])
        mbs = jax.tree_util.tree_map(split, batch)
        g0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def acc(carry, mb):
            gs, ls, aux = carry
            (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params,
                                                                  mb)
            gs = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(jnp.float32), gs, g)
            return (gs, ls + l, aux + m["aux"]), None

        (grads, loss, aux), _ = jax.lax.scan(
            acc, (g0, jnp.zeros(()), jnp.zeros(())), mbs)
        inv = 1.0 / microbatches
        grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
        return loss * inv, {"loss": loss * inv, "aux": aux * inv}, grads
    return compute


def build_train_step(model_cfg: ModelConfig, opt_cfg: AdamWConfig, mesh, *,
                     microbatches: int = 1,
                     warmup: int = 100, total_steps: int = 10_000,
                     donate: bool = True, scan_layers: bool = True):
    """Returns (jit_step, shardings dict). jit_step(params, opt, batch) ->

    (params, opt, metrics)."""
    compute = _grads_fn(model_cfg, microbatches, scan_layers)

    def step(params, opt_state, batch):
        from repro import runtime_context as rctx
        from repro.launch import mesh as _m
        with rctx.use_mesh(mesh, _m.dp_axes(mesh)):
            loss, metrics, grads = compute(params, batch)
        lr_scale = adamw.lr_schedule(opt_state.step, warmup=warmup,
                                     total=total_steps)
        params, opt_state, om = adamw.update(grads, opt_state, params,
                                             opt_cfg, lr_scale)
        out = {"loss": metrics["loss"], "aux": metrics["aux"],
               "grad_norm": om["grad_norm"],
               "lr": lr_scale * opt_cfg.lr}
        return params, opt_state, out

    def shardings_for(params, opt_state, batch):
        p_sh = shd.shard_params_tree(params, mesh)
        o_sh = OptState(step=NamedSharding(mesh, P()),
                        m=shd.shard_params_tree(opt_state.m, mesh),
                        v=shd.shard_params_tree(opt_state.v, mesh))
        gb = jax.tree_util.tree_leaves(batch)[0].shape[0]
        b_sh = jax.tree_util.tree_map(
            lambda x: NamedSharding(mesh, shd.batch_spec(mesh, gb)), batch)
        return p_sh, o_sh, b_sh

    def jit_step(params_struct, opt_struct, batch_struct):
        p_sh, o_sh, b_sh = shardings_for(params_struct, opt_struct,
                                         batch_struct)
        scalar = NamedSharding(mesh, P())
        out_metrics = {"loss": scalar, "aux": scalar, "grad_norm": scalar,
                       "lr": scalar}
        return jax.jit(step,
                       in_shardings=(p_sh, o_sh, b_sh),
                       out_shardings=(p_sh, o_sh, out_metrics),
                       donate_argnums=(0, 1) if donate else ())
    return step, jit_step, shardings_for
