"""Training loop: data prefetch, checkpoint/restart, straggler watchdog.

Fault-tolerance contract:
  * checkpoints are atomic + async (checkpoint/ckpt.py) every
    `ckpt_every` steps;
  * on startup, `resume=True` restores the latest checkpoint (elastic:
    the current mesh's shardings are applied on load);
  * a StepWatchdog arms a per-step deadline; policy "raise" aborts so the
    outer launcher restarts from the checkpoint — the standard
    preemption/node-failure path on TPU fleets;
  * data is keyed by (seed, host, step): restart replays from the exact
    batch after the checkpoint step.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import ckpt as ckpt_lib
from repro.data.loader import PrefetchLoader
from repro.data.synthetic import CorpusConfig, SyntheticCorpus
from repro.ft.watchdog import StepWatchdog
from repro.models.config import ModelConfig
from repro.models.model import init_params
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.train.step import build_train_step


@dataclasses.dataclass
class TrainConfig:
    steps: int = 200
    global_batch: int = 8
    seq_len: int = 128
    microbatches: int = 1
    ckpt_every: int = 100
    ckpt_dir: Optional[str] = None
    resume: bool = False
    log_every: int = 10
    step_deadline_s: float = 300.0
    watchdog_policy: str = "log"
    warmup: int = 20
    seed: int = 0


def train(model_cfg: ModelConfig, train_cfg: TrainConfig,
          opt_cfg: AdamWConfig = AdamWConfig(), mesh=None,
          log_fn: Callable[[str], None] = print,
          extra_batch_fn: Optional[Callable] = None) -> Dict:
    """Runs the loop; returns {'params','opt','history',...}."""
    corpus = SyntheticCorpus(CorpusConfig(vocab=model_cfg.vocab,
                                          seed=train_cfg.seed + 1))
    key = jax.random.PRNGKey(train_cfg.seed)
    params = init_params(model_cfg, key)
    opt_state = adamw.init(params, opt_cfg)

    start_step = 0
    if train_cfg.resume and train_cfg.ckpt_dir and \
            ckpt_lib.latest_step(train_cfg.ckpt_dir) is not None:
        state_like = {"params": params, "opt": opt_state}
        restored, at = ckpt_lib.restore(state_like, train_cfg.ckpt_dir)
        params, opt_state = restored["params"], restored["opt"]
        start_step = at
        log_fn(f"[trainer] resumed from step {at}")

    step_fn, jit_builder, _ = build_train_step(
        model_cfg, opt_cfg, mesh, microbatches=train_cfg.microbatches,
        warmup=train_cfg.warmup, total_steps=train_cfg.steps) \
        if mesh is not None else (None, None, None)

    if mesh is None:
        compiled = jax.jit(_single_device_step(model_cfg, opt_cfg,
                                               train_cfg),
                           donate_argnums=(0, 1))
    else:
        compiled = None  # built lazily on first batch

    def sample(step):
        b = corpus.sample_batch(train_cfg.global_batch, train_cfg.seq_len,
                                step=step)
        if extra_batch_fn:
            b.update(extra_batch_fn(train_cfg.global_batch,
                                    train_cfg.seq_len, model_cfg))
        return b

    loader = PrefetchLoader(sample, start_step=start_step)
    saver = ckpt_lib.AsyncCheckpointer()
    watchdog = StepWatchdog(train_cfg.step_deadline_s,
                            train_cfg.watchdog_policy)
    history = []
    try:
        for _ in range(start_step, train_cfg.steps):
            step_idx, batch = next(loader)
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            watchdog.arm(step_idx)
            t0 = time.monotonic()
            if compiled is None:
                compiled = jit_builder(
                    jax.eval_shape(lambda: params),
                    jax.eval_shape(lambda: opt_state),
                    jax.eval_shape(lambda: batch))
            params, opt_state, metrics = compiled(params, opt_state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.monotonic() - t0
            watchdog.disarm()
            watchdog.check()
            history.append({"step": step_idx, "time_s": dt, **metrics})
            if step_idx % train_cfg.log_every == 0:
                log_fn(f"[trainer] step {step_idx} "
                       f"loss={metrics['loss']:.4f} "
                       f"gnorm={metrics['grad_norm']:.2e} {dt*1e3:.0f}ms")
            if train_cfg.ckpt_dir and (step_idx + 1) % \
                    train_cfg.ckpt_every == 0:
                saver.save({"params": params, "opt": opt_state},
                           train_cfg.ckpt_dir, step_idx + 1)
    finally:
        loader.close()
        watchdog.close()
        saver.wait()

    if train_cfg.ckpt_dir:
        ckpt_lib.save({"params": params, "opt": opt_state},
                      train_cfg.ckpt_dir, train_cfg.steps)
    return {"params": params, "opt": opt_state, "history": history,
            "corpus": corpus, "incidents": watchdog.incidents}


def _single_device_step(model_cfg, opt_cfg, train_cfg):
    from repro.models.model import train_loss

    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: train_loss(model_cfg, p, batch, remat=False),
            has_aux=True)(params)
        lr_scale = adamw.lr_schedule(opt_state.step,
                                     warmup=train_cfg.warmup,
                                     total=train_cfg.steps)
        params, opt_state, om = adamw.update(grads, opt_state, params,
                                             opt_cfg, lr_scale)
        return params, opt_state, {"loss": metrics["loss"],
                                   "aux": metrics["aux"],
                                   "grad_norm": om["grad_norm"],
                                   "lr": lr_scale * opt_cfg.lr}
    return step
