"""Pallas TPU kernel: paged decode attention over the serving KV arena.

The serving hot path's XLA reference gather (``models.attention.
paged_cache_read``) materializes the FULL block-table width for every
decode lane — compute and on-chip residency scale with ``max_pages`` even
when a lane holds one live page. This kernel consumes the paged arena +
block tables directly and streams only live pages, which is exactly the
page-granular LPDDR5 traffic ``memsys.workload.kv_traffic_paged``
(``live_only=True``) charges the Eq. (3)/(4) DSE.

Grid / BlockSpec contract
-------------------------
  * Grid ``(B, KV, P)`` — batch lane x KV head x block-table slot, with
    the page axis innermost so the online-softmax scratch accumulates
    across one lane-head's pages before moving on.
  * The arena is viewed as ``[n_pages, page, KV, hd]`` (plus
    ``[n_pages, page, KV]`` scales for the int8 layout). Per grid step the
    BlockSpec index map does a data-dependent fetch of ONE page of ONE KV
    head: block ``(1, page, 1, hd)`` at row ``tbl[b, p]`` — the
    ``PrefetchScalarGridSpec`` scalar-prefetch mechanism, same as
    ``kernels/qmm.py``'s stream routing.
  * Scalar prefetch operands: ``tbl [B, P]`` (block tables), ``seq [B]``
    (valid KV length per lane, i.e. decode position + 1) and
    ``meta = [page_offset, n_local_pages]`` (shard-local page-id window;
    ``[0, n_pages]`` on a single device).
  * Dead or out-of-shard table slots are remapped to arena row 0 by the
    index map (never a live page — row 0 is the reserved null page) and
    fully masked in the body, so they contribute nothing and cost no
    live-page stream: per-step gather work is ``sum_b ceil(seq_b/page)``
    pages, not ``B * P``.
  * Online softmax (flash-style running max / sum) keeps exactly one page
    of K/V resident per step; GQA query groups ride along as the ``G``
    rows of each block. int8-KV dequant (per-page-slot, per-head scales
    from ``models.kvcache.quantize_kv``'s layout) is fused before the dot.
  * Outputs: normalized ``o [B, KV, G, hd]`` plus the running ``(m, l)``
    softmax state — the state is what makes the kernel mesh-composable:
    under the PR-3 sharding contract the arena's page axis shards over
    ``data``, so each shard runs the kernel over its own page slice and
    the partial ``(o, m, l)`` triples merge with a flash-decoding-style
    ``pmax``/``psum`` reduction (``shard_map`` over the full
    ``(data, model)`` mesh; KV heads stay ``model``-local like
    ``qmm_shard_map``).

``interpret=True`` (the default off-TPU) executes the real kernel body on
CPU, so CI runs the same code path the TPU backend compiles. Block shapes
follow the problem geometry rather than the (8/16/32, 128) MXU tiles —
fine in interpret mode; a production TPU build would pad ``G``/``hd`` up
to the dtype's native tile.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.launch.mesh import axis_size as _mesh_axis


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


# ---------------------------------------------------------------------------
# kernel body
# ---------------------------------------------------------------------------
def _accumulate(tbl_ref, seq_ref, meta_ref, q_ref, k_ref, v_ref,
                ks_ref, vs_ref, o_ref, mo_ref, lo_ref,
                acc_ref, m_ref, l_ref, *, page: int,
                window: Optional[int], attn_softcap: Optional[float],
                scale: float):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)

    seq = seq_ref[b]
    local = tbl_ref[b, p] - meta_ref[0]
    owned = (local >= 0) & (local < meta_ref[1])
    live = (p * page) < seq

    qs = q_ref[0, 0].astype(jnp.float32) * scale           # [G, hd]
    k = k_ref[0, :, 0, :].astype(jnp.float32)              # [page, hd]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    if ks_ref is not None:                                 # fused dequant
        k = k * ks_ref[0, :, 0].astype(jnp.float32)[:, None]
        v = v * vs_ref[0, :, 0].astype(jnp.float32)[:, None]

    scores = jax.lax.dot_general(qs, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    if attn_softcap:
        scores = attn_softcap * jnp.tanh(scores / attn_softcap)

    pos = p * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    mask = (pos < seq) & owned & live                      # [1, page]
    if window is not None:
        mask = mask & ((seq - 1) - pos < window)
    scores = jnp.where(mask, scores, -1e30)

    cm = jnp.max(scores, axis=-1, keepdims=True)           # [G, 1]
    m_new = jnp.maximum(m_ref[...], cm)
    # probs masked explicitly: with every score at -1e30 AND m still at
    # its -1e30 init (a fully dead lane) exp(score - m_new) would be 1
    probs = jnp.where(mask, jnp.exp(scores - m_new), 0.0)  # [G, page]
    corr = jnp.exp(m_ref[...] - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(probs, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        probs, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(p == pl.num_programs(2) - 1)
    def _done():
        # a lane with no live position keeps l == 0 -> output exactly 0
        o_ref[0, 0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        mo_ref[0, 0] = m_ref[:, 0]
        lo_ref[0, 0] = l_ref[:, 0]


def _make_kernel(page, window, attn_softcap, scale, quantized):
    body = functools.partial(_accumulate, page=page, window=window,
                             attn_softcap=attn_softcap, scale=scale)
    if quantized:
        def kernel(tbl, seq, meta, q, k, v, ks, vs, o, mo, lo, acc, m, l):
            body(tbl, seq, meta, q, k, v, ks, vs, o, mo, lo, acc, m, l)
    else:
        def kernel(tbl, seq, meta, q, k, v, o, mo, lo, acc, m, l):
            body(tbl, seq, meta, q, k, v, None, None, o, mo, lo, acc, m, l)
    return kernel


# ---------------------------------------------------------------------------
# shard-local call
# ---------------------------------------------------------------------------
def _paged_attn_call(q4, kp, vp, ksp, vsp, tbl, seq, meta, *,
                     window, attn_softcap, interpret):
    """One shard's kernel call.

    q4 [B, KV, G, hd]; kp/vp [n_pages, page, KV, hd]; ksp/vsp
    [n_pages, page, KV] or None; tbl [B, P]; seq [B];
    meta = [page_offset, n_local_pages]. Returns (o, m, l) — normalized
    output plus the online-softmax state for cross-shard merging.
    """
    bsz, n_kv, g, hd = q4.shape
    page = kp.shape[1]
    n_tbl = tbl.shape[1]
    quantized = ksp is not None
    scale = float(hd) ** -0.5

    def _page_sel(b, h, p, tbl_ref, seq_ref, meta_ref):
        local = tbl_ref[b, p] - meta_ref[0]
        ok = ((local >= 0) & (local < meta_ref[1])
              & (p * page < seq_ref[b]))
        return jnp.where(ok, local, 0)

    def q_map(b, h, p, *refs):
        return (b, h, 0, 0)

    def kv_map(b, h, p, *refs):
        return (_page_sel(b, h, p, *refs), 0, h, 0)

    def sc_map(b, h, p, *refs):
        return (_page_sel(b, h, p, *refs), 0, h)

    def o_map(b, h, p, *refs):
        return (b, h, 0, 0)

    def ml_map(b, h, p, *refs):
        return (b, h, 0)

    in_specs = [pl.BlockSpec((1, 1, g, hd), q_map),
                pl.BlockSpec((1, page, 1, hd), kv_map),
                pl.BlockSpec((1, page, 1, hd), kv_map)]
    operands = [q4, kp, vp]
    if quantized:
        in_specs += [pl.BlockSpec((1, page, 1), sc_map),
                     pl.BlockSpec((1, page, 1), sc_map)]
        operands += [ksp, vsp]

    call = pl.pallas_call(
        _make_kernel(page, window, attn_softcap, scale, quantized),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(bsz, n_kv, n_tbl),
            in_specs=in_specs,
            out_specs=[pl.BlockSpec((1, 1, g, hd), o_map),
                       pl.BlockSpec((1, 1, g), ml_map),
                       pl.BlockSpec((1, 1, g), ml_map)],
            scratch_shapes=[pltpu.VMEM((g, hd), jnp.float32),
                            pltpu.VMEM((g, 1), jnp.float32),
                            pltpu.VMEM((g, 1), jnp.float32)]),
        out_shape=[jax.ShapeDtypeStruct((bsz, n_kv, g, hd), jnp.float32),
                   jax.ShapeDtypeStruct((bsz, n_kv, g), jnp.float32),
                   jax.ShapeDtypeStruct((bsz, n_kv, g), jnp.float32)],
        interpret=interpret,
    )
    return call(tbl, seq, meta, *operands)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------
def shard_compatible(mesh, n_pages_total: int, n_kv: int) -> bool:
    """Whether the shard-local kernel honors the PR-3 arena sharding:

    the page axis must divide ``data`` (each shard owns an equal page
    slice) and the KV head count must divide ``model`` (heads stay
    TP-local; a fused-kv_dim split through the middle of a head — legal
    for the XLA gather — cannot run head-local)."""
    if mesh is None:
        return True
    d = _mesh_axis(mesh, "data")
    m = _mesh_axis(mesh, "model")
    return n_pages_total % max(d, 1) == 0 and n_kv % max(m, 1) == 0


def paged_decode_attention(q: jax.Array, cache: dict, seq_len: jax.Array,
                           *, n_kv: int, head_dim: int,
                           window: Optional[int] = None,
                           attn_softcap: Optional[float] = None,
                           mesh=None,
                           interpret: Optional[bool] = None) -> jax.Array:
    """Decode attention straight off the paged arena.

    q ``[B, 1, H, hd]``; ``cache`` holds ``k_pages/v_pages
    [n_pages, page, KV*hd]`` (int8 layouts add ``{k,v}_scale_pages
    [n_pages, page, KV]``) and ``block_tbl [B, max_pages]``;
    ``seq_len [B]`` is each lane's valid KV length (decode position + 1;
    0 marks an inactive lane, whose output is exactly 0). Returns
    ``[B, 1, H, hd]`` in q's dtype.

    With a mesh the kernel runs shard-local under ``shard_map`` over the
    full ``(data, model)`` mesh: each data shard streams only its slice
    of the page pool and the partial softmax states merge with a
    flash-decoding ``pmax``/``psum``; KV heads split over ``model``.
    Callers must check :func:`shard_compatible` first.
    """
    b, s, h, hd = q.shape
    if s != 1:
        raise ValueError(f"decode kernel takes one query token, got S={s}")
    if hd != head_dim or h % n_kv:
        raise ValueError((q.shape, n_kv, head_dim))
    g = h // n_kv
    if interpret is None:
        interpret = not _on_tpu()

    kp = cache["k_pages"]
    vp = cache["v_pages"]
    n_pages, page, _ = kp.shape
    kp = kp.reshape(n_pages, page, n_kv, hd)
    vp = vp.reshape(n_pages, page, n_kv, hd)
    ksp = vsp = None
    if "k_scale_pages" in cache:
        ksp = cache["k_scale_pages"]
        vsp = cache["v_scale_pages"]
    q4 = q.reshape(b, n_kv, g, hd)
    tbl = cache["block_tbl"].astype(jnp.int32)
    seq = seq_len.astype(jnp.int32)
    kw = dict(window=window, attn_softcap=attn_softcap, interpret=interpret)

    d_n = _mesh_axis(mesh, "data") if mesh is not None else 1
    m_n = _mesh_axis(mesh, "model") if mesh is not None else 1
    if mesh is None or d_n * m_n == 1:
        meta = jnp.array([0, n_pages], jnp.int32)
        o, _, _ = _paged_attn_call(q4, kp, vp, ksp, vsp, tbl, seq, meta,
                                   **kw)
        return o.astype(q.dtype).reshape(b, 1, h, hd)

    if not shard_compatible(mesh, n_pages, n_kv):
        raise ValueError("arena/head geometry does not divide the mesh; "
                         "gate on shard_compatible() before dispatching")
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    n_local = n_pages // d_n

    def body(q4, kp, vp, ksp, vsp, tbl, seq):
        off = jax.lax.axis_index("data").astype(jnp.int32) * n_local
        meta = jnp.stack([off, jnp.int32(n_local)])
        o, m, l = _paged_attn_call(q4, kp, vp, ksp, vsp, tbl, seq, meta,
                                   **kw)
        # flash-decoding merge of per-shard softmax states over `data`
        mg = jax.lax.pmax(m, "data")
        w = jnp.exp(m - mg) * l                          # [B, KVl, G]
        den = jax.lax.psum(w, "data")
        num = jax.lax.psum(o * w[..., None], "data")
        return num / jnp.maximum(den, 1e-30)[..., None]

    if ksp is None:
        def body2(q4, kp, vp, tbl, seq):
            return body(q4, kp, vp, None, None, tbl, seq)
        specs = (P(None, "model", None, None),
                 P("data", None, "model", None),
                 P("data", None, "model", None), P(None, None), P(None))
        o = shard_map(body2, mesh=mesh, in_specs=specs,
                      out_specs=P(None, "model", None, None),
                      check_rep=False)(q4, kp, vp, tbl, seq)
    else:
        specs = (P(None, "model", None, None),
                 P("data", None, "model", None),
                 P("data", None, "model", None),
                 P("data", None, "model"), P("data", None, "model"),
                 P(None, None), P(None))
        o = shard_map(body, mesh=mesh, in_specs=specs,
                      out_specs=P(None, "model", None, None),
                      check_rep=False)(q4, kp, vp, ksp, vsp, tbl, seq)
    return o.astype(q.dtype).reshape(b, 1, h, hd)
