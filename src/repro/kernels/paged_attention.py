"""Pallas TPU kernel: ragged paged attention over the serving KV arena.

ONE kernel serves every attention step the paged engine runs: batched
decode (one query token per lane), chunked prefill (a block of query
tokens per lane, scattered straight into the arena first) and the mixed
rounds where both co-schedule in the same jit step. The XLA reference
gather (``models.attention.paged_cache_read``) materializes the FULL
block-table width for every lane — it survives only as the differential
oracle and the fallback for geometries the kernel cannot shard; all
serving traffic streams through here, which is exactly the page-granular
LPDDR5 traffic ``memsys.workload`` (``kv_traffic_paged`` for decode,
``kv_traffic_chunked`` for prefill chunks) charges the Eq. (3)/(4) DSE.

Grid / BlockSpec contract
-------------------------
  * Grid ``(B, KV, QB, P)`` — batch lane x KV head x **q block** x
    block-table slot. The q-block axis is the multi-query extension: each
    lane's ``S`` query tokens are split into ``QB = ceil(S/q_blk)``
    blocks of ``q_blk`` rows. The page axis stays innermost so the
    online-softmax scratch accumulates one (lane, head, q-block)'s pages
    before moving on.
  * Queries are ragged: lane ``b``'s queries sit at absolute positions
    ``q_start[b] + t`` (``t < S``) and attend KV positions
    ``<= q_start[b] + t`` that are ``< kv_len[b]`` — causal masking at
    intra-page granularity, so a chunk attends the pages it just wrote
    plus every earlier page, exactly like one-shot prefill. Query rows at
    positions ``>= kv_len`` (right padding of a short chunk, or a lane
    idling in a mixed round with ``n_new = 0``) emit exactly 0.
  * The arena is viewed as ``[n_pages, page, KV, hd]`` (plus
    ``[n_pages, page, KV]`` scales for the int8 layout). Per grid step
    the BlockSpec index map does a data-dependent fetch of ONE page of
    ONE KV head at row ``tbl[b, p]`` — the ``PrefetchScalarGridSpec``
    scalar-prefetch mechanism, same as ``kernels/qmm.py``'s stream
    routing.
  * Scalar prefetch operands: ``tbl [B, P]`` (block tables), ``q_start
    [B]``, ``kv_len [B]`` and ``meta = [page_offset, n_local_pages]``
    (shard-local page-id window; ``[0, n_pages]`` on a single device).
  * Dead, causally-future, out-of-shard or padding-only fetches are
    remapped to arena row 0 by the index map (never a live page — row 0
    is the reserved null page) and fully masked in the body: q block
    ``qb`` streams page ``p`` only when the block holds a valid query
    (``q_start + qb*q_blk < kv_len``) and the page is causally visible
    to it (``p*page < min(kv_len, q_start + (qb+1)*q_blk)``). Per-lane
    gather work is what ``memsys.workload.chunk_pages_streamed`` counts
    — for decode (``S = 1``) that collapses to ``ceil(kv_len/page)``
    pages, never ``B * P``.
  * Online softmax (flash-style running max / sum) keeps exactly one
    page of K/V resident per step; GQA query groups ride along as extra
    block rows (``q_blk * G`` rows per q block). int8-KV dequant
    (per-page-slot, per-head scales from ``models.kvcache.quantize_kv``)
    is fused before the dot.
  * Outputs: normalized ``o`` plus the running ``(m, l)`` softmax state
    per query row — the state is what makes the kernel mesh-composable:
    under the PR-3 sharding contract the arena's page axis shards over
    ``data``, so each shard runs the kernel over its own page slice and
    the partial ``(o, m, l)`` triples merge with a flash-decoding-style
    ``pmax``/``psum`` reduction (``shard_map`` over the full
    ``(data, model)`` mesh; KV heads stay ``model``-local like
    ``qmm_shard_map``).

``interpret=True`` (the default off-TPU) executes the real kernel body on
CPU, so CI runs the same code path the TPU backend compiles. Block shapes
follow the problem geometry rather than the (8/16/32, 128) MXU tiles —
fine in interpret mode; a production TPU build would pad ``q_blk * G`` /
``hd`` up to the dtype's native tile.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.launch.mesh import axis_size as _mesh_axis

# default q-block rows per grid step; mirrored by the host-side stream
# accounting (memsys.workload.chunk_pages_streamed and the engine's
# prefill_kv_pages_live counter), which must stay page-for-page with the
# index map below
Q_BLOCK = 16


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


# ---------------------------------------------------------------------------
# kernel body
# ---------------------------------------------------------------------------
def _accumulate(tbl_ref, qs_ref, kl_ref, meta_ref, q_ref, k_ref, v_ref,
                ks_ref, vs_ref, o_ref, mo_ref, lo_ref,
                acc_ref, m_ref, l_ref, *, page: int, q_blk: int, g: int,
                window: Optional[int], attn_softcap: Optional[float],
                scale: float):
    b = pl.program_id(0)
    qb = pl.program_id(2)
    p = pl.program_id(3)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)

    kl = kl_ref[b]
    qs = qs_ref[b]
    local = tbl_ref[b, p] - meta_ref[0]
    owned = (local >= 0) & (local < meta_ref[1])
    limit = jnp.minimum(kl, qs + (qb + 1) * q_blk)
    live = ((p * page) < limit) & (qs + qb * q_blk < kl)

    q = q_ref[0, 0, :, 0].astype(jnp.float32) * scale      # [q_blk, G, hd]
    q2 = q.reshape(q_blk * g, q.shape[-1])
    k = k_ref[0, :, 0, :].astype(jnp.float32)              # [page, hd]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    if ks_ref is not None:                                 # fused dequant
        k = k * ks_ref[0, :, 0].astype(jnp.float32)[:, None]
        v = v * vs_ref[0, :, 0].astype(jnp.float32)[:, None]

    scores = jax.lax.dot_general(q2, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    if attn_softcap:
        scores = attn_softcap * jnp.tanh(scores / attn_softcap)

    # row r of the block is query token r // g (GQA groups interleave)
    tok = jax.lax.broadcasted_iota(jnp.int32, (q_blk * g, 1), 0) // g
    pos_q = qs + qb * q_blk + tok                          # [q_blk*g, 1]
    pos_k = p * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    mask = (pos_k <= pos_q) & (pos_k < kl) & (pos_q < kl) & owned & live
    if window is not None:
        mask = mask & (pos_q - pos_k < window)
    scores = jnp.where(mask, scores, -1e30)

    cm = jnp.max(scores, axis=-1, keepdims=True)           # [q_blk*g, 1]
    m_new = jnp.maximum(m_ref[...], cm)
    # probs masked explicitly: with every score at -1e30 AND m still at
    # its -1e30 init (a fully dead row) exp(score - m_new) would be 1
    probs = jnp.where(mask, jnp.exp(scores - m_new), 0.0)
    corr = jnp.exp(m_ref[...] - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(probs, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        probs, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(p == pl.num_programs(3) - 1)
    def _done():
        # a row with no live position keeps l == 0 -> output exactly 0
        hd = acc_ref.shape[-1]
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :, 0] = out.reshape(q_blk, g, hd)
        mo_ref[0, 0, :, 0] = m_ref[...].reshape(q_blk, g)
        lo_ref[0, 0, :, 0] = l_ref[...].reshape(q_blk, g)


def _make_kernel(page, q_blk, g, window, attn_softcap, scale, quantized):
    body = functools.partial(_accumulate, page=page, q_blk=q_blk, g=g,
                             window=window, attn_softcap=attn_softcap,
                             scale=scale)
    if quantized:
        def kernel(tbl, qs, kl, meta, q, k, v, ks, vs, o, mo, lo,
                   acc, m, l):
            body(tbl, qs, kl, meta, q, k, v, ks, vs, o, mo, lo, acc, m, l)
    else:
        def kernel(tbl, qs, kl, meta, q, k, v, o, mo, lo, acc, m, l):
            body(tbl, qs, kl, meta, q, k, v, None, None, o, mo, lo,
                 acc, m, l)
    return kernel


# ---------------------------------------------------------------------------
# shard-local call
# ---------------------------------------------------------------------------
def _ragged_call(q6, kp, vp, ksp, vsp, tbl, qs, kl, meta, *,
                 window, attn_softcap, interpret):
    """One shard's kernel call.

    q6 [B, QB, q_blk, KV, G, hd]; kp/vp [n_pages, page, KV, hd]; ksp/vsp
    [n_pages, page, KV] or None; tbl [B, P]; qs/kl [B];
    meta = [page_offset, n_local_pages]. Returns (o, m, l) — normalized
    output plus the online-softmax state for cross-shard merging, shapes
    o [B, QB, q_blk, KV, G, hd] and m/l [B, QB, q_blk, KV, G]."""
    bsz, qb_n, q_blk, n_kv, g, hd = q6.shape
    page = kp.shape[1]
    n_tbl = tbl.shape[1]
    quantized = ksp is not None
    scale = float(hd) ** -0.5

    def _page_sel(b, h, qb, p, tbl_ref, qs_ref, kl_ref, meta_ref):
        local = tbl_ref[b, p] - meta_ref[0]
        limit = jnp.minimum(kl_ref[b], qs_ref[b] + (qb + 1) * q_blk)
        ok = ((local >= 0) & (local < meta_ref[1])
              & ((p * page) < limit)
              & (qs_ref[b] + qb * q_blk < kl_ref[b]))
        return jnp.where(ok, local, 0)

    def q_map(b, h, qb, p, *refs):
        return (b, qb, 0, h, 0, 0)

    def kv_map(b, h, qb, p, *refs):
        return (_page_sel(b, h, qb, p, *refs), 0, h, 0)

    def sc_map(b, h, qb, p, *refs):
        return (_page_sel(b, h, qb, p, *refs), 0, h)

    def o_map(b, h, qb, p, *refs):
        return (b, qb, 0, h, 0, 0)

    def ml_map(b, h, qb, p, *refs):
        return (b, qb, 0, h, 0)

    in_specs = [pl.BlockSpec((1, 1, q_blk, 1, g, hd), q_map),
                pl.BlockSpec((1, page, 1, hd), kv_map),
                pl.BlockSpec((1, page, 1, hd), kv_map)]
    operands = [q6, kp, vp]
    if quantized:
        in_specs += [pl.BlockSpec((1, page, 1), sc_map),
                     pl.BlockSpec((1, page, 1), sc_map)]
        operands += [ksp, vsp]

    call = pl.pallas_call(
        _make_kernel(page, q_blk, g, window, attn_softcap, scale,
                     quantized),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(bsz, n_kv, qb_n, n_tbl),
            in_specs=in_specs,
            out_specs=[pl.BlockSpec((1, 1, q_blk, 1, g, hd), o_map),
                       pl.BlockSpec((1, 1, q_blk, 1, g), ml_map),
                       pl.BlockSpec((1, 1, q_blk, 1, g), ml_map)],
            scratch_shapes=[pltpu.VMEM((q_blk * g, hd), jnp.float32),
                            pltpu.VMEM((q_blk * g, 1), jnp.float32),
                            pltpu.VMEM((q_blk * g, 1), jnp.float32)]),
        out_shape=[jax.ShapeDtypeStruct((bsz, qb_n, q_blk, n_kv, g, hd),
                                        jnp.float32),
                   jax.ShapeDtypeStruct((bsz, qb_n, q_blk, n_kv, g),
                                        jnp.float32),
                   jax.ShapeDtypeStruct((bsz, qb_n, q_blk, n_kv, g),
                                        jnp.float32)],
        interpret=interpret,
    )
    return call(tbl, qs, kl, meta, *operands)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------
def shard_compatible(mesh, n_pages_total: int, n_kv: int) -> bool:
    """Whether the shard-local kernel honors the PR-3 arena sharding:

    the page axis must divide ``data`` (each shard owns an equal page
    slice) and the KV head count must divide ``model`` (heads stay
    TP-local; a fused-kv_dim split through the middle of a head — legal
    for the XLA gather — cannot run head-local)."""
    if mesh is None:
        return True
    d = _mesh_axis(mesh, "data")
    m = _mesh_axis(mesh, "model")
    return n_pages_total % max(d, 1) == 0 and n_kv % max(m, 1) == 0


def ragged_paged_attention(q: jax.Array, cache: dict, q_start: jax.Array,
                           kv_len: jax.Array, *, n_kv: int, head_dim: int,
                           window: Optional[int] = None,
                           attn_softcap: Optional[float] = None,
                           mesh=None,
                           interpret: Optional[bool] = None,
                           q_block: int = Q_BLOCK) -> jax.Array:
    """Ragged multi-query attention straight off the paged arena.

    q ``[B, S, H, hd]`` — lane ``b``'s queries sit at absolute positions
    ``q_start[b] + t``; ``cache`` holds ``k_pages/v_pages
    [n_pages, page, KV*hd]`` (int8 layouts add ``{k,v}_scale_pages
    [n_pages, page, KV]``) and ``block_tbl [B, max_pages]``;
    ``kv_len [B]`` is each lane's valid KV bound (for a chunk that just
    scattered ``n_new`` tokens, ``q_start + n_new``; for decode,
    position + 1). Query rows at positions ``>= kv_len`` emit exactly 0
    (a 0-token lane emits all zeros). Returns ``[B, S, H, hd]`` in q's
    dtype.

    With a mesh the kernel runs shard-local under ``shard_map`` over the
    full ``(data, model)`` mesh: each data shard streams only its slice
    of the page pool and the partial softmax states merge with a
    flash-decoding ``pmax``/``psum``; KV heads split over ``model``.
    Callers must check :func:`shard_compatible` first.
    """
    b, s, h, hd = q.shape
    if hd != head_dim or h % n_kv:
        raise ValueError((q.shape, n_kv, head_dim))
    g = h // n_kv
    if interpret is None:
        interpret = not _on_tpu()

    kp = cache["k_pages"]
    vp = cache["v_pages"]
    n_pages, page, _ = kp.shape
    kp = kp.reshape(n_pages, page, n_kv, hd)
    vp = vp.reshape(n_pages, page, n_kv, hd)
    ksp = vsp = None
    if "k_scale_pages" in cache:
        ksp = cache["k_scale_pages"]
        vsp = cache["v_scale_pages"]

    q_blk = min(q_block, s)
    qb_n = -(-s // q_blk)
    s_pad = qb_n * q_blk
    q5 = q.reshape(b, s, n_kv, g, hd)
    if s_pad != s:
        q5 = jnp.pad(q5, ((0, 0), (0, s_pad - s), (0, 0), (0, 0), (0, 0)))
    q6 = q5.reshape(b, qb_n, q_blk, n_kv, g, hd)
    tbl = cache["block_tbl"].astype(jnp.int32)
    qs = q_start.astype(jnp.int32)
    kl = kv_len.astype(jnp.int32)
    kw = dict(window=window, attn_softcap=attn_softcap, interpret=interpret)

    def _finish(o):
        o = o.reshape(b, s_pad, h, hd)[:, :s]
        return o.astype(q.dtype)

    d_n = _mesh_axis(mesh, "data") if mesh is not None else 1
    m_n = _mesh_axis(mesh, "model") if mesh is not None else 1
    if mesh is None or d_n * m_n == 1:
        meta = jnp.array([0, n_pages], jnp.int32)
        o, _, _ = _ragged_call(q6, kp, vp, ksp, vsp, tbl, qs, kl, meta,
                               **kw)
        return _finish(o)

    if not shard_compatible(mesh, n_pages, n_kv):
        raise ValueError("arena/head geometry does not divide the mesh; "
                         "gate on shard_compatible() before dispatching")
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    n_local = n_pages // d_n

    def body(q6, kp, vp, ksp, vsp, tbl, qs, kl):
        off = jax.lax.axis_index("data").astype(jnp.int32) * n_local
        meta = jnp.stack([off, jnp.int32(n_local)])
        o, m, l = _ragged_call(q6, kp, vp, ksp, vsp, tbl, qs, kl, meta,
                               **kw)
        # flash-decoding merge of per-shard softmax states over `data`
        mg = jax.lax.pmax(m, "data")
        w = jnp.exp(m - mg) * l                  # [B, QB, q_blk, KVl, G]
        den = jax.lax.psum(w, "data")
        num = jax.lax.psum(o * w[..., None], "data")
        return num / jnp.maximum(den, 1e-30)[..., None]

    q_spec = P(None, None, None, "model", None, None)
    if ksp is None:
        def body2(q6, kp, vp, tbl, qs, kl):
            return body(q6, kp, vp, None, None, tbl, qs, kl)
        specs = (q_spec,
                 P("data", None, "model", None),
                 P("data", None, "model", None),
                 P(None, None), P(None), P(None))
        o = shard_map(body2, mesh=mesh, in_specs=specs,
                      out_specs=q_spec,
                      check_rep=False)(q6, kp, vp, tbl, qs, kl)
    else:
        specs = (q_spec,
                 P("data", None, "model", None),
                 P("data", None, "model", None),
                 P("data", None, "model"), P("data", None, "model"),
                 P(None, None), P(None), P(None))
        o = shard_map(body, mesh=mesh, in_specs=specs,
                      out_specs=q_spec,
                      check_rep=False)(q6, kp, vp, ksp, vsp, tbl, qs, kl)
    return _finish(o)


def paged_decode_attention(q: jax.Array, cache: dict, seq_len: jax.Array,
                           *, n_kv: int, head_dim: int,
                           window: Optional[int] = None,
                           attn_softcap: Optional[float] = None,
                           mesh=None,
                           interpret: Optional[bool] = None) -> jax.Array:
    """Single-token decode view of :func:`ragged_paged_attention`.

    q ``[B, 1, H, hd]``; ``seq_len [B]`` is each lane's valid KV length
    (decode position + 1; 0 marks an inactive lane, whose output is
    exactly 0). Kept as the S == 1 wrapper so decode call sites and the
    differential harness read naturally — there is only ONE kernel."""
    if q.shape[1] != 1:
        raise ValueError(
            f"decode wrapper takes one query token, got S={q.shape[1]}; "
            f"call ragged_paged_attention for multi-query chunks")
    seq = seq_len.astype(jnp.int32)
    return ragged_paged_attention(q, cache, jnp.maximum(seq - 1, 0), seq,
                                  n_kv=n_kv, head_dim=head_dim,
                                  window=window, attn_softcap=attn_softcap,
                                  mesh=mesh, interpret=interpret)
