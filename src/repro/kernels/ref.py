"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernel tests assert_allclose against, and the
fallback compute path on backends without Pallas support (the CPU dry-run
lowers these).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.qtensor import QTensor, dequantize_qtensor


def qmm_ref(x: jax.Array, qt: QTensor) -> jax.Array:
    """Dual-stream quantized matmul oracle: x [M, K] @ dequant(qt) [K, N]."""
    w = dequantize_qtensor(qt, dtype=jnp.float32)
    return jnp.matmul(x.astype(jnp.float32), w).astype(x.dtype)


def unpack3b_ref(packed: jax.Array, n: int) -> jax.Array:
    """Decode a little-endian 3-bit stream (packed uint8) to int32 codes.

    Mirrors core.packing.unpack_codes for bits=3 (bias 4).
    """
    byts = packed.astype(jnp.uint8)
    bits = ((byts[:, None] >> jnp.arange(8, dtype=jnp.uint8)[None, :]) & 1)
    bits = bits.reshape(-1)[: n * 3].reshape(n, 3).astype(jnp.int32)
    vals = bits[:, 0] + (bits[:, 1] << 1) + (bits[:, 2] << 2)
    return vals - 4


def dequant_subtile_ref(qt: QTensor) -> jax.Array:
    """Dense reconstruction oracle (same as core, re-exported for tests)."""
    return dequantize_qtensor(qt, dtype=jnp.float32)
