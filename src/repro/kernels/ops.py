"""Jitted public wrappers around the Pallas kernels, with XLA fallbacks.

`qmm` is the dispatch point used by models.layers.matmul_any: when
use_pallas is False (CPU dry-run / non-TPU backends) it lowers the pure-jnp
oracle; when True it calls the Pallas kernel (interpret-mode on CPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.qtensor import QTensor
from repro.kernels import ref as kref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def qmm(x: jax.Array, qt: QTensor, use_pallas: bool = False) -> jax.Array:
    """x [..., K] @ dequant(qt) [K, N] with batch dims preserved."""
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    if use_pallas:
        from repro.kernels.qmm import qmm_pallas
        m = x2.shape[0]
        block_m = 128 if m % 128 == 0 else (8 if m % 8 == 0 else None)
        if block_m is not None and k % 128 == 0 and qt.shape[1] % 128 == 0:
            y = qmm_pallas(x2, qt, block_m=block_m,
                           interpret=not _on_tpu())
            return y.reshape(*lead, qt.shape[1])
    y = kref.qmm_ref(x2, qt)
    return y.reshape(*lead, qt.shape[1])


def unpack3b(packed: jax.Array, n: int, use_pallas: bool = False
             ) -> jax.Array:
    if use_pallas and n % 8 == 0:
        block = 1024 if n % 1024 == 0 else (8 if n % 8 == 0 else None)
        if block is not None:
            from repro.kernels.unpack3b import unpack3b_pallas
            return unpack3b_pallas(packed, n, block_codes=block,
                                   interpret=not _on_tpu())
    return kref.unpack3b_ref(packed, n)
