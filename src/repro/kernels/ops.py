"""Jitted public wrappers around the Pallas kernels, with XLA fallbacks.

`qmm` is the dispatch point used by models.layers.matmul_any. The tiling
decision is made by `qmm_plan` keyed on the flattened activation width M
(= B*C when called from the serving step, so the plan is effectively keyed
on the engine's compiled step width C ∈ {1, chunk}):

* pallas backends: M is right-padded to the next multiple of 8 and the
  result sliced back — decode never falls back to a full-matrix dequant.
  M >= 128 (and M % 128 == 0 after padding) selects the column-strip
  kernel (128-deep MXU accumulation); smaller M selects the decode-width
  kernel with the widest N strip that divides N.
* XLA backends (use_pallas=False): M <= 2 lowers `qmm_skinny`, a
  stream-direct einsum + segment-scatter that skips the dense dequant
  entirely (wins at single-lane decode); wider M lowers the `qmm_ref`
  oracle, whose one-shot dequant amortizes better.

Shapes the kernels cannot tile (K or N not a multiple of 128, or a
non-(8,128) subtile) fall back to `qmm_ref` regardless of M.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.qtensor import QTensor
from repro.kernels import ref as kref

# Widest XLA stream-direct width: below this, qmm_skinny's gather/einsum
# beats qmm_ref's dense dequant on CPU; above, the dequant amortizes.
_SKINNY_XLA_MAX_M = 2


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def qmm_plan(m: int, k: int, n: int, subtile: tuple[int, int],
             use_pallas: bool = False) -> dict:
    """Pick the qmm lowering for an [m, k] @ [k, n] call.

    Returns {"path", "pad_m", "block_m", "block_n"}; path is one of
    "colstrip" | "decode" | "skinny_xla" | "ref". pad_m is the padded M
    the kernel runs at (== m when no padding is needed).
    """
    tileable = (subtile == (8, 128) and k % 128 == 0 and n % 128 == 0)
    if use_pallas and tileable:
        pad_m = -(-m // 8) * 8
        if pad_m >= 128 and pad_m % 128 == 0:
            return {"path": "colstrip", "pad_m": pad_m,
                    "block_m": 128, "block_n": 128}
        block_n = next(bn for bn in (512, 256, 128) if n % bn == 0)
        return {"path": "decode", "pad_m": pad_m,
                "block_m": 8, "block_n": block_n}
    if not use_pallas and m <= _SKINNY_XLA_MAX_M:
        return {"path": "skinny_xla", "pad_m": m,
                "block_m": m, "block_n": n}
    return {"path": "ref", "pad_m": m, "block_m": m, "block_n": n}


def qmm_skinny(x: jax.Array, qt: QTensor) -> jax.Array:
    """Stream-direct skinny-M matmul: einsum each packed subtile against
    its activation slice and scatter-add into per-stream accumulators —
    no dense [K, N] weight matrix is ever materialized."""
    m, k = x.shape
    r, c = qt.subtile
    gr, gc = qt.is_out.shape
    n = qt.shape[1]
    pos = qt.stream_pos.reshape(-1)
    tags = qt.is_out.reshape(-1)
    n_in = qt.in_codes.shape[0]
    codes = jnp.concatenate([qt.in_codes.astype(jnp.float32),
                             qt.out_codes.astype(jnp.float32)], axis=0)
    slot = jnp.where(tags, n_in + pos, pos)           # [gr*gc]
    sub = jnp.arange(gr * gc, dtype=jnp.int32)
    row_of = sub // gc
    col_of = sub % gc
    xt = x.reshape(m, gr, r).transpose(1, 0, 2)       # [gr, m, r]
    xg = xt[row_of]                                   # [n_sub, m, r]
    wg = codes[slot]                                  # [n_sub, r, c]
    contrib = jnp.einsum("smr,src->smc", xg, wg)
    seg = tags.astype(jnp.int32)                      # 0 = in, 1 = out
    acc = jnp.zeros((2, gc, m, c), jnp.float32)
    acc = acc.at[seg, col_of].add(contrib)
    y_in = acc[0].transpose(1, 0, 2).reshape(m, n)
    y_out = acc[1].transpose(1, 0, 2).reshape(m, n)
    return (y_in * qt.scale_in + y_out * qt.scale_out).astype(x.dtype)


def qmm(x: jax.Array, qt: QTensor, use_pallas: bool = False) -> jax.Array:
    """x [..., K] @ dequant(qt) [K, N] with batch dims preserved."""
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = qt.shape[1]
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    plan = qmm_plan(m, k, n, qt.subtile, use_pallas=use_pallas)
    if plan["path"] in ("decode", "colstrip"):
        from repro.kernels.qmm import qmm_pallas, qmm_pallas_colstrip
        if plan["pad_m"] != m:
            x2 = jnp.pad(x2, ((0, plan["pad_m"] - m), (0, 0)))
        if plan["path"] == "colstrip":
            y = qmm_pallas_colstrip(x2, qt, block_m=plan["block_m"],
                                    interpret=not _on_tpu())
        else:
            y = qmm_pallas(x2, qt, block_m=plan["block_m"],
                           block_n=plan["block_n"],
                           interpret=not _on_tpu())
        return y[:m].reshape(*lead, n)
    if plan["path"] == "skinny_xla":
        return qmm_skinny(x2, qt).reshape(*lead, n)
    return kref.qmm_ref(x2, qt).reshape(*lead, n)


def unpack3b(packed: jax.Array, n: int, use_pallas: bool = False
             ) -> jax.Array:
    if use_pallas and n % 8 == 0:
        block = 1024 if n % 1024 == 0 else (8 if n % 8 == 0 else None)
        if block is not None:
            from repro.kernels.unpack3b import unpack3b_pallas
            return unpack3b_pallas(packed, n, block_codes=block,
                                   interpret=not _on_tpu())
    return kref.unpack3b_ref(packed, n)
