"""Pallas TPU kernel: dual-stream QMC matmul (the Model Weight Controller).

The paper's heterogeneous memory controller fetches outlier weights from
MRAM and inlier weights from MLC ReRAM concurrently and merges them before
they reach the compute unit (Eq. 3: T = max(T_mram, T_reram) + T_sync).
On TPU the analogue is this kernel: the two packed code streams live in HBM;
for every (128, 128) weight tile the kernel pulls the 16 constituent (8, 128)
subtiles from whichever stream owns them, dequantizes them next to the MXU in
VMEM, and feeds the reconstructed slice to the matmul accumulator.

Grid: (M/bm, N/128, K/128, 16). The innermost axis walks the 16 subtile rows
of the current K tile; per-subtile stream tags/positions are scalar-prefetched
(SMEM) so the BlockSpec index maps can do data-dependent fetches — the same
mechanism block-sparse TPU kernels use. VMEM working set per step:
x tile (bm x 128 x 4B) + 2 subtiles (8 x 128) + scales + fp32 accumulator
(bm x 128 x 4B) ~= 134 KB at bm=128 — comfortably inside v5e's ~16 MB VMEM,
leaving room for double buffering of the streamed subtiles.

On real hardware the 8-deep MXU issue is hidden behind the weight-stream DMA
(decode is bandwidth-bound — exactly the paper's regime); DESIGN.md describes
the column-strip variant that restores 128-deep MXU ops for compute-bound
prefill.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.qtensor import QTensor


def _qmm_kernel(tags_ref, pos_ref,          # scalar prefetch (SMEM)
                x_ref, in_ref, out_ref, sin_ref, sout_ref,  # VMEM in
                y_ref,                       # VMEM out
                acc_ref,                     # VMEM scratch
                *, n_sub_k: int, out_dtype):
    """One grid step: accumulate x[bm, 8] @ subtile[8, 128] into acc."""
    s = pl.program_id(3)                     # subtile row within the K tile
    k = pl.program_id(2)
    j = pl.program_id(1)

    @pl.when((k == 0) & (s == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Merge point: choose the stream this subtile was routed to at PTQ time.
    gi = k * n_sub_k + s                     # global subtile row index
    is_out = tags_ref[gi, j]
    w_in = in_ref[0].astype(jnp.float32) * sin_ref[...]
    w_out = out_ref[0].astype(jnp.float32) * sout_ref[...]
    w = jnp.where(is_out > 0, w_out, w_in)   # [8, 128] dequantized

    xs = x_ref[...].astype(jnp.float32)      # [bm, 8] (sliced by BlockSpec)
    acc_ref[...] += jax.lax.dot_general(
        xs, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when((k == pl.num_programs(2) - 1) & (s == n_sub_k - 1))
    def _done():
        y_ref[...] = acc_ref[...].astype(out_dtype)


def qmm_pallas(x: jax.Array, qt: QTensor, *, block_m: int = 128,
               interpret: bool = True) -> jax.Array:
    """x [M, K] @ dequant(qt) [K, N] via the dual-stream Pallas kernel.

    Requires M % block_m == 0, K % 128 == 0, N % 128 == 0 (production tiles).
    `interpret=True` executes the kernel body on CPU for validation; on a
    real TPU backend pass interpret=False.
    """
    m, k_dim = x.shape
    k_w, n = qt.shape
    assert k_dim == k_w, (x.shape, qt.shape)
    r, c = qt.subtile
    assert (r, c) == (8, 128), "kernel assumes (8,128) subtiles"
    assert m % block_m == 0 and k_dim % 128 == 0 and n % 128 == 0

    n_sub_k = 128 // r                       # 16 subtile rows per K tile
    grid = (m // block_m, n // 128, k_dim // 128, n_sub_k)

    tags = qt.is_out.astype(jnp.int32)       # [gr, gc]
    pos = qt.stream_pos.astype(jnp.int32)    # [gr, gc]

    def x_map(i, j, k, s, tags_ref, pos_ref):
        return (i, k * n_sub_k + s)

    def in_map(i, j, k, s, tags_ref, pos_ref):
        gi = k * n_sub_k + s
        p = pos_ref[gi, j]
        # outlier subtiles read stream slot 0 (discarded by the select)
        return (jnp.where(tags_ref[gi, j] > 0, 0, p), 0, 0)

    def out_map(i, j, k, s, tags_ref, pos_ref):
        gi = k * n_sub_k + s
        p = pos_ref[gi, j]
        return (jnp.where(tags_ref[gi, j] > 0, p, 0), 0, 0)

    def scale_map(i, j, k, s, tags_ref, pos_ref):
        return (0, j)

    def y_map(i, j, k, s, tags_ref, pos_ref):
        return (i, j)

    kernel = functools.partial(_qmm_kernel, n_sub_k=n_sub_k,
                               out_dtype=x.dtype)
    # The kernel consumes codes as int8; on TPU the int4->int8 container
    # conversion happens in the load path for free.
    in_codes = qt.in_codes.astype(jnp.int8)

    call = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, 8), x_map),
                pl.BlockSpec((1, r, c), in_map),
                pl.BlockSpec((1, r, c), out_map),
                pl.BlockSpec((1, 128), scale_map),
                pl.BlockSpec((1, 128), scale_map),
            ],
            out_specs=pl.BlockSpec((block_m, 128), y_map),
            scratch_shapes=[pltpu.VMEM((block_m, 128), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )
    return call(tags, pos, x, in_codes, qt.out_codes,
                qt.scale_in, qt.scale_out)
