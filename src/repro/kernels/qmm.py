"""Pallas TPU kernels: dual-stream QMC matmul (the Model Weight Controller).

The paper's heterogeneous memory controller fetches outlier weights from
MRAM and inlier weights from MLC ReRAM concurrently and merges them before
they reach the compute unit (Eq. 3: T = max(T_mram, T_reram) + T_sync).
On TPU the analogue is these kernels: the two packed code streams live in
HBM; for every weight tile the kernel pulls the constituent (8, 128)
subtiles from whichever stream owns them, dequantizes them next to the MXU
in VMEM, and feeds the reconstructed slice to the matmul accumulator.

Tiling contract (decode-width vs column-strip)
----------------------------------------------
Two tilings share one stream format, selected by ``kernels.ops.qmm_plan``
on the flattened activation width M (= B*C under the serving step, so the
choice is keyed on the engine's compiled step widths C in {1, chunk}):

* **Decode-width** (``qmm_pallas``, ``block_m=8``, wide ``block_n``) — the
  skinny-M shape decode drives (M = live slots; ops.qmm right-pads M to
  the next multiple of 8 and slices the result). Grid
  ``(M/bm, N/bn, K/128, 16 * bn/128)``: the innermost axis walks the
  ``bn/128`` column subtiles of each of the 16 subtile rows of the
  current K tile, so the x block (``bm x 8``, indexed by (i, k, s) only)
  stays resident across the whole N strip and the scalar-prefetched
  tag/pos tables are fetched once per kernel launch and reused across
  both the M axis and the strip. Per-step VMEM at bm=8, bn=512:
  x (8x8x4B) + 2 code subtiles (2x8x128) + scales (2x128x4B) + fp32
  accumulator (8x512x4B) + y (8x512x4B) ~= 36 KB — deep double-buffering
  headroom inside a ~16 MB VMEM budget.
* **Column-strip** (``qmm_pallas_colstrip``, ``block_m>=128``) — the
  compute-bound prefill/training shape. Grid ``(M/bm, N/128, K/128, 16)``;
  the 16 subtiles of one (128, 128) weight tile are dequantized into a
  VMEM staging tile and the MXU sees ONE 128-deep
  ``x[bm,128] @ staging[128,128]`` op per K tile instead of sixteen
  8-deep ops — contiguous same-stream subtile runs of a column are
  fetched back to back while the x tile (indexed by (i, k) only) stays
  resident. Per-step VMEM at bm=128: x (128x128x4B) + staging
  (128x128x4B) + acc (128x128x4B) + 2 code subtiles + scales ~= 200 KB.

Both tilings route every *dead-stream* fetch through a hold table
(``_hold_tables``): instead of loading stream slot 0, the BlockSpec index
map re-issues the most recently fetched live slot of that stream, so the
Pallas pipeline's same-index elision turns the paper's "2x weight
traffic" select-merge into at most one subtile fetch per stream per run
of equal tags — on real hardware the dead stream costs no DMA at all.
Block-granular index maps cannot express arbitrary element offsets, so
a literal contiguous-run burst is not representable; repeated-index
elision is the TPU-native equivalent.

``interpret=True`` executes the kernel bodies on CPU for validation; the
serving CPU fallback is the XLA path in ``kernels/ops.py``, not these.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.qtensor import QTensor


def _hold_tables(tags: jax.Array, pos: jax.Array):
    """Per-stream DMA-elision index tables, [gr, gc] each.

    ``hold_in[gi, j]`` is the inlier-stream slot to *fetch* when the
    kernel is at subtile row gi of column j: the subtile's own slot when
    the tag routes it to the inlier stream, else the most recently
    fetched inlier slot of that column (so the fetch index repeats and
    the Pallas pipeline elides the copy). Rows before the first live
    slot fall back to 0 — always valid because ``quantize_qtensor`` pads
    empty streams with one dummy tile. ``hold_out`` is the mirror image.
    Pure jnp (runs under jit: tags/pos are traced pytree leaves).
    """
    def hold(mine):
        marked = jnp.where(mine, pos, -1)                     # [gr, gc]
        # "last non-(-1) above me" prefix scan down the subtile rows
        last = jax.lax.associative_scan(
            lambda a, b: jnp.where(b >= 0, b, a), marked, axis=0)
        return jnp.maximum(last, 0).astype(jnp.int32)

    return hold(~tags), hold(tags)


def _qmm_kernel(tags_ref, hin_ref, hout_ref,   # scalar prefetch (SMEM)
                x_ref, in_ref, out_ref, sin_ref, sout_ref,  # VMEM in
                y_ref,                          # VMEM out
                acc_ref,                        # VMEM scratch
                *, n_sub_k: int, cn: int, out_dtype):
    """Decode-width step: accumulate x[bm, 8] @ subtile[8, 128] into the
    strip accumulator column jj of the current N strip."""
    t = pl.program_id(3)                        # s * cn + jj
    s = t // cn
    jj = t % cn
    k = pl.program_id(2)

    @pl.when((k == 0) & (t == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Merge point: choose the stream this subtile was routed to at PTQ
    # time (the dead stream's ref re-fetched its held slot — no new DMA).
    gi = k * n_sub_k + s
    gcol = pl.program_id(1) * cn + jj
    is_out = tags_ref[gi, gcol]
    w_in = in_ref[0].astype(jnp.float32) * sin_ref[...]
    w_out = out_ref[0].astype(jnp.float32) * sout_ref[...]
    w = jnp.where(is_out > 0, w_out, w_in)      # [8, 128] dequantized

    xs = x_ref[...].astype(jnp.float32)         # [bm, 8]
    acc_ref[:, pl.dslice(jj * 128, 128)] += jax.lax.dot_general(
        xs, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when((k == pl.num_programs(2) - 1)
             & (t == pl.num_programs(3) - 1))
    def _done():
        y_ref[...] = acc_ref[...].astype(out_dtype)


def qmm_pallas(x: jax.Array, qt: QTensor, *, block_m: int = 8,
               block_n: int = 128, interpret: bool = True) -> jax.Array:
    """x [M, K] @ dequant(qt) [K, N] via the decode-width tiling.

    Requires M % block_m == 0, K % 128 == 0, N % block_n == 0 and
    block_n % 128 == 0 (``kernels.ops.qmm`` pads M and picks the blocks;
    see the module docstring for the tiling contract). ``interpret=True``
    executes the kernel body on CPU; pass False on a real TPU backend.
    """
    m, k_dim = x.shape
    k_w, n = qt.shape
    assert k_dim == k_w, (x.shape, qt.shape)
    r, c = qt.subtile
    assert (r, c) == (8, 128), "kernel assumes (8,128) subtiles"
    assert m % block_m == 0 and k_dim % 128 == 0
    assert block_n % 128 == 0 and n % block_n == 0

    n_sub_k = 128 // r                       # 16 subtile rows per K tile
    cn = block_n // 128                      # column subtiles per N strip
    grid = (m // block_m, n // block_n, k_dim // 128, n_sub_k * cn)

    tags = qt.is_out.astype(jnp.int32)       # [gr, gc]
    hold_in, hold_out = _hold_tables(qt.is_out, qt.stream_pos)

    def x_map(i, j, k, t, tags_ref, hin_ref, hout_ref):
        return (i, k * n_sub_k + t // cn)

    def in_map(i, j, k, t, tags_ref, hin_ref, hout_ref):
        gi = k * n_sub_k + t // cn
        return (hin_ref[gi, j * cn + t % cn], 0, 0)

    def out_map(i, j, k, t, tags_ref, hin_ref, hout_ref):
        gi = k * n_sub_k + t // cn
        return (hout_ref[gi, j * cn + t % cn], 0, 0)

    def scale_map(i, j, k, t, tags_ref, hin_ref, hout_ref):
        return (0, j * cn + t % cn)

    def y_map(i, j, k, t, tags_ref, hin_ref, hout_ref):
        return (i, j)

    kernel = functools.partial(_qmm_kernel, n_sub_k=n_sub_k, cn=cn,
                               out_dtype=x.dtype)
    # The kernel consumes codes as int8; on TPU the int4->int8 container
    # conversion happens in the load path for free.
    in_codes = qt.in_codes.astype(jnp.int8)

    call = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, 8), x_map),
                pl.BlockSpec((1, r, c), in_map),
                pl.BlockSpec((1, r, c), out_map),
                pl.BlockSpec((1, 128), scale_map),
                pl.BlockSpec((1, 128), scale_map),
            ],
            out_specs=pl.BlockSpec((block_m, block_n), y_map),
            scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )
    return call(tags, hold_in, hold_out, x, in_codes, qt.out_codes,
                qt.scale_in, qt.scale_out)


def _qmm_colstrip_kernel(tags_ref, hin_ref, hout_ref,
                         x_ref, in_ref, out_ref, sin_ref, sout_ref,
                         y_ref,
                         acc_ref, stage_ref,
                         *, n_sub_k: int, out_dtype):
    """Column-strip step: stage 16 dequantized subtiles into a (128, 128)
    VMEM tile, then issue ONE 128-deep MXU op per K tile."""
    s = pl.program_id(3)
    k = pl.program_id(2)
    j = pl.program_id(1)

    @pl.when((k == 0) & (s == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    gi = k * n_sub_k + s
    is_out = tags_ref[gi, j]
    w_in = in_ref[0].astype(jnp.float32) * sin_ref[...]
    w_out = out_ref[0].astype(jnp.float32) * sout_ref[...]
    stage_ref[pl.dslice(s * 8, 8), :] = jnp.where(is_out > 0, w_out, w_in)

    @pl.when(s == n_sub_k - 1)
    def _accum():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...].astype(jnp.float32), stage_ref[...],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when((k == pl.num_programs(2) - 1) & (s == n_sub_k - 1))
    def _done():
        y_ref[...] = acc_ref[...].astype(out_dtype)


def qmm_pallas_colstrip(x: jax.Array, qt: QTensor, *, block_m: int = 128,
                        interpret: bool = True) -> jax.Array:
    """x [M, K] @ dequant(qt) [K, N] via the column-strip tiling
    (128-deep MXU accumulation — the compute-bound prefill shape).

    Requires M % block_m == 0 (block_m >= 128), K % 128 == 0,
    N % 128 == 0."""
    m, k_dim = x.shape
    k_w, n = qt.shape
    assert k_dim == k_w, (x.shape, qt.shape)
    r, c = qt.subtile
    assert (r, c) == (8, 128), "kernel assumes (8,128) subtiles"
    assert block_m >= 128 and m % block_m == 0
    assert k_dim % 128 == 0 and n % 128 == 0

    n_sub_k = 128 // r
    grid = (m // block_m, n // 128, k_dim // 128, n_sub_k)

    tags = qt.is_out.astype(jnp.int32)
    hold_in, hold_out = _hold_tables(qt.is_out, qt.stream_pos)

    def x_map(i, j, k, s, tags_ref, hin_ref, hout_ref):
        return (i, k)

    def in_map(i, j, k, s, tags_ref, hin_ref, hout_ref):
        return (hin_ref[k * n_sub_k + s, j], 0, 0)

    def out_map(i, j, k, s, tags_ref, hin_ref, hout_ref):
        return (hout_ref[k * n_sub_k + s, j], 0, 0)

    def scale_map(i, j, k, s, tags_ref, hin_ref, hout_ref):
        return (0, j)

    def y_map(i, j, k, s, tags_ref, hin_ref, hout_ref):
        return (i, j)

    kernel = functools.partial(_qmm_colstrip_kernel, n_sub_k=n_sub_k,
                               out_dtype=x.dtype)
    in_codes = qt.in_codes.astype(jnp.int8)

    call = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, 128), x_map),
                pl.BlockSpec((1, r, c), in_map),
                pl.BlockSpec((1, r, c), out_map),
                pl.BlockSpec((1, 128), scale_map),
                pl.BlockSpec((1, 128), scale_map),
            ],
            out_specs=pl.BlockSpec((block_m, 128), y_map),
            scratch_shapes=[pltpu.VMEM((block_m, 128), jnp.float32),
                            pltpu.VMEM((128, 128), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )
    return call(tags, hold_in, hold_out, x, in_codes, qt.out_codes,
                qt.scale_in, qt.scale_out)
