"""Pallas kernel: 3-bit bitstream decode (the ReRAM read path).

Decodes a packed little-endian 3-bit code stream (uint8 bytes) into signed
integer codes. This models the paper's "bit packing/unpacking" stage — the
mismatch between logical 3-bit weights and physical cell storage — as a
vectorizable shift/mask pipeline: each block of 3 bytes yields 8 codes, so a
(block_n*3,) byte tile expands to a (block_n*8,) code tile with only
word-aligned loads, shifts and masks (VPU-friendly; no gathers).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _unpack3b_kernel(p_ref, o_ref, *, codes_per_block: int):
    """p_ref: [3*codes_per_block//8] uint8 -> o_ref: [codes_per_block] int32."""
    n_groups = codes_per_block // 8
    byts = p_ref[...].astype(jnp.int32).reshape(n_groups, 3)
    b0, b1, b2 = byts[:, 0], byts[:, 1], byts[:, 2]
    word = b0 | (b1 << 8) | (b2 << 16)       # 24 bits = 8 codes
    shifts = jnp.arange(8, dtype=jnp.int32) * 3
    codes = (word[:, None] >> shifts[None, :]) & 0x7
    o_ref[...] = (codes - 4).reshape(codes_per_block)


def unpack3b_pallas(packed: jax.Array, n: int, *, block_codes: int = 1024,
                    interpret: bool = True) -> jax.Array:
    """Decode `n` 3-bit codes from a packed uint8 stream.

    n must be a multiple of 8 and of block_codes; the stream length must be
    exactly 3*n/8 bytes (pad upstream — core.packing pads the final byte).
    """
    assert n % 8 == 0 and n % block_codes == 0
    nbytes = 3 * n // 8
    assert packed.shape == (nbytes,), (packed.shape, nbytes)
    bytes_per_block = 3 * block_codes // 8

    kernel = functools.partial(_unpack3b_kernel,
                               codes_per_block=block_codes)
    return pl.pallas_call(
        kernel,
        grid=(n // block_codes,),
        in_specs=[pl.BlockSpec((bytes_per_block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block_codes,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(packed)
