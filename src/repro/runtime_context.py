"""Trace-time runtime context: the active mesh for shard_map-based ops.

Step builders (serve/train/dryrun) set the mesh before tracing; model code
reads it inside matmul dispatch. Trace-time constant — never crosses into
runtime values.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Tuple

_MESH = None
_DP: Tuple[str, ...] = ()


@contextlib.contextmanager
def use_mesh(mesh, dp: Tuple[str, ...] = ()):
    global _MESH, _DP
    prev, prev_dp = _MESH, _DP
    _MESH, _DP = mesh, tuple(dp)
    try:
        yield
    finally:
        _MESH, _DP = prev, prev_dp


def current_mesh():
    return _MESH


def current_dp() -> Tuple[str, ...]:
    return _DP
