"""Vocab-chunked fused lm_head + cross-entropy (beyond-paper optimization).

The naive path materializes logits [T, V] in f32 twice (forward + dlogits in
backward) and — with a vocab-sharded lm_head — forces an all-gather of the
full logits for the label gather. For gemma2's 256k vocab at 1M tokens
that's the dominant memory AND collective term of the train step.

This implementation scans over vocab chunks with an online logsumexp and a
custom VJP that regenerates each chunk's logits in the backward pass, so
peak residency is O(T * chunk) and the label "gather" is an arithmetic mask
(no cross-shard gather). The gold logit is accumulated with masks, keeping
every chunk's compute local to its vocab shard under GSPMD.

loss = mean_mask( logsumexp(logits) - logits[label] ),
logits = softcap(x @ w) with the model's optional logit softcap.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp


def _pad_w(w: jax.Array, chunk: int):
    d, v = w.shape
    n_chunks = -(-v // chunk)
    pad = n_chunks * chunk - v
    if pad:
        w = jnp.pad(w, ((0, 0), (0, pad)))
    # [n_chunks, d, chunk]
    return w.reshape(d, n_chunks, chunk).swapaxes(0, 1), n_chunks, v


def _chunk_logits(x2, w_c, c0, chunk, v, softcap):
    """x2 [T, d] f32-accum matmul -> softcapped f32 logits + valid mask."""
    logits = jnp.matmul(x2, w_c.astype(x2.dtype),
                        preferred_element_type=jnp.float32)
    logits = logits.astype(jnp.float32)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    col = c0 + jnp.arange(chunk)
    valid = col < v
    logits = jnp.where(valid[None, :], logits, -jnp.inf)
    return logits, col, valid


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def chunked_ce(x2: jax.Array, w: jax.Array, labels1: jax.Array,
               chunk: int, softcap: Optional[float],
               mask_info: tuple) -> jax.Array:
    loss, _ = _fwd(x2, w, labels1, chunk, softcap, mask_info)
    return loss


def _fwd(x2, w, labels1, chunk, softcap, mask_info):
    t, d = x2.shape
    w_stack, n_chunks, v = _pad_w(w, chunk)
    neg = jnp.float32(-1e30)

    def body(carry, inp):
        m, s, gold = carry
        c_idx, w_c = inp
        logits, col, _ = _chunk_logits(x2, w_c, c_idx * chunk, chunk, v,
                                       softcap)
        cm = jnp.max(logits, axis=1)
        m_new = jnp.maximum(m, cm)
        s = s * jnp.exp(m - m_new) + jnp.sum(
            jnp.exp(logits - m_new[:, None]), axis=1)
        hit = (labels1[:, None] == col[None, :])
        gold = gold + jnp.sum(jnp.where(hit, logits, 0.0), axis=1)
        return (m_new, s, gold), None

    init = (jnp.full((t,), neg), jnp.zeros((t,)), jnp.zeros((t,)))
    (m, s, gold), _ = jax.lax.scan(
        body, init, (jnp.arange(n_chunks), w_stack))
    lse = m + jnp.log(s)
    nll = lse - gold
    tok_mask, denom = mask_info_arrays(mask_info, t)
    loss = jnp.sum(nll * tok_mask) / denom
    return loss, (x2, w, labels1, lse, tok_mask, denom)


def mask_info_arrays(mask_info, t):
    kind, payload = mask_info
    if kind == "none":
        return jnp.ones((t,), jnp.float32), jnp.float32(t)
    raise ValueError(kind)


def _bwd(chunk, softcap, mask_info, res, g):
    x2, w, labels1, lse, tok_mask, denom = res
    t, d = x2.shape
    w_stack, n_chunks, v = _pad_w(w, chunk)
    coef = (g * tok_mask / denom).astype(jnp.float32)   # [T]

    def body(dx, inp):
        c_idx, w_c = inp
        logits, col, valid = _chunk_logits(x2, w_c, c_idx * chunk, chunk,
                                           v, softcap)
        p = jnp.exp(logits - lse[:, None])              # softmax chunk
        hit = (labels1[:, None] == col[None, :]).astype(jnp.float32)
        dlog = (p - hit) * coef[:, None]                # [T, chunk]
        if softcap is not None:
            th = logits / softcap                       # tanh(z/cap)
            dlog = dlog * (1.0 - jnp.square(th))
        dlog = jnp.where(valid[None, :], dlog, 0.0)
        dw_c = jnp.matmul(x2.T.astype(jnp.float32), dlog)   # [d, chunk]
        dx = dx + jnp.matmul(dlog, w_c.astype(jnp.float32).T)
        return dx, dw_c.astype(w.dtype)

    body = jax.checkpoint(body)
    dx, dw_stack = jax.lax.scan(
        body, jnp.zeros((t, d), jnp.float32),
        (jnp.arange(n_chunks), w_stack))
    dw = dw_stack.swapaxes(0, 1).reshape(d, n_chunks * chunk)[:, :v]
    return dx.astype(x2.dtype), dw.astype(w.dtype), None


chunked_ce.defvjp(_fwd, _bwd)


def chunked_ce_loss(x: jax.Array, w: jax.Array, labels: jax.Array, *,
                    chunk: int = 16384,
                    logit_softcap: Optional[float] = None,
                    mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token NLL over [B, S]; never materializes [T, V] logits.

    x [B,S,d]; w [d,V] (pass embed.T for tied embeddings); labels [B,S].
    """
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    labels1 = labels.reshape(-1)
    if mask is not None:
        # fold an explicit mask by zeroing labels' contribution: simplest
        # correct route is the unchunked path; train shapes don't mask.
        raise NotImplementedError("chunked CE with loss masks")
    return chunked_ce(x2, w, labels1, chunk, logit_softcap, ("none", None))


def sharded_ce_loss(x: jax.Array, w: jax.Array, labels: jax.Array, *,
                    logit_softcap: Optional[float] = None) -> jax.Array:
    """Gather-free CE: the SPMD-native variant (§Perf iteration log).

    The naive loss gathers logits across the vocab-sharded lm_head because
    of take_along_axis; the scan-chunked variant misaligns chunk boundaries
    with vocab shards and gathers too. This formulation replaces the label
    gather with an arithmetic mask so every reduction over V is a partial
    reduction + tiny all-reduce — GSPMD keeps logits [T, V/tp] resident per
    device and never moves them.
    """
    b, s, d = x.shape
    t = b * s
    x2 = x.reshape(t, d)
    labels1 = labels.reshape(-1)
    v = w.shape[1]
    # Pin the layouts GSPMD should use: tokens stay dp-sharded, the head's
    # contraction dim is gathered (small: d*V bf16) instead of letting the
    # partitioner all-reduce [T, V/tp] f32 partial logits (67 GB/dev).
    from repro import runtime_context as rctx
    mesh = rctx.current_mesh()
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        dp = rctx.current_dp() or None
        tp = "model" if "model" in mesh.axis_names else None
        dp_n = 1
        for a in (dp or ()):
            dp_n *= mesh.devices.shape[list(mesh.axis_names).index(a)]
        dp = dp if (dp and t % max(dp_n, 1) == 0) else None
        cst = jax.lax.with_sharding_constraint
        x2 = cst(x2, NamedSharding(mesh, P(dp, None)))
        w = cst(w, NamedSharding(mesh, P(None, tp)))
        labels1 = cst(labels1, NamedSharding(mesh, P(dp)))
    logits = jnp.matmul(x2, w, preferred_element_type=jnp.float32)
    if mesh is not None:
        logits = cst(logits, NamedSharding(mesh, P(dp, tp)))
    logits = logits.astype(jnp.float32)
    if logit_softcap is not None:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)
    m = jnp.max(logits, axis=1)
    se = jnp.sum(jnp.exp(logits - m[:, None]), axis=1)
    lse = m + jnp.log(se)
    hit = labels1[:, None] == jnp.arange(v, dtype=labels1.dtype)[None, :]
    gold = jnp.sum(jnp.where(hit, logits, 0.0), axis=1)
    return jnp.mean(lse - gold)
