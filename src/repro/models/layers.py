"""Primitive layers: norms, linears (dense or QTensor), rotary embeddings.

All functions are pure; parameters are plain pytree leaves. `matmul_any`
is the single dispatch point where QMC-quantized serving weights enter the
compute graph.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.qtensor import QTensor
from repro.kernels import ops as kops


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(
        jnp.float32))).astype(dt)


def matmul_any(x: jax.Array, w, use_pallas: bool = False,
               tp_dim: int = 1) -> jax.Array:
    """x @ w where w is dense, a QTensor, or a ShardedQTensor (QMC serving).

    tp_dim: which weight dim carries tensor parallelism (1 = column-
    parallel wq/w_up..., 0 = row-parallel wo/w_down) — used by the ZeRO-3
    weight-gathering constraint."""
    from repro.core.qtensor_sharded import (ShardedQTensor, qmm_shard_map,
                                            qmm_sharded_ref)
    if isinstance(w, ShardedQTensor):
        from repro import runtime_context as ctx
        mesh = ctx.current_mesh()
        if mesh is not None and "model" in mesh.axis_names \
                and w.n_shards == mesh.devices.shape[
                    list(mesh.axis_names).index("model")]:
            return qmm_shard_map(x, w, mesh, dp=ctx.current_dp(),
                                 use_pallas=use_pallas)
        if w.n_shards == 1:
            # Single-shard serving stack: dispatch through kernels.ops so
            # the block_m plan (skinny-XLA / decode-width / column-strip)
            # applies; multi-shard-without-mesh keeps the sharded oracle.
            return kops.qmm(x, w.local(0), use_pallas=use_pallas)
        return qmm_sharded_ref(x, w)
    if isinstance(w, QTensor):
        return kops.qmm(x, w, use_pallas=use_pallas)
    w = _gather_weight_for_use(x, w, tp_dim)
    return jnp.matmul(x, w.astype(x.dtype))


def _gather_weight_for_use(x: jax.Array, w, tp_dim: int) -> jax.Array:
    """ZeRO-3 weight gathering (§Perf): FSDP shards every large weight's

    non-TP dim over `data`; at use time the cheap move is to all-gather the
    weight (MBs) — left alone, GSPMD instead computes partial products over
    the sharded contraction dim and all-reduces [tokens, features] f32
    activations (GBs). Pin the gathered layout for sequence compute
    (train/prefill); decode (seq==1) keeps fully-sharded weights."""
    from repro import runtime_context as rctx
    mesh = rctx.current_mesh()
    if mesh is None or getattr(w, "ndim", 0) != 2 or x.ndim < 3 \
            or x.shape[-2] <= 1 or "model" not in mesh.axis_names:
        return w
    tp = mesh.devices.shape[list(mesh.axis_names).index("model")]
    from jax.sharding import NamedSharding, PartitionSpec as P
    if tp_dim == 0 and w.shape[0] % tp == 0:
        spec = P("model", None)
    elif tp_dim == 1 and w.shape[1] % tp == 0:
        spec = P(None, "model")
    else:
        return w
    return jax.lax.with_sharding_constraint(w, NamedSharding(mesh, spec))


def linear(x: jax.Array, w, b: Optional[jax.Array] = None,
           use_pallas: bool = False, tp_dim: int = 1) -> jax.Array:
    y = matmul_any(x, w, use_pallas=use_pallas, tp_dim=tp_dim)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def rotary_cos_sin(positions: jax.Array, dim: int, theta: float,
                   dtype=jnp.float32):
    """positions [..., S] -> (cos, sin) of shape [..., S, dim//2]."""
    inv_freq = 1.0 / (theta ** (jnp.arange(0, dim, 2,
                                           dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rotary(x: jax.Array, cos: jax.Array, sin: jax.Array,
                 rotary_pct: float = 1.0) -> jax.Array:
    """x [B, S, H, D]; cos/sin [B, S, D_rot//2]. Partial rotary supported."""
    d = x.shape[-1]
    d_rot = int(d * rotary_pct) // 2 * 2
    xr, xp = x[..., :d_rot], x[..., d_rot:]
    x1, x2 = jnp.split(xr, 2, axis=-1)
    c = cos[..., None, : d_rot // 2]
    s = sin[..., None, : d_rot // 2]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


def embed_tokens(tokens: jax.Array, table: jax.Array,
                 scale: bool = False) -> jax.Array:
    x = jnp.take(table, tokens, axis=0)
    if scale:
        x = x * jnp.asarray(table.shape[1] ** 0.5, dtype=x.dtype)
    return x


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token NLL in fp32. logits [B,S,V], labels [B,S] int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1).squeeze(-1)
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def glu_mlp(x: jax.Array, p: dict, act: str = "silu", gated: bool = True,
            use_pallas: bool = False, tap=None) -> jax.Array:
    actf = {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[act]
    if tap:
        tap("w_up", x)
    if gated:
        h = actf(linear(x, p["w_gate"], use_pallas=use_pallas)) \
            * linear(x, p["w_up"], use_pallas=use_pallas)
    else:
        h = actf(linear(x, p["w_up"], use_pallas=use_pallas))
    if tap:
        tap("w_down", h)
    return linear(h, p["w_down"], use_pallas=use_pallas, tp_dim=0)
