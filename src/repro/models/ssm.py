"""Mamba2 — state-space duality (SSD) mixer, chunked scan + decode recurrence.

Training/prefill uses the chunked SSD algorithm (arXiv:2405.21060 §6):
quadratic attention-like compute inside fixed-size chunks, linear state
passing across chunks. Decode is the O(1) recurrence on the [B,H,P,N] state.
All einsums; chunk scan via lax.scan so HLO size is depth-independent.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import linear, rms_norm

CHUNK = 256


def _segsum(a: jax.Array) -> jax.Array:
    """a [..., L] -> M[..., i, j] = sum_{j < k <= i} a_k  (lower-tri)."""
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    ltri = jnp.tril(jnp.ones(a.shape[-1:] * 2, dtype=bool), k=0)
    return jnp.where(ltri, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, a_dt: jax.Array, b_mat: jax.Array,
                c_mat: jax.Array, h0: Optional[jax.Array],
                chunk: int = CHUNK) -> Tuple[jax.Array, jax.Array]:
    """SSD scan.

    x     [B,L,H,P]  (inputs already scaled by dt)
    a_dt  [B,L,H]    (dt * A, negative)
    b/c   [B,L,G,N]  (G groups broadcast over heads)
    h0    [B,H,P,N]  initial state or None
    Returns (y [B,L,H,P], h_final [B,H,P,N]).
    """
    bsz, l, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    assert l % chunk == 0, (l, chunk)
    c = l // chunk
    hg = h // g

    def rs(t):  # [B,L,...] -> [C,B,chunk,...] (scan axis first)
        return t.reshape(bsz, c, chunk, *t.shape[2:]).swapaxes(0, 1)

    xc, ac = rs(x), rs(a_dt)
    bc, cc = rs(b_mat), rs(c_mat)

    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)

    def chunk_step(h_prev, inp):
        xk, ak, bk, ck = inp                    # [B,chunk,...]
        acs = jnp.cumsum(ak, axis=1)            # [B,chunk,H]
        # intra-chunk (diagonal block): attention-like
        lmat = jnp.exp(_segsum(ak.swapaxes(1, 2)))        # [B,H,chunk,chunk]
        ckh = jnp.repeat(ck, hg, axis=2)        # [B,chunk,H,N]
        bkh = jnp.repeat(bk, hg, axis=2)
        scores = jnp.einsum("blhn,bshn->bhls", ckh.astype(jnp.float32),
                            bkh.astype(jnp.float32))
        y_diag = jnp.einsum("bhls,bshp->blhp", scores * lmat,
                            xk.astype(jnp.float32))
        # contribution of the incoming state
        y_off = jnp.einsum("blhn,bhpn,blh->blhp", ckh.astype(jnp.float32),
                           h_prev, jnp.exp(acs))
        # chunk state update
        a_tot = acs[:, -1]                      # [B,H]
        decay = jnp.exp(a_tot[:, None] - acs)   # [B,chunk,H]
        h_new = jnp.einsum("blhn,blh,blhp->bhpn", bkh.astype(jnp.float32),
                           decay, xk.astype(jnp.float32))
        h_next = h_prev * jnp.exp(a_tot)[:, :, None, None] + h_new
        return h_next, (y_diag + y_off).astype(x.dtype)

    h_final, ys = jax.lax.scan(chunk_step, h0, (xc, ac, bc, cc))
    y = ys.swapaxes(0, 1).reshape(bsz, l, h, p)
    return y, h_final


def _causal_conv(x: jax.Array, w: jax.Array, cache: Optional[jax.Array],
                 valid_len: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Depthwise causal conv1d. x [B,L,C]; w [C,K]; cache [B,K-1,C].

    ``valid_len [B]`` marks the true sequence end inside a right-padded
    prefill bucket: the returned cache then holds the K-1 inputs *ending at
    the last valid token* (input index t sits at xin row t + K-1, so rows
    valid_len..valid_len+K-2 are exactly x[valid_len-K+1 : valid_len], with
    the pre-sequence zeros appearing naturally when valid_len < K-1)."""
    bsz, l, ch = x.shape
    k = w.shape[1]
    if cache is None:
        xin = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
        new_cache = None
    else:
        xin = jnp.concatenate([cache.astype(x.dtype), x], axis=1)
        if valid_len is None:
            new_cache = xin[:, -(k - 1):, :]
        else:
            idx = valid_len[:, None] + jnp.arange(k - 1)[None, :]
            new_cache = jnp.take_along_axis(xin, idx[..., None], axis=1)
    out = jax.lax.conv_general_dilated(
        xin, w.T[:, None, :].astype(x.dtype),    # [K,1,C] kernel
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=ch)
    return out, new_cache


def mamba_block(p: dict, x: jax.Array, cfg, *,
                cache: Optional[dict] = None,
                valid_len: Optional[jax.Array] = None,
                tap=None, use_pallas: bool = False
                ) -> Tuple[jax.Array, Optional[dict]]:
    """Mamba2 mixer. cache = {'ssm': [B,H,P,N], 'conv': [B,K-1,convdim]}.

    ``valid_len [B]``: count of valid columns in THIS input window (the
    serving step passes chunks at arbitrary absolute positions;
    ``blocks.apply_block`` converts its absolute bound to this count).
    Unlike attention, the recurrence is not causally immune to right
    padding, so pad positions get dt=0 / x=0 — the same state-neutral
    values the internal chunk padding uses — the conv cache is gathered
    at the true window end, and a fully-padded lane (``valid_len == 0``,
    a lane idling in a mixed serving round) leaves both states
    untouched, including through the s == 1 decode recurrence."""
    bsz, s, _ = x.shape
    di, hd = cfg.d_inner, cfg.ssm_headdim
    nh, g, n = cfg.ssm_nheads, cfg.ssm_ngroups, cfg.d_state

    if tap:
        tap("in_proj", x)
    zxbcdt = linear(x, p["in_proj"], use_pallas=use_pallas)
    z, xbc, dt = jnp.split(zxbcdt, [di, di + cfg.conv_dim], axis=-1)

    conv_cache = cache.get("conv") if cache else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], conv_cache,
                                 valid_len=valid_len)
    xbc = jax.nn.silu(xbc)
    xs, b_mat, c_mat = jnp.split(xbc, [di, di + g * n], axis=-1)

    xh = xs.reshape(bsz, s, nh, hd)
    b_mat = b_mat.reshape(bsz, s, g, n)
    c_mat = c_mat.reshape(bsz, s, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # [B,S,H]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))              # [H]

    h0 = cache.get("ssm") if cache else None
    if s == 1 and cache is not None:
        # O(1) decode recurrence
        da = jnp.exp(dt[:, 0] * a[None, :])                   # [B,H]
        bh = jnp.repeat(b_mat[:, 0], nh // g, axis=1)         # [B,H,N]
        bx = jnp.einsum("bhp,bhn->bhpn",
                        (xh[:, 0] * dt[:, 0, :, None]).astype(jnp.float32),
                        bh.astype(jnp.float32))
        h_new = h0 * da[:, :, None, None] + bx
        ch = jnp.repeat(c_mat[:, 0], nh // g, axis=1)         # [B,H,N]
        y = jnp.einsum("bhpn,bhn->bhp", h_new, ch.astype(jnp.float32))
        y = y[:, None].astype(x.dtype)                        # [B,1,H,P]
        h_final = h_new
        if valid_len is not None:
            # a lane idling in a mixed serving round (0 valid tokens)
            # must not advance its state on the padding token
            vm = (valid_len > 0)[:, None, None, None]
            h_final = jnp.where(vm, h_new, h0)
    else:
        if valid_len is not None:
            vm = (jnp.arange(s)[None, :] < valid_len[:, None])    # [B,S]
            dt = dt * vm[..., None]
            xh = xh * vm[:, :, None, None].astype(xh.dtype)
        chunk = CHUNK if s >= CHUNK else max(8, 1 << (s - 1).bit_length())
        pad = (-s) % chunk
        if pad:
            xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
            c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y, h_final = ssd_chunked(xh * dt[..., None].astype(xh.dtype),
                                 dt * a[None, None, :], b_mat, c_mat,
                                 h0, chunk=chunk)
        y = y[:, :s].astype(x.dtype)

    y = y + xh[:, :s] * p["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(bsz, s, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    if tap:
        tap("out_proj", y)
    out = linear(y, p["out_proj"], use_pallas=use_pallas, tp_dim=0)

    new_cache = None
    if cache is not None:
        new_cache = {"ssm": h_final, "conv": new_conv}
    return out, new_cache
