"""Unified model configuration covering all assigned architecture families.

A model is a stack of `n_groups` identical *groups*; each group applies the
block kinds in `pattern` in order (so gemma2's local/global alternation is
pattern=("attn_local", "attn") and jamba's 1:7 attn:mamba interleave is
pattern=("attn", "mamba" * 7)). lax.scan runs over groups, keeping HLO size
independent of depth.

Block kinds
-----------
attn          global self-attention mixer (+ FFN if d_ff > 0)
attn_local    sliding-window self-attention mixer (+ FFN)
mamba         Mamba2 SSD mixer (+ FFN if d_ff > 0)
hybrid        parallel attn + SSM heads, outputs fused (Hymba-style)
hybrid_local  same with sliding-window attention
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # stacking
    pattern: Tuple[str, ...] = ("attn",)
    moe_pattern: Tuple[bool, ...] = (False,)   # per pattern slot: MoE FFN?

    # attention
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0
    window: int = 4096               # sliding window for *_local
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    scale_embed: bool = False        # gemma-style sqrt(d) embedding scale

    # MoE
    n_experts: int = 0
    topk: int = 0
    capacity_factor: float = 1.25

    # SSM (Mamba2)
    d_state: int = 0
    ssm_headdim: int = 64
    expand: int = 2
    d_conv: int = 4
    ssm_ngroups: int = 1

    # encoder-decoder (whisper): n_layers counts DECODER layers
    is_encdec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500              # stub audio frontend sequence length

    # vlm stub frontend
    n_vis_tokens: int = 0

    # misc
    norm_eps: float = 1e-5
    act: str = "silu"
    gated_mlp: bool = True
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    qkv_bias: bool = False

    # quantized serving: "fp16" | "qtensor"
    serve_weights: str = "fp16"

    # ---- beyond-paper performance options (see EXPERIMENTS.md §Perf) ----
    chunked_ce: bool = False      # vocab-chunked fused lm_head + CE loss
    ce_chunk: int = 16384
    chunked_attn: bool = False    # KV-chunked online-softmax attention
    attn_chunk: int = 1024
    kv_cache_quant: bool = False  # int8 KV cache (decode bandwidth)
    remat_policy: str = "full"    # "full" | "dots" (save matmul outputs)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.n_heads, 1))
        if self.n_layers % len(self.pattern):
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"pattern length {len(self.pattern)}")
        if len(self.moe_pattern) not in (1, len(self.pattern)):
            raise ValueError("moe_pattern must match pattern length (or 1)")

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def moe_slots(self) -> Tuple[bool, ...]:
        if len(self.moe_pattern) == 1:
            return self.moe_pattern * len(self.pattern)
        return self.moe_pattern

    @property
    def d_inner(self) -> int:        # mamba inner width
        return self.expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.ssm_ngroups * self.d_state

    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def has_kind(self, kind_prefix: str) -> bool:
        return any(k.startswith(kind_prefix) for k in self.pattern)

    @property
    def is_subquadratic(self) -> bool:
        """True if no block performs *global* attention (long_500k rule)."""
        return not any(k in ("attn", "hybrid") for k in self.pattern)

    def param_count(self) -> int:
        """Analytic parameter count (used by memsys + roofline MODEL_FLOPS)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        n = v * d                                   # embedding
        if not self.tie_embeddings:
            n += d * v                              # lm head
        per_kind = {}
        attn_p = d * self.attn_dim + 2 * d * self.kv_dim \
            + self.attn_dim * d + d
        mlp_p = ((3 if self.gated_mlp else 2) * d * ff + d) if ff else 0
        moe_p = (d * self.n_experts
                 + self.n_experts * (3 if self.gated_mlp else 2) * d * ff
                 + d) if self.n_experts else 0
        ssm_p = (d * (2 * self.d_inner + 2 * self.ssm_ngroups * self.d_state
                      + self.ssm_nheads)
                 + self.conv_dim * self.d_conv
                 + 3 * self.ssm_nheads + self.d_inner
                 + self.d_inner * d + d)
        for slot, kind in enumerate(self.pattern):
            p = 0
            if kind.startswith("attn"):
                p += attn_p
            elif kind == "mamba":
                p += ssm_p
            elif kind.startswith("hybrid"):
                p += attn_p + ssm_p
            if kind != "mamba" or ff:
                p += moe_p if self.moe_slots[slot] else mlp_p
            per_kind[slot] = p
        n += self.n_groups * sum(per_kind.values())
        if self.is_encdec:
            enc_p = attn_p + mlp_p
            cross_p = attn_p
            n += self.n_enc_layers * enc_p + self.n_layers * cross_p
        n += d  # final norm
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if not self.n_experts:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        dense_expert = (3 if self.gated_mlp else 2) * d * ff
        n_moe_slots = sum(1 for s in self.moe_slots if s) * self.n_groups
        inactive = n_moe_slots * (self.n_experts - self.topk) * dense_expert
        return self.param_count() - inactive
