"""Block-level init/apply: one transformer "layer" of any supported kind.

A block = pre-norm mixer (attention / mamba / parallel-hybrid) + residual,
then (if the config has an FFN) pre-norm FFN (dense MLP or MoE) + residual.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import attn_block
from repro.models.layers import glu_mlp, rms_norm
from repro.models.moe import moe_block
from repro.models.ssm import mamba_block


def _init_linear(key, din, dout, scale=None, dtype=jnp.float32):
    std = scale if scale is not None else (1.0 / math.sqrt(din))
    return (jax.random.normal(key, (din, dout)) * std).astype(dtype)


def init_attn_params(key, cfg, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p = {
        "wq": _init_linear(ks[0], d, cfg.attn_dim, dtype=dtype),
        "wk": _init_linear(ks[1], d, cfg.kv_dim, dtype=dtype),
        "wv": _init_linear(ks[2], d, cfg.kv_dim, dtype=dtype),
        "wo": _init_linear(ks[3], cfg.attn_dim, d,
                           scale=1.0 / math.sqrt(cfg.attn_dim
                                                 * 2 * cfg.n_layers),
                           dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.attn_dim,), dtype)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dtype)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dtype)
    return p


def init_mlp_params(key, cfg, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    d, ff = cfg.d_model, cfg.d_ff
    p = {"w_up": _init_linear(ks[1], d, ff, dtype=dtype),
         "w_down": _init_linear(ks[2], ff, d,
                                scale=1.0 / math.sqrt(ff * 2 * cfg.n_layers),
                                dtype=dtype)}
    if cfg.gated_mlp:
        p["w_gate"] = _init_linear(ks[0], d, ff, dtype=dtype)
    return p


def init_moe_params(key, cfg, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    std_in = 1.0 / math.sqrt(d)
    std_out = 1.0 / math.sqrt(ff * 2 * cfg.n_layers)
    p = {
        "router": _init_linear(ks[0], d, e, scale=0.02, dtype=jnp.float32),
        "w_up": (jax.random.normal(ks[1], (e, d, ff)) * std_in
                 ).astype(dtype),
        "w_down": (jax.random.normal(ks[2], (e, ff, d)) * std_out
                   ).astype(dtype),
    }
    if cfg.gated_mlp:
        p["w_gate"] = (jax.random.normal(ks[3], (e, d, ff)) * std_in
                       ).astype(dtype)
    return p


def init_mamba_params(key, cfg, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    d, di = cfg.d_model, cfg.d_inner
    nh = cfg.ssm_nheads
    in_dim = 2 * di + 2 * cfg.ssm_ngroups * cfg.d_state + nh
    dt = jnp.exp(jax.random.uniform(ks[2], (nh,)) *
                 (math.log(0.1) - math.log(0.001)) + math.log(0.001))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))   # inverse softplus
    return {
        "in_proj": _init_linear(ks[0], d, in_dim, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_dim, cfg.d_conv))
                   / math.sqrt(cfg.d_conv)).astype(dtype),
        "a_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm_scale": jnp.zeros((di,), jnp.float32),
        "out_proj": _init_linear(ks[3], di, d,
                                 scale=1.0 / math.sqrt(di * 2 * cfg.n_layers),
                                 dtype=dtype),
    }


def init_block(key, kind: str, use_moe: bool, cfg, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    p = {"norm1": jnp.zeros((cfg.d_model,), jnp.float32)}
    if kind.startswith("attn"):
        p["attn"] = init_attn_params(ks[0], cfg, dtype)
    elif kind == "mamba":
        p["mamba"] = init_mamba_params(ks[0], cfg, dtype)
    elif kind.startswith("hybrid"):
        p["attn"] = init_attn_params(ks[0], cfg, dtype)
        p["mamba"] = init_mamba_params(ks[1], cfg, dtype)
    else:
        raise ValueError(kind)
    if cfg.d_ff > 0:
        p["norm2"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["ffn"] = (init_moe_params(ks[2], cfg, dtype) if use_moe
                    else init_mlp_params(ks[2], cfg, dtype))
    return p


def apply_block(p: dict, x: jax.Array, kind: str, use_moe: bool, cfg, *,
                positions: jax.Array,
                cache: Optional[dict] = None,
                pos: Optional[jax.Array] = None,
                valid_len: Optional[jax.Array] = None,
                tap=None, use_pallas: bool = False,
                paged_attention: bool = False
                ) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    """Returns (x_out, new_cache, moe_aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    new_cache = {}
    window = cfg.window if kind.endswith("_local") else None
    # attention takes valid_len as an ABSOLUTE position bound (chunked
    # prefill runs at positions start..start+S-1); the SSM recurrence
    # wants the count of valid columns in THIS input window
    ssm_valid = (valid_len - positions[:, 0]
                 if valid_len is not None else None)

    if kind.startswith("attn"):
        mix, ac = attn_block(p["attn"], h, cfg, positions=positions,
                             window=window,
                             cache=cache.get("attn") if cache else None,
                             pos=pos, valid_len=valid_len,
                             tap=_sub(tap, "attn"),
                             use_pallas=use_pallas,
                             paged_attention=paged_attention)
        if ac is not None:
            new_cache["attn"] = ac
    elif kind == "mamba":
        mix, mc = mamba_block(p["mamba"], h, cfg,
                              cache=cache.get("mamba") if cache else None,
                              valid_len=ssm_valid,
                              tap=_sub(tap, "mamba"), use_pallas=use_pallas)
        if mc is not None:
            new_cache["mamba"] = mc
    elif kind.startswith("hybrid"):
        mix_a, ac = attn_block(p["attn"], h, cfg, positions=positions,
                               window=window,
                               cache=cache.get("attn") if cache else None,
                               pos=pos, valid_len=valid_len,
                               tap=_sub(tap, "attn"),
                               use_pallas=use_pallas,
                               paged_attention=paged_attention)
        mix_m, mc = mamba_block(p["mamba"], h, cfg,
                                cache=cache.get("mamba") if cache else None,
                                valid_len=ssm_valid,
                                tap=_sub(tap, "mamba"),
                                use_pallas=use_pallas)
        mix = 0.5 * (mix_a + mix_m)
        if ac is not None:
            new_cache["attn"] = ac
        if mc is not None:
            new_cache["mamba"] = mc
    else:
        raise ValueError(kind)
    x = x + mix

    if "ffn" in p:
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if use_moe:
            y, aux = moe_block(p["ffn"], h, cfg, tap=_sub(tap, "moe"),
                               use_pallas=use_pallas)
        else:
            y = glu_mlp(h, p["ffn"], cfg.act, cfg.gated_mlp,
                        use_pallas=use_pallas, tap=_sub(tap, "ffn"))
        x = x + y
    return x, (new_cache or None), aux


def _sub(tap, prefix):
    if tap is None:
        return None

    def inner(name, value):
        tap(f"{prefix}/{name}", value)
    return inner
