"""Grouped-query attention with sliding-window, softcap, cache and cross-attn.

One implementation serves training (full causal), prefill (same, but also
returns the KV cache) and decode (single query over a fixed-size cache with
a validity length mask). Everything is einsum-based so GSPMD can shard heads
on the `model` mesh axis and batch on `data`.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import runtime_context as rctx
from repro.launch.mesh import axis_size as _mesh_axis
from repro.models.layers import apply_rotary, linear, rotary_cos_sin, softcap


def _constrain_pages(x: jax.Array) -> jax.Array:
    """Pin an arena page leaf [n_pages, page, last] to the serving
    sharding contract (pages over ``data``, fused kv/scale dim over
    ``model``) under the runtime mesh — keeps the scatter's output
    sharded instead of letting GSPMD replicate the whole pool."""
    mesh = rctx.current_mesh()
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    d_n, m_n = _mesh_axis(mesh, "data"), _mesh_axis(mesh, "model")
    p_ax = "data" if (d_n > 1 and x.shape[0] % d_n == 0) else None
    k_ax = "model" if (m_n > 1 and x.shape[-1] % m_n == 0) else None
    if p_ax is None and k_ax is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(p_ax, None, k_ax)))


def _constrain_heads(x: jax.Array) -> jax.Array:
    """Pin gathered K/V [B, T, KV, hd] to head sharding on ``model`` so
    the attention einsums run TP-local after the cross-shard page
    gather."""
    mesh = rctx.current_mesh()
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    m_n = _mesh_axis(mesh, "model")
    if m_n <= 1 or x.shape[2] % m_n != 0:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(None, None, "model", None)))


def _expand_gqa(q: jax.Array, n_kv: int) -> jax.Array:
    """q [B,S,H,D] -> [B,S,KV,G,D] with H = KV*G."""
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def attend(q: jax.Array, k: jax.Array, v: jax.Array, *,
           q_positions: jax.Array, kv_positions: jax.Array,
           kv_valid_len: Optional[jax.Array] = None,
           causal: bool = True, window: Optional[int] = None,
           attn_softcap: Optional[float] = None) -> jax.Array:
    """q [B,S,H,D]; k,v [B,T,KV,D]; positions are absolute token indices.

    Returns [B,S,H,D]. The mask combines causality, optional sliding window
    and cache validity (for decode where T is the max cache size).
    """
    b, s, h, d = q.shape
    t = k.shape[1]
    n_kv = k.shape[2]
    qg = _expand_gqa(q, n_kv)                              # [B,S,KV,G,D]
    scale = jnp.asarray(d ** -0.5, q.dtype)

    # f32 accumulation WITHOUT materializing f32 copies of K (the K cache
    # is the dominant byte stream at decode time — §Perf iteration 1)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg * scale, k,
                        preferred_element_type=jnp.float32)
    scores = softcap(scores, attn_softcap)

    pq = q_positions[:, None, None, :, None]               # [B,1,1,S,1]
    pk = kv_positions[:, None, None, None, :]              # [B,1,1,1,T]
    mask = jnp.ones((b, 1, 1, s, t), dtype=bool)
    if causal:
        mask &= pk <= pq
    if window is not None:
        mask &= pq - pk < window
    if kv_valid_len is not None:
        valid = kv_positions < kv_valid_len[:, None]
        mask &= valid[:, None, None, None, :]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out.reshape(b, s, h, d)


def attend_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   q_positions: jax.Array, kv_positions: jax.Array,
                   causal: bool = True, window: Optional[int] = None,
                   attn_softcap: Optional[float] = None,
                   chunk: int = 1024) -> jax.Array:
    """Flash-style online-softmax attention: lax.scan over KV chunks.

    Beyond-paper optimization for the train/prefill memory roofline term:
    scores are never materialized at [S, T], only [S, chunk] per step, and
    the scan body is rematerialized in the backward pass (jax.checkpoint)
    so residuals stay O(S * D) instead of O(S * T).
    """
    b, s, h, d = q.shape
    t = k.shape[1]
    n_kv = k.shape[2]
    if t % chunk:
        pad = chunk - t % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)),
                               constant_values=2 ** 30)
        t += pad
    n_chunks = t // chunk
    qg = _expand_gqa(q, n_kv) * jnp.asarray(d ** -0.5, q.dtype)
    ks = (k.reshape(b, n_chunks, chunk, n_kv, d).swapaxes(0, 1))
    vs = (v.reshape(b, n_chunks, chunk, n_kv, d).swapaxes(0, 1))
    ps = (kv_positions.reshape(b, n_chunks, chunk).swapaxes(0, 1))
    pq = q_positions[:, None, None, :, None]            # [B,1,1,S,1]

    def body(carry, inp):
        m, l, acc = carry
        k_c, v_c, p_c = inp
        scores = jnp.einsum("bskgd,btkd->bkgst", qg, k_c,
                            preferred_element_type=jnp.float32)
        scores = softcap(scores, attn_softcap)
        pk = p_c[:, None, None, None, :]
        mask = jnp.ones_like(scores, dtype=bool)
        if causal:
            mask &= pk <= pq
        if window is not None:
            mask &= pq - pk < window
        mask &= pk < 2 ** 30
        scores = jnp.where(mask, scores, -1e30)
        cm = jnp.max(scores, axis=-1)                    # [B,KV,G,S]
        m_new = jnp.maximum(m, cm)
        p = jnp.exp(scores - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p.astype(v_c.dtype), v_c,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    init = (jnp.full((b, n_kv, h // n_kv, s), -1e30),
            jnp.zeros((b, n_kv, h // n_kv, s)),
            jnp.zeros((b, n_kv, h // n_kv, s, d)))
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), init, (ks, vs, ps))
    out = acc / jnp.maximum(l, 1e-30)[..., None]         # [B,KV,G,S,D]
    return (out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, d)
            .astype(q.dtype))


def attn_block(p: dict, x: jax.Array, cfg, *,
               positions: jax.Array,
               window: Optional[int],
               cache: Optional[dict] = None,
               pos: Optional[jax.Array] = None,
               valid_len: Optional[jax.Array] = None,
               tap=None, use_pallas: bool = False,
               paged_attention: bool = False
               ) -> Tuple[jax.Array, Optional[dict]]:
    """Self-attention mixer. cache={'k','v'} [B,T,KV,D] (decode/prefill).

    ``valid_len`` [B] (absolute position bound, prompt start + true length)
    tightens the cache-validity mask when the input is right-padded to a
    bucket, and routes paged writes of padding garbage to the null page —
    required for suffix prefill at a nonzero start position, where padding
    columns would otherwise scatter into the slot's live pages.

    ``paged_attention=True`` routes EVERY paged step — single-token
    decode, chunked prefill, and mixed rounds — through the ragged Pallas
    page-table kernel (``kernels/paged_attention.py``), which streams only
    causally-live pages instead of materializing the full block-table
    width. The XLA gather below survives only as the differential oracle
    and the fallback for geometries the kernel cannot shard."""
    b, s, d_model = x.shape
    hd, nh, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    if tap:
        tap("wq", x)
    q = linear(x, p["wq"], p.get("bq"), use_pallas).reshape(b, s, nh, hd)
    k = linear(x, p["wk"], p.get("bk"), use_pallas).reshape(b, s, nkv, hd)
    v = linear(x, p["wv"], p.get("bv"), use_pallas).reshape(b, s, nkv, hd)

    cos, sin = rotary_cos_sin(positions, int(hd * cfg.rotary_pct) // 2 * 2,
                              cfg.rope_theta, dtype=jnp.float32)
    q = apply_rotary(q, cos, sin, cfg.rotary_pct)
    k = apply_rotary(k, cos, sin, cfg.rotary_pct)

    new_cache = None
    if cache is None:                                      # training
        kv_pos = positions
        k_all, v_all, valid = k, v, None
        if cfg.chunked_attn and s > cfg.attn_chunk:
            out = attend_chunked(q, k, v, q_positions=positions,
                                 kv_positions=kv_pos, causal=True,
                                 window=window,
                                 attn_softcap=cfg.attn_softcap,
                                 chunk=cfg.attn_chunk)
            if tap:
                tap("wo", out.reshape(b, s, nh * hd))
            return linear(out.reshape(b, s, nh * hd), p["wo"],
                          p.get("bo"), use_pallas, tp_dim=0), None
    elif "k_pages" in cache:                 # paged decode / prefill chunk
        new_cache = paged_cache_write(cache, k, v, positions,
                                      valid_len=valid_len)
        valid = (valid_len if valid_len is not None
                 else positions[:, -1] + 1)
        if paged_attention:
            from repro.kernels.paged_attention import (
                ragged_paged_attention, shard_compatible)
            mesh = rctx.current_mesh()
            if shard_compatible(mesh, cache["k_pages"].shape[0], nkv):
                out = ragged_paged_attention(
                    q, new_cache, positions[:, 0], valid, n_kv=nkv,
                    head_dim=hd, window=window,
                    attn_softcap=cfg.attn_softcap, mesh=mesh)
                if tap:
                    tap("wo", out.reshape(b, s, nh * hd))
                return linear(out.reshape(b, s, nh * hd), p["wo"],
                              p.get("bo"), use_pallas, tp_dim=0), new_cache
        k_all, v_all = paged_cache_read(new_cache, x.dtype, nkv, hd)
        t_max = k_all.shape[1]
        kv_pos = jnp.broadcast_to(jnp.arange(t_max)[None, :], (b, t_max))
    else:
        t_max = cache["k"].shape[1]
        pos0 = 0 if s > 1 else (pos if pos is not None
                                else positions[0, 0])
        new_cache = _cache_write(cache, k, v, pos0)
        k_all, v_all = _cache_read(new_cache, x.dtype, nkv, hd)
        kv_pos = jnp.broadcast_to(jnp.arange(t_max)[None, :], (b, t_max))
        valid = (valid_len if valid_len is not None
                 else positions[:, -1] + 1)

    out = attend(q, k_all if cache is not None else k,
                 v_all if cache is not None else v,
                 q_positions=positions, kv_positions=kv_pos,
                 kv_valid_len=valid, causal=True, window=window,
                 attn_softcap=cfg.attn_softcap)
    if tap:
        tap("wo", out.reshape(b, s, nh * hd))
    y = linear(out.reshape(b, s, nh * hd), p["wo"], p.get("bo"),
               use_pallas, tp_dim=0)
    return y, new_cache


def _cache_write(cache: dict, k: jax.Array, v: jax.Array, pos0) -> dict:
    """Insert new K/V at pos0 (cache layout is flat [B, T, KV*hd]);

    quantizes to int8 when the cache is int8."""
    b, s, n_kv, hd = k.shape
    if "k_scale" in cache:
        from repro.models.kvcache import quantize_kv
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        kq = kq.reshape(b, s, n_kv * hd)
        vq = vq.reshape(b, s, n_kv * hd)
        return {
            "k": jax.lax.dynamic_update_slice(cache["k"], kq,
                                              (0, pos0, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], vq,
                                              (0, pos0, 0)),
            "k_scale": jax.lax.dynamic_update_slice(cache["k_scale"], ks,
                                                    (0, pos0, 0)),
            "v_scale": jax.lax.dynamic_update_slice(cache["v_scale"], vs,
                                                    (0, pos0, 0)),
        }
    return {
        "k": jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype).reshape(b, s, n_kv * hd),
            (0, pos0, 0)),
        "v": jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype).reshape(b, s, n_kv * hd),
            (0, pos0, 0)),
    }


def _cache_read(cache: dict, dtype, n_kv: int, hd: int):
    b, t, _ = cache["k"].shape
    k = cache["k"].reshape(b, t, n_kv, hd)
    v = cache["v"].reshape(b, t, n_kv, hd)
    if "k_scale" in cache:
        k = k.astype(dtype) * cache["k_scale"][..., None].astype(dtype)
        v = v.astype(dtype) * cache["v_scale"][..., None].astype(dtype)
    return k, v


def paged_cache_write(cache: dict, k: jax.Array, v: jax.Array,
                      positions: jax.Array,
                      valid_len: Optional[jax.Array] = None) -> dict:
    """Scatter K/V tokens into the paged arena (decode AND suffix prefill).

    cache holds ``k_pages/v_pages [n_pages, page, kv_dim]`` plus
    ``block_tbl [B, max_pages]``; ``positions [B, S]`` are absolute write
    positions (S == 1 for decode, S == the suffix bucket for prefill).
    Inactive decode lanes carry an all-null block table and land on the
    reserved null page 0, which no live table maps. ``valid_len`` [B]
    additionally routes right-padding columns (positions >= valid_len) to
    the null page — without it a padded suffix bucket could index past the
    slot's live pages and, after clipping, corrupt them."""
    b, s, n_kv, hd = k.shape
    page = cache["k_pages"].shape[1]
    tbl = cache["block_tbl"]
    blk = jnp.clip(positions // page, 0, tbl.shape[1] - 1)       # [B, S]
    page_idx = jnp.take_along_axis(tbl, blk, axis=1)             # [B, S]
    if valid_len is not None:
        page_idx = jnp.where(positions < valid_len[:, None], page_idx, 0)
    off = positions % page
    new = dict(cache)
    if "k_scale_pages" in cache:
        from repro.models.kvcache import quantize_kv
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        new["k_pages"] = _constrain_pages(
            cache["k_pages"].at[page_idx, off].set(
                kq.reshape(b, s, n_kv * hd)))
        new["v_pages"] = _constrain_pages(
            cache["v_pages"].at[page_idx, off].set(
                vq.reshape(b, s, n_kv * hd)))
        new["k_scale_pages"] = _constrain_pages(
            cache["k_scale_pages"].at[page_idx, off].set(
                ks.reshape(b, s, n_kv)))
        new["v_scale_pages"] = _constrain_pages(
            cache["v_scale_pages"].at[page_idx, off].set(
                vs.reshape(b, s, n_kv)))
        return new
    dt = cache["k_pages"].dtype
    new["k_pages"] = _constrain_pages(
        cache["k_pages"].at[page_idx, off].set(
            k.astype(dt).reshape(b, s, n_kv * hd)))
    new["v_pages"] = _constrain_pages(
        cache["v_pages"].at[page_idx, off].set(
            v.astype(dt).reshape(b, s, n_kv * hd)))
    return new


def paged_cache_read(cache: dict, dtype, n_kv: int, hd: int):
    """Gather each sequence's pages into logical token order.

    Returns k, v of shape ``[B, max_pages*page, n_kv, hd]``; entries past
    the sequence's valid length are garbage and masked by ``kv_valid_len``
    in ``attend``. Note this XLA reference gather materializes the FULL
    block-table width (null-page repeats included) — the
    ``kv_traffic_paged(live_only=False)`` stream; the Pallas kernel
    (``kernels/paged_attention.py``, ``paged_attention=True``) streams
    only live pages, the ``live_only=True`` traffic the DSE charges."""
    tbl = cache["block_tbl"]                              # [B, P]
    b, p = tbl.shape
    page = cache["k_pages"].shape[1]
    k = cache["k_pages"][tbl].reshape(b, p * page, n_kv, hd)
    v = cache["v_pages"][tbl].reshape(b, p * page, n_kv, hd)
    if "k_scale_pages" in cache:
        ks = cache["k_scale_pages"][tbl].reshape(b, p * page, n_kv)
        vs = cache["v_scale_pages"][tbl].reshape(b, p * page, n_kv)
        k = k.astype(dtype) * ks[..., None].astype(dtype)
        v = v.astype(dtype) * vs[..., None].astype(dtype)
    return _constrain_heads(k), _constrain_heads(v)


def cross_attn_block(p: dict, x: jax.Array, enc_kv: dict, cfg, *,
                     tap=None, use_pallas: bool = False) -> jax.Array:
    """Cross-attention (whisper decoder): K/V precomputed from the encoder."""
    b, s, _ = x.shape
    hd, nh = cfg.head_dim, cfg.n_heads
    if tap:
        tap("wq", x)
    q = linear(x, p["wq"], p.get("bq"), use_pallas).reshape(b, s, nh, hd)
    k, v = enc_kv["xk"], enc_kv["xv"]                      # [B,T,KV,D]
    t = k.shape[1]
    pos_q = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    pos_k = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    out = attend(q, k, v, q_positions=pos_q, kv_positions=pos_k,
                 causal=False, window=None,
                 attn_softcap=cfg.attn_softcap)
    if tap:
        tap("wo", out.reshape(b, s, nh * hd))
    return linear(out.reshape(b, s, nh * hd), p["wo"], p.get("bo"),
                  use_pallas, tp_dim=0)


def precompute_cross_kv(p: dict, enc_out: jax.Array, cfg,
                        use_pallas: bool = False) -> dict:
    b, t, _ = enc_out.shape
    hd, nkv = cfg.head_dim, cfg.n_kv_heads
    k = linear(enc_out, p["wk"], p.get("bk"), use_pallas
               ).reshape(b, t, nkv, hd)
    v = linear(enc_out, p["wv"], p.get("bv"), use_pallas
               ).reshape(b, t, nkv, hd)
    return {"xk": k, "xv": v}
