"""KV / SSM cache construction for every block kind (stacked over groups)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _attn_cache(cfg, batch: int, max_len: int, dtype):
    """K/V caches are stored FLAT [B, T, kv_dim]: a flat 16-way sharding of

    kv_dim is GSPMD-reshapeable into the nested (KV x head_dim) sharding the
    attention einsums want, even when n_kv_heads < the TP width (GQA-8 on
    TP-16 would otherwise replicate the cache — §Perf cell B)."""
    kvd = cfg.n_kv_heads * cfg.head_dim
    if getattr(cfg, "kv_cache_quant", False):
        # int8 cache + per-(token, head) scales: ~2x decode KV bandwidth
        return {"k": jnp.zeros((batch, max_len, kvd), jnp.int8),
                "v": jnp.zeros((batch, max_len, kvd), jnp.int8),
                "k_scale": jnp.zeros((batch, max_len, cfg.n_kv_heads),
                                     jnp.bfloat16),
                "v_scale": jnp.zeros((batch, max_len, cfg.n_kv_heads),
                                     jnp.bfloat16)}
    return {"k": jnp.zeros((batch, max_len, kvd), dtype),
            "v": jnp.zeros((batch, max_len, kvd), dtype)}


def _mamba_cache(cfg, batch: int, dtype):
    return {"ssm": jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_headdim,
                              cfg.d_state), jnp.float32),
            "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.conv_dim), dtype)}


def block_cache(cfg, kind: str, batch: int, max_len: int, dtype):
    c = {}
    if kind.startswith("attn") or kind.startswith("hybrid"):
        c["attn"] = _attn_cache(cfg, batch, max_len, dtype)
    if kind == "mamba" or kind.startswith("hybrid"):
        c["mamba"] = _mamba_cache(cfg, batch, dtype)
    return c


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Stacked cache pytree: leaves have leading n_groups dim."""
    group = {f"b{i}": block_cache(cfg, kind, batch, max_len, dtype)
             for i, kind in enumerate(cfg.pattern)}
    return jax.tree_util.tree_map(
        lambda l: jnp.broadcast_to(l[None], (cfg.n_groups,) + l.shape),
        group)


def init_encdec_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Decoder cache: self-attn KV + cross-attn KV (filled at prefill)."""
    group = {"self": _attn_cache(cfg, batch, max_len, dtype),
             "cross": {"xk": jnp.zeros((batch, cfg.enc_seq, cfg.n_kv_heads,
                                        cfg.head_dim), dtype),
                       "xv": jnp.zeros((batch, cfg.enc_seq, cfg.n_kv_heads,
                                        cfg.head_dim), dtype)}}
    return jax.tree_util.tree_map(
        lambda l: jnp.broadcast_to(l[None], (cfg.n_layers,) + l.shape),
        group)


def cache_bytes(cache) -> int:
    return sum(l.size * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(cache))
