"""KV / SSM cache construction for every block kind (stacked over groups).

Two attention-cache layouts share one quantization scheme:

  * contiguous ``[B, T, kv_dim]`` — one slab per sequence, used by training,
    prefill, and the legacy per-slot decode path.
  * paged ``[n_pages, page, kv_dim]`` — a shared arena of fixed-size pages
    addressed through per-sequence block tables (``serve/paged_kv.py``).
    The page is the unit of both allocation and DRAM streaming: with the
    default 16-token page and an int8 cache, one page per KV head group is a
    multiple of the 64-byte LPDDR5 burst the memsys model charges per access
    (``memsys/devices.py``), so the paged gather never pays for a partial
    burst.

``quantize_kv`` is the single int8 code path — both layouts store identical
codes/scales, which is what makes paged-vs-contiguous decode token-identical
under ``kv_cache_quant``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _attn_cache(cfg, batch: int, max_len: int, dtype):
    """K/V caches are stored FLAT [B, T, kv_dim]: a flat 16-way sharding of

    kv_dim is GSPMD-reshapeable into the nested (KV x head_dim) sharding the
    attention einsums want, even when n_kv_heads < the TP width (GQA-8 on
    TP-16 would otherwise replicate the cache — §Perf cell B)."""
    kvd = cfg.n_kv_heads * cfg.head_dim
    if getattr(cfg, "kv_cache_quant", False):
        # int8 cache + per-(token, head) scales: ~2x decode KV bandwidth
        return {"k": jnp.zeros((batch, max_len, kvd), jnp.int8),
                "v": jnp.zeros((batch, max_len, kvd), jnp.int8),
                "k_scale": jnp.zeros((batch, max_len, cfg.n_kv_heads),
                                     jnp.bfloat16),
                "v_scale": jnp.zeros((batch, max_len, cfg.n_kv_heads),
                                     jnp.bfloat16)}
    return {"k": jnp.zeros((batch, max_len, kvd), dtype),
            "v": jnp.zeros((batch, max_len, kvd), dtype)}


def quantize_kv(x: jax.Array):
    """Per-(token, head) symmetric int8: x [..., n_kv, hd] ->

    (codes int8 [..., n_kv, hd], scale bf16 [..., n_kv]). Shared by the
    contiguous and paged write paths so both layouts hold identical bits."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0 + 1e-8
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                     -127, 127)
    return codes.astype(jnp.int8), scale.astype(jnp.bfloat16)


def paged_attn_cache(cfg, n_pages: int, page: int, max_slots: int,
                     max_pages_per_seq: int, dtype):
    """Paged K/V arena + block table (one group's share of the pool).

    Arena leaves are ``[n_pages, page, kv_dim]`` (pages are shared across
    layers only in *index space* — every group owns its own arena rows, but
    page id j means tokens [j*page, (j+1)*page) of the owning sequence in
    every group, so one block table serves the whole stack, vLLM-style).
    Page 0 is reserved as the null page: inactive decode lanes scatter their
    garbage K/V there and it is never mapped into a live block table."""
    kvd = cfg.n_kv_heads * cfg.head_dim
    c = {"block_tbl": jnp.zeros((max_slots, max_pages_per_seq), jnp.int32)}
    if getattr(cfg, "kv_cache_quant", False):
        c.update({
            "k_pages": jnp.zeros((n_pages, page, kvd), jnp.int8),
            "v_pages": jnp.zeros((n_pages, page, kvd), jnp.int8),
            "k_scale_pages": jnp.zeros((n_pages, page, cfg.n_kv_heads),
                                       jnp.bfloat16),
            "v_scale_pages": jnp.zeros((n_pages, page, cfg.n_kv_heads),
                                       jnp.bfloat16)})
        return c
    c.update({"k_pages": jnp.zeros((n_pages, page, kvd), dtype),
              "v_pages": jnp.zeros((n_pages, page, kvd), dtype)})
    return c


def paged_block_cache(cfg, kind: str, n_pages: int, page: int,
                      max_slots: int, max_pages_per_seq: int, dtype):
    """Like block_cache, but attention K/V live in the paged arena; SSM /

    conv state stays dense per-slot (it is O(1) in sequence length)."""
    c = {}
    if kind.startswith("attn") or kind.startswith("hybrid"):
        c["attn"] = paged_attn_cache(cfg, n_pages, page, max_slots,
                                     max_pages_per_seq, dtype)
    if kind == "mamba" or kind.startswith("hybrid"):
        c["mamba"] = _mamba_cache(cfg, max_slots, dtype)
    return c


def paged_init_cache(cfg, n_pages: int, page: int, max_slots: int,
                     max_pages_per_seq: int, dtype=jnp.bfloat16):
    """Stacked paged-pool pytree: leaves have leading n_groups dim."""
    group = {f"b{i}": paged_block_cache(cfg, kind, n_pages, page, max_slots,
                                        max_pages_per_seq, dtype)
             for i, kind in enumerate(cfg.pattern)}
    return jax.tree_util.tree_map(
        lambda l: jnp.broadcast_to(l[None], (cfg.n_groups,) + l.shape),
        group)


def _mamba_cache(cfg, batch: int, dtype):
    return {"ssm": jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_headdim,
                              cfg.d_state), jnp.float32),
            "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.conv_dim), dtype)}


def block_cache(cfg, kind: str, batch: int, max_len: int, dtype):
    c = {}
    if kind.startswith("attn") or kind.startswith("hybrid"):
        c["attn"] = _attn_cache(cfg, batch, max_len, dtype)
    if kind == "mamba" or kind.startswith("hybrid"):
        c["mamba"] = _mamba_cache(cfg, batch, dtype)
    return c


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Stacked cache pytree: leaves have leading n_groups dim."""
    group = {f"b{i}": block_cache(cfg, kind, batch, max_len, dtype)
             for i, kind in enumerate(cfg.pattern)}
    return jax.tree_util.tree_map(
        lambda l: jnp.broadcast_to(l[None], (cfg.n_groups,) + l.shape),
        group)


def init_encdec_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Decoder cache: self-attn KV + cross-attn KV (filled at prefill)."""
    group = {"self": _attn_cache(cfg, batch, max_len, dtype),
             "cross": {"xk": jnp.zeros((batch, cfg.enc_seq, cfg.n_kv_heads,
                                        cfg.head_dim), dtype),
                       "xv": jnp.zeros((batch, cfg.enc_seq, cfg.n_kv_heads,
                                        cfg.head_dim), dtype)}}
    return jax.tree_util.tree_map(
        lambda l: jnp.broadcast_to(l[None], (cfg.n_layers,) + l.shape),
        group)


def cache_bytes(cache) -> int:
    return sum(l.size * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(cache))
