"""Mixture-of-Experts FFN with sort-based capacity dispatch.

FLOP-proportional dispatch (no dense all-experts compute): tokens are
argsorted by assigned expert, scattered into an [E, C, d] buffer (capacity
C = tokens * topk / E * capacity_factor), processed by a grouped einsum whose
FLOPs equal active-expert FLOPs, and combined back with router gates.
Tokens beyond an expert's capacity are dropped (standard GShard semantics);
an auxiliary load-balancing loss keeps the router near-uniform.

Sharding: experts live on the `model` mesh axis, tokens on `data`; GSPMD
inserts the all-to-alls at the scatter/gather boundaries.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import linear


def moe_block(p: dict, x: jax.Array, cfg, *, tap=None,
              use_pallas: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x [B,S,d] -> (y [B,S,d], aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.topk
    cap = int(math.ceil(t * k / e * cfg.capacity_factor))
    cap = max(cap, k)

    xf = x.reshape(t, d)
    if tap:
        tap("router", xf)
    logits = linear(xf, p["router"]).astype(jnp.float32)   # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)        # [T, k]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # ---- load-balancing auxiliary loss (Switch-style) -------------------
    me = jnp.mean(probs, axis=0)                           # [E]
    ce = jnp.mean(
        jax.nn.one_hot(expert_ids[:, 0], e, dtype=jnp.float32), axis=0)
    aux = jnp.sum(me * ce) * e

    # ---- sort-based dispatch -------------------------------------------
    flat_expert = expert_ids.reshape(-1)                   # [T*k]
    flat_token = jnp.repeat(jnp.arange(t), k)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)
    se, st, sg = flat_expert[order], flat_token[order], flat_gate[order]
    # position within expert group
    starts = jnp.searchsorted(se, jnp.arange(e), side="left")
    seg_pos = jnp.arange(t * k) - starts[se]
    keep = seg_pos < cap

    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[se, jnp.where(keep, seg_pos, cap - 1)].add(
        jnp.where(keep[:, None], xf[st], 0).astype(x.dtype),
        mode="drop")

    # ---- grouped expert FFN (FLOPs = E * C * d * ff terms) --------------
    # Serving with QMC weights: expert streams are QTensor stacks (fields
    # carry a leading E dim, sharded on `model`); dequantize on the fly.
    def _w(name):
        wp = p[name]
        from repro.core.qtensor import QTensor, dequantize_qtensor
        if isinstance(wp, QTensor):
            return jax.vmap(lambda q: dequantize_qtensor(q, x.dtype))(wp)
        return wp.astype(x.dtype)

    if cfg.gated_mlp:
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, _w("w_gate"))) \
            * jnp.einsum("ecd,edf->ecf", buf, _w("w_up"))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, _w("w_up")))
    y_buf = jnp.einsum("ecf,efd->ecd", h, _w("w_down"))

    # ---- combine back ----------------------------------------------------
    gathered = y_buf[se, jnp.where(keep, seg_pos, 0)]      # [T*k, d]
    contrib = jnp.where(keep[:, None], gathered
                        * sg[:, None].astype(x.dtype), 0)
    y = jnp.zeros((t, d), x.dtype).at[st].add(contrib)
    return y.reshape(b, s, d), aux.astype(jnp.float32)
