"""Top-level model API: init_params / forward / train_loss / prefill / decode.

One code path serves every assigned architecture; the config's `pattern`
(block kinds per group) plus family flags (encdec, vlm) select behaviour.
Layer groups run under lax.scan (HLO size independent of depth) unless
`scan_layers=False` (used for calibration taps and tiny smoke tests).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models import kvcache as KV
from repro.models.attention import (attn_block, cross_attn_block,
                                    precompute_cross_kv)
from repro.models.config import ModelConfig
from repro.models.layers import (cross_entropy_loss, embed_tokens, glu_mlp,
                                 linear, rms_norm, softcap)

MOE_AUX_WEIGHT = 0.01


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def init_params(cfg: ModelConfig, key: jax.Array,
                dtype=jnp.float32) -> Dict[str, Any]:
    keys = jax.random.split(key, 8)
    d = cfg.d_model
    params: Dict[str, Any] = {
        "embed": {"tok": (jax.random.normal(keys[0], (cfg.vocab, d))
                          * 0.02).astype(dtype)},
        "final_norm": jnp.zeros((d,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(keys[1], (d, cfg.vocab))
                             * (d ** -0.5)).astype(dtype)

    # decoder blocks, stacked over groups
    def one_group(gkey):
        ks = jax.random.split(gkey, len(cfg.pattern))
        return {f"b{i}": B.init_block(ks[i], kind, cfg.moe_slots[i], cfg,
                                      dtype)
                for i, kind in enumerate(cfg.pattern)}

    gkeys = jax.random.split(keys[2], cfg.n_groups)
    groups = [one_group(gk) for gk in gkeys]
    params["blocks"] = jax.tree_util.tree_map(
        lambda *ls: jnp.stack(ls), *groups)

    if cfg.is_encdec:
        def enc_layer(k):
            ks = jax.random.split(k, 2)
            return {"norm1": jnp.zeros((d,), jnp.float32),
                    "attn": B.init_attn_params(ks[0], cfg, dtype),
                    "norm2": jnp.zeros((d,), jnp.float32),
                    "ffn": B.init_mlp_params(ks[1], cfg, dtype)}

        def dec_xattn(k):
            return {"norm_x": jnp.zeros((d,), jnp.float32),
                    "xattn": B.init_attn_params(k, cfg, dtype)}

        ekeys = jax.random.split(keys[3], cfg.n_enc_layers)
        params["encoder"] = {
            "blocks": jax.tree_util.tree_map(
                lambda *ls: jnp.stack(ls), *[enc_layer(k) for k in ekeys]),
            "pos_emb": (jax.random.normal(keys[4], (cfg.enc_seq, d))
                        * 0.02).astype(dtype),
            "final_norm": jnp.zeros((d,), jnp.float32),
        }
        xkeys = jax.random.split(keys[5], cfg.n_layers)
        params["xattn"] = jax.tree_util.tree_map(
            lambda *ls: jnp.stack(ls), *[dec_xattn(k) for k in xkeys])
    return params


# --------------------------------------------------------------------------
# group application (one scan step)
# --------------------------------------------------------------------------
def _apply_group(cfg: ModelConfig, grp_params, x, grp_cache, positions, pos,
                 xattn_params=None, enc_kv=None, valid_len=None, tap=None,
                 use_pallas: bool = False, paged_attention: bool = False):
    aux_total = jnp.zeros((), jnp.float32)
    new_cache = {}
    for i, kind in enumerate(cfg.pattern):
        bp = grp_params[f"b{i}"]
        bc = grp_cache[f"b{i}"] if grp_cache is not None else None
        if xattn_params is not None:
            # encdec decoder: cross-attention between self-attn and FFN
            h = rms_norm(x, bp["norm1"], cfg.norm_eps)
            mix, ac = attn_block(bp["attn"], h, cfg, positions=positions,
                                 window=None,
                                 cache=bc.get("self") if bc else None,
                                 pos=pos, use_pallas=use_pallas)
            x = x + mix
            hx = rms_norm(x, xattn_params["norm_x"], cfg.norm_eps)
            kv = enc_kv if enc_kv is not None else bc["cross"]
            x = x + cross_attn_block(xattn_params["xattn"], hx, kv, cfg,
                                     use_pallas=use_pallas)
            h2 = rms_norm(x, bp["norm2"], cfg.norm_eps)
            x = x + glu_mlp(h2, bp["ffn"], cfg.act, cfg.gated_mlp,
                            use_pallas=use_pallas)
            nc = {}
            if ac is not None:
                nc["self"] = ac
                nc["cross"] = kv
            new_cache[f"b{i}"] = nc or None
        else:
            x, nc, aux = B.apply_block(
                bp, x, kind, cfg.moe_slots[i], cfg, positions=positions,
                cache=bc, pos=pos, valid_len=valid_len,
                tap=_tap_prefix(tap, f"b{i}"), use_pallas=use_pallas,
                paged_attention=paged_attention)
            new_cache[f"b{i}"] = nc
            aux_total = aux_total + aux
    any_cache = any(v is not None for v in new_cache.values())
    return x, (new_cache if any_cache else None), aux_total


def _tap_prefix(taps, prefix):
    if taps is None:
        return None

    def inner(name, value):
        taps(f"{prefix}/{name}", value)
    return inner


# --------------------------------------------------------------------------
# encoder (whisper)
# --------------------------------------------------------------------------
def encode(cfg: ModelConfig, params, frames: jax.Array,
           use_pallas: bool = False, scan_layers: bool = True) -> jax.Array:
    enc = params["encoder"]
    x = frames + enc["pos_emb"][None, : frames.shape[1]].astype(frames.dtype)
    b, t, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))

    def layer_fn(x, lp):
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        q = linear(h, lp["attn"]["wq"], use_pallas=use_pallas
                   ).reshape(b, t, cfg.n_heads, cfg.head_dim)
        k = linear(h, lp["attn"]["wk"], use_pallas=use_pallas
                   ).reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
        v = linear(h, lp["attn"]["wv"], use_pallas=use_pallas
                   ).reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
        from repro.models.attention import attend
        o = attend(q, k, v, q_positions=positions, kv_positions=positions,
                   causal=False, window=None)
        x = x + linear(o.reshape(b, t, -1), lp["attn"]["wo"],
                       use_pallas=use_pallas, tp_dim=0)
        h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
        return x + glu_mlp(h2, lp["ffn"], cfg.act, cfg.gated_mlp,
                           use_pallas=use_pallas)

    if scan_layers:
        def body(carry, lp):
            return layer_fn(carry, lp), None
        x, _ = jax.lax.scan(body, x, enc["blocks"])
    else:
        n = jax.tree_util.tree_leaves(enc["blocks"])[0].shape[0]
        for i in range(n):
            lp = jax.tree_util.tree_map(lambda l: l[i], enc["blocks"])
            x = layer_fn(x, lp)
    return rms_norm(x, enc["final_norm"], cfg.norm_eps)


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------
def forward(cfg: ModelConfig, params, tokens: jax.Array, *,
            positions: Optional[jax.Array] = None,
            cache=None, pos: Optional[jax.Array] = None,
            vis_embeds: Optional[jax.Array] = None,
            frames: Optional[jax.Array] = None,
            enc_out: Optional[jax.Array] = None,
            valid_len: Optional[jax.Array] = None,
            taps: Optional[dict] = None,
            use_pallas: bool = False, scan_layers: bool = True,
            remat: bool = False, skip_head: bool = False,
            paged_attention: bool = False
            ) -> Tuple[jax.Array, Any, jax.Array]:
    """Returns (logits [B,S_text,V], new_cache, moe_aux).

    skip_head=True returns the final-norm hidden states instead of logits
    (the chunked-CE loss fuses the lm_head into the loss).
    valid_len [B]: true lengths when tokens are right-padded to a prefill
    bucket — attention is causally immune to right padding, but the SSM
    recurrence needs it to keep its carried state clean."""
    b, s = tokens.shape
    x = embed_tokens(tokens, params["embed"]["tok"], cfg.scale_embed)

    n_vis = 0
    if cfg.n_vis_tokens and vis_embeds is not None:
        n_vis = vis_embeds.shape[1]
        x = jnp.concatenate([vis_embeds.astype(x.dtype), x], axis=1)

    total = x.shape[1]
    if positions is None:
        if pos is not None:  # decode step
            pos = jnp.asarray(pos, jnp.int32)
            positions = (jnp.full((b, total), pos, jnp.int32)
                         if pos.ndim == 0 else pos[:, None])
        else:
            positions = jnp.broadcast_to(jnp.arange(total)[None],
                                         (b, total))

    enc_kv_all = None
    if cfg.is_encdec:
        if enc_out is None and frames is not None:
            enc_out = encode(cfg, params, frames, use_pallas, scan_layers)
        if enc_out is not None:
            # per-layer cross KV, stacked: computed functionally inside scan
            pass

    grp = functools.partial(_apply_group, cfg, valid_len=valid_len,
                            use_pallas=use_pallas,
                            paged_attention=paged_attention)
    if remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots" else None)
        grp = jax.checkpoint(grp, policy=policy)

    aux_total = jnp.zeros((), jnp.float32)
    if cfg.is_encdec:
        # decoder layers are NOT grouped (pattern=("attn",)); scan over
        # layers with per-layer cross-attn params.
        xattn = params["xattn"]

        def dec_body(carry, inp):
            xx, aux = carry
            lp, xp, lc = inp
            kv = (precompute_cross_kv(xp["xattn"], enc_out, cfg, use_pallas)
                  if enc_out is not None else None)
            xx, nc, a = _apply_group(cfg, lp, xx, lc, positions, pos,
                                     xattn_params=xp, enc_kv=kv,
                                     use_pallas=use_pallas)
            return (xx, aux + a), nc

        blocks = params["blocks"]
        if scan_layers and taps is None:
            (x, aux_total), new_cache = jax.lax.scan(
                dec_body, (x, aux_total), (blocks, xattn, cache))
        else:
            ncs = []
            n = cfg.n_groups
            for i in range(n):
                lp = jax.tree_util.tree_map(lambda l: l[i], blocks)
                xp = jax.tree_util.tree_map(lambda l: l[i], xattn)
                lc = (jax.tree_util.tree_map(lambda l: l[i], cache)
                      if cache is not None else None)
                (x, aux_total), nc = dec_body((x, aux_total), (lp, xp, lc))
                ncs.append(nc)
            new_cache = (jax.tree_util.tree_map(lambda *ls: jnp.stack(ls),
                                                *ncs)
                         if ncs and ncs[0] is not None else None)
    else:
        def body(carry, inp):
            xx, aux = carry
            lp, lc = inp
            xx, nc, a = grp(lp, xx, lc, positions, pos)
            return (xx, aux + a), nc

        if scan_layers and taps is None:
            (x, aux_total), new_cache = jax.lax.scan(
                body, (x, aux_total), (params["blocks"], cache))
        else:
            ncs = []
            for i in range(cfg.n_groups):
                lp = jax.tree_util.tree_map(lambda l: l[i], params["blocks"])
                lc = (jax.tree_util.tree_map(lambda l: l[i], cache)
                      if cache is not None else None)
                x, nc, a = _apply_group(
                    cfg, lp, x, lc, positions, pos, valid_len=valid_len,
                    tap=_make_tap(taps, i), use_pallas=use_pallas,
                    paged_attention=paged_attention)
                aux_total = aux_total + a
                ncs.append(nc)
            new_cache = (jax.tree_util.tree_map(lambda *ls: jnp.stack(ls),
                                                *ncs)
                         if ncs and ncs[0] is not None else None)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if n_vis:
        x = x[:, n_vis:]
    if skip_head:
        return x, new_cache, aux_total
    if cfg.tie_embeddings:
        logits = jnp.matmul(x, params["embed"]["tok"].T.astype(x.dtype))
    else:
        logits = linear(x, params["lm_head"], use_pallas=use_pallas)
    logits = softcap(logits, cfg.logit_softcap)
    return logits, new_cache, aux_total


def _make_tap(taps, layer_idx):
    if taps is None:
        return None

    def inner(name, value):
        key = f"blocks/{layer_idx}/{name}"
        prev = taps.get(key)
        v = value.reshape(-1, value.shape[-1])
        # subsample calibration rows to bound memory
        if v.shape[0] > 512:
            v = v[:: v.shape[0] // 512][:512]
        taps[key] = v if prev is None else jnp.concatenate([prev, v])
    return inner


# --------------------------------------------------------------------------
# public steps
# --------------------------------------------------------------------------
def train_loss(cfg: ModelConfig, params, batch: Dict[str, jax.Array], *,
               use_pallas: bool = False, scan_layers: bool = True,
               remat: bool = True) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    if cfg.chunked_ce and batch.get("loss_mask") is None:
        from repro.models.chunked_ce import sharded_ce_loss
        hidden, _, aux = forward(
            cfg, params, batch["tokens"],
            vis_embeds=batch.get("vis_embeds"),
            frames=batch.get("frames"), use_pallas=use_pallas,
            scan_layers=scan_layers, remat=remat, skip_head=True)
        w_head = (params["embed"]["tok"].T if cfg.tie_embeddings
                  else params["lm_head"])
        loss = sharded_ce_loss(hidden, w_head, batch["labels"],
                               logit_softcap=cfg.logit_softcap)
    else:
        logits, _, aux = forward(
            cfg, params, batch["tokens"],
            vis_embeds=batch.get("vis_embeds"), frames=batch.get("frames"),
            use_pallas=use_pallas, scan_layers=scan_layers, remat=remat)
        loss = cross_entropy_loss(logits, batch["labels"],
                                  batch.get("loss_mask"))
    total = loss + MOE_AUX_WEIGHT * aux
    return total, {"loss": loss, "aux": aux}


def prefill(cfg: ModelConfig, params, tokens: jax.Array, *,
            max_len: int, cache_dtype=jnp.bfloat16,
            vis_embeds: Optional[jax.Array] = None,
            frames: Optional[jax.Array] = None,
            use_pallas: bool = False, scan_layers: bool = True):
    """Full forward over the prompt; returns (last_logits, cache)."""
    b = tokens.shape[0]
    if cfg.is_encdec:
        cache = KV.init_encdec_cache(cfg, b, max_len, cache_dtype)
        enc_out = encode(cfg, params, frames, use_pallas, scan_layers)
        logits, new_cache, _ = forward(
            cfg, params, tokens, cache=_encdec_cache_names(cache),
            enc_out=enc_out, use_pallas=use_pallas, scan_layers=scan_layers)
    else:
        cache = KV.init_cache(cfg, b, max_len, cache_dtype)
        logits, new_cache, _ = forward(
            cfg, params, tokens, cache=cache, vis_embeds=vis_embeds,
            use_pallas=use_pallas, scan_layers=scan_layers)
    return logits[:, -1], new_cache


def _encdec_cache_names(cache):
    # encdec caches are stored as {"self":..., "cross":...} per layer but the
    # scan body expects {"b0": {...}}
    return {"b0": cache}


def decode_step(cfg: ModelConfig, params, token: jax.Array, cache,
                pos: jax.Array, *, use_pallas: bool = False,
                scan_layers: bool = True, paged_attention: bool = False):
    """One token step. token [B,1]; pos scalar int32 (current position).

    ``paged_attention=True``: paged caches attend through the Pallas
    page-table kernel instead of the full-width XLA gather."""
    logits, new_cache, _ = forward(
        cfg, params, token, cache=cache, pos=pos,
        use_pallas=use_pallas, scan_layers=scan_layers,
        paged_attention=paged_attention)
    return logits[:, -1], new_cache
