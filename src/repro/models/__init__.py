"""Model zoo: one configurable stack covering all assigned architectures."""
from repro.models.config import ModelConfig
from repro.models.model import (decode_step, forward, init_params, prefill,
                                train_loss)
from repro.models.kvcache import cache_bytes, init_cache, init_encdec_cache

__all__ = ["ModelConfig", "decode_step", "forward", "init_params", "prefill",
           "train_loss", "cache_bytes", "init_cache", "init_encdec_cache"]
