"""Analytic heterogeneous-memory co-design simulator (paper §3.3, §4.2.3)."""
from repro.memsys.devices import (FLASH, LPDDR5, MRAM, RERAM_2B, RERAM_3B,
                                  MemDevice)
from repro.memsys.system import (EvalResult, MemSystemConfig, dse,
                                 evaluate_conventional, evaluate_hetero)
from repro.memsys.workload import Traffic, make_traffic

__all__ = ["FLASH", "LPDDR5", "MRAM", "RERAM_2B", "RERAM_3B", "MemDevice",
           "EvalResult", "MemSystemConfig", "dse", "evaluate_conventional",
           "evaluate_hetero", "Traffic", "make_traffic"]
