"""Heterogeneous memory-system evaluation — paper Eq. (3) latency model,

Eq. (4) power constraint, and the capacity/area accounting of §4.2.3.

A `MemSystem` assigns the traffic streams to devices:

  * conventional (Jetson-class): weights + KV + activations all on LPDDR5
    (bandwidth contention: one shared bus), optional Flash residency.
  * QMC heterogeneous: outliers -> on-chip MRAM (UCIe-capped), inliers ->
    off-chip MLC ReRAM (bus-capped), KV/activations -> LPDDR5, all fetched
    concurrently and merged: T_final = max(T_i) + T_sync.
  * eMEMs homogeneous: all weights in a single NVM + KV on LPDDR5.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

from repro.memsys import devices as dv
from repro.memsys.workload import Traffic

NS = 1e-9
PJ = 1e-12


@dataclasses.dataclass(frozen=True)
class MemSystemConfig:
    """A point in the design space: bandwidth allocation per device."""
    mram_channels: int = 8           # x 36.57 GiB/s, capped by UCIe
    reram_banks: int = 4             # x 32 arrays x 1.8 GiB/s each
    reram_cell_bits: int = 3
    lpddr_channels: int = 1          # x 186.26 GiB/s (Jetson-class: 1)
    t_queue_ns: float = 10.0
    power_budget_w: float = 8.0

    @property
    def mram_bw(self) -> float:      # bytes/s
        raw = dv.MRAM.bandwidth_bytes(self.mram_channels)
        return min(raw, dv.UCIE_BW_GIBS * dv.GiB)

    @property
    def reram_bw(self) -> float:
        per_bank = dv.RERAM_3B.bandwidth_bytes(32)
        bus = dv.RERAM_BUS_GHZ * 1e9 * dv.RERAM_BUS_BYTES
        return min(per_bank * self.reram_banks, 2 * bus)

    @property
    def lpddr_bw(self) -> float:
        return dv.LPDDR5.bandwidth_bytes(self.lpddr_channels)

    @property
    def reram_dev(self) -> dv.MemDevice:
        return dv.RERAM_3B if self.reram_cell_bits == 3 else dv.RERAM_2B


@dataclasses.dataclass
class EvalResult:
    name: str
    latency_s: float                 # per decode step
    energy_j: float                  # per decode step
    capacity_cells: float            # memory cells for weight residency
    external_bits: float             # off-chip transferred bits per step
    area_mm2: float
    power_w: float                   # sustained memory read power
    feasible: bool

    def row(self):
        return (f"{self.name:14s} lat={self.latency_s*1e3:8.3f}ms "
                f"energy={self.energy_j*1e3:8.3f}mJ "
                f"cells={self.capacity_cells/8/1024**2:9.1f}MB-eq "
                f"ext={self.external_bits/8/1024**2:8.1f}MB "
                f"area={self.area_mm2:7.1f}mm2 "
                f"power={self.power_w:5.2f}W")


def _stream_time(bits: float, bw_bytes: float, t_access_ns: float,
                 t_queue_ns: float) -> float:
    """Eq. (3): T = t_access + s/b + t_queue."""
    if bits <= 0:
        return 0.0
    return t_access_ns * NS + (bits / 8.0) / bw_bytes + t_queue_ns * NS


def evaluate_conventional(traffic: Traffic, sys_cfg: MemSystemConfig,
                          legacy_flash: bool = True) -> EvalResult:
    """Jetson-class baseline: one LPDDR5 bus serves weights + KV + acts."""
    total_bits = (traffic.weight_bits + traffic.kv_bits + traffic.act_bits)
    lat = _stream_time(total_bits, sys_cfg.lpddr_bw,
                       dv.LPDDR5.read_latency_ns, sys_cfg.t_queue_ns)
    energy = total_bits * (dv.LPDDR5.read_energy_pj_per_bit
                           + dv.E_NETWORK_PJ_PER_BIT) * PJ
    cells = traffic.weight_cells_inlier + traffic.weight_cells_outlier
    if legacy_flash and traffic.flash_resident_bits:
        cells += traffic.flash_resident_bits      # Flash copy of the weights
    area = (traffic.dram_resident_bits / 1e6 / 8 * 8
            / dv.LPDDR5.density_mb_per_mm2 / 8) \
        + (traffic.flash_resident_bits / 8e6 / dv.FLASH.density_mb_per_mm2
           if legacy_flash else 0.0)
    power = total_bits / lat * (dv.LPDDR5.read_energy_pj_per_bit
                                + dv.E_NETWORK_PJ_PER_BIT) * PJ \
        if lat > 0 else 0.0
    return EvalResult(traffic.name, lat, energy, cells,
                      external_bits=total_bits, area_mm2=area, power_w=power,
                      feasible=True)


def evaluate_hetero(traffic: Traffic, sys_cfg: MemSystemConfig
                    ) -> EvalResult:
    """QMC / eMEMs: NVM weight streams in parallel with the LPDDR5 KV path.

    T_final = max(T_mram, T_reram, T_lpddr) + T_sync  (Eq. 3)
    P = BW_m (E_m + E_net) + BW_r (E_r + E_net)       (Eq. 4)
    """
    rdev = sys_cfg.reram_dev
    t_m = _stream_time(traffic.weight_bits_outlier, sys_cfg.mram_bw,
                       dv.MRAM.read_latency_ns, sys_cfg.t_queue_ns)
    t_r = _stream_time(traffic.weight_bits_inlier, sys_cfg.reram_bw,
                       rdev.read_latency_ns, sys_cfg.t_queue_ns)
    t_d = _stream_time(traffic.kv_bits + traffic.act_bits, sys_cfg.lpddr_bw,
                       dv.LPDDR5.read_latency_ns, sys_cfg.t_queue_ns)
    lat = max(t_m, t_r, t_d) + dv.T_SYNC_NS * NS

    e_m = traffic.weight_bits_outlier * (dv.MRAM.read_energy_pj_per_bit
                                         + dv.E_NETWORK_PJ_PER_BIT)
    e_r = traffic.weight_bits_inlier * (rdev.read_energy_pj_per_bit
                                        + dv.E_NETWORK_PJ_PER_BIT)
    e_d = (traffic.kv_bits + traffic.act_bits) \
        * (dv.LPDDR5.read_energy_pj_per_bit + dv.E_NETWORK_PJ_PER_BIT)
    energy = (e_m + e_r + e_d) * PJ

    # Eq. (4): sustained power of the two weight streams
    power = (sys_cfg.mram_bw * 8 * (dv.MRAM.read_energy_pj_per_bit
                                    + dv.E_NETWORK_PJ_PER_BIT)
             + sys_cfg.reram_bw * 8 * (rdev.read_energy_pj_per_bit
                                       + dv.E_NETWORK_PJ_PER_BIT)) * PJ
    feasible = power <= sys_cfg.power_budget_w

    cells = traffic.weight_cells_inlier + traffic.weight_cells_outlier
    # area: MRAM cells are 1 bit each; ReRAM density is quoted in logical
    # Mb/mm2 for the given MLC mode (cells * cell_bits = logical bits)
    mram_mb = traffic.weight_cells_outlier / 1e6
    reram_mb = traffic.weight_cells_inlier * rdev.cell_bits / 1e6
    area = mram_mb / dv.MRAM.density_mb_per_mm2 \
        + reram_mb / rdev.density_mb_per_mm2
    external = traffic.weight_bits_inlier + traffic.kv_bits \
        + traffic.act_bits            # MRAM is on-chip -> not external
    return EvalResult(traffic.name, lat, energy, cells, external, area,
                      power, feasible)


def dse(traffic: Traffic, *, cell_bits: int = 3, power_budget_w: float = 8.0,
        t_queue_ns: float = 10.0) -> Optional[MemSystemConfig]:
    """§3.3.3: sweep discrete MRAM/ReRAM bandwidth configs, drop the ones

    violating the power budget, pick the latency-minimal survivor."""
    best, best_lat = None, float("inf")
    for ch, banks in itertools.product(range(1, 15), range(1, 13)):
        cfgp = MemSystemConfig(mram_channels=ch, reram_banks=banks,
                               reram_cell_bits=cell_bits,
                               t_queue_ns=t_queue_ns,
                               power_budget_w=power_budget_w)
        r = evaluate_hetero(traffic, cfgp)
        if not r.feasible:
            continue
        if r.latency_s < best_lat:
            best, best_lat = cfgp, r.latency_s
    return best
