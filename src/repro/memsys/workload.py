"""Decode-step traffic model for a model config + quantization scheme.

LLM decode is read-dominated: every generated token streams all active
weights once, plus the KV cache / SSM state, plus (small) activations.
This module turns a ModelConfig + quant method into a byte/bit traffic
breakdown that the memory-system simulator consumes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.qconfig import MXConfig, QMCConfig
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class Traffic:
    """Per-decode-step traffic (bits) and residency (bits / cells)."""
    name: str
    # streamed per token
    weight_bits_outlier: float       # -> MRAM in QMC
    weight_bits_inlier: float        # -> ReRAM in QMC (or DRAM for baselines)
    kv_bits: float                   # -> LPDDR5 always
    act_bits: float                  # -> LPDDR5 always
    # storage
    weight_cells_inlier: float       # MLC cells (capacity accounting)
    weight_cells_outlier: float
    dram_resident_bits: float        # weights resident in DRAM (baselines)
    flash_resident_bits: float       # legacy hierarchy keeps a Flash copy

    @property
    def weight_bits(self) -> float:
        return self.weight_bits_outlier + self.weight_bits_inlier

    @property
    def total_cells(self) -> float:
        return self.weight_cells_inlier + self.weight_cells_outlier


def pages_for(n_tokens: int, page: int) -> int:
    """Pages needed to hold n_tokens (ceil division, min 1).

    Canonical page-granularity rule, shared by the serving allocator
    (``serve/paged_kv.py``) and this traffic model so the two accounts
    cannot drift."""
    return max(1, -(-int(n_tokens) // page))


def kv_bits_per_step(cfg: ModelConfig, seq_len: int, kv_dtype_bits: int = 16
                     ) -> float:
    """KV cache + SSM state bits read per decode step (batch=1)."""
    n_attn = sum(1 for k in cfg.pattern
                 if k.startswith(("attn", "hybrid"))) * cfg.n_groups
    kv = 2.0 * n_attn * cfg.kv_dim * seq_len * kv_dtype_bits
    n_ssm = sum(1 for k in cfg.pattern
                if k == "mamba" or k.startswith("hybrid")) * cfg.n_groups
    ssm = n_ssm * (cfg.ssm_nheads * cfg.ssm_headdim * cfg.d_state * 32
                   + (cfg.d_conv - 1) * cfg.conv_dim * kv_dtype_bits)
    if cfg.is_encdec:
        kv += 2.0 * cfg.n_layers * cfg.kv_dim * cfg.enc_seq * kv_dtype_bits
    return kv + ssm


def act_bits_per_step(cfg: ModelConfig, act_dtype_bits: int = 16) -> float:
    return 4.0 * cfg.n_layers * cfg.d_model * act_dtype_bits


@dataclasses.dataclass(frozen=True)
class PagedKVTraffic:
    """Batch-dependent KV stream under the paged serving pool.

    A block-table-aware attention kernel streams *whole live pages*, so
    per-step traffic is page-rounded; residency counts allocated pages, so
    pool sizing sees internal fragmentation explicitly. ``exact`` fields
    are the contiguous (unpadded) equivalents for comparison. (The CPU
    reference gather in ``models/attention.py`` reads the full block-table
    width instead — this model describes the target hardware path.)"""
    page: int
    n_seqs: int
    n_pages: int                     # allocated across the batch
    kv_bits_per_step: float          # page-rounded, summed over the batch
    kv_bits_per_step_exact: float    # contiguous equivalent
    resident_bits: float             # pool bytes held by the batch
    resident_bits_exact: float

    @property
    def frag_bits_per_step(self) -> float:
        return self.kv_bits_per_step - self.kv_bits_per_step_exact

    @property
    def utilization(self) -> float:
        """Fraction of allocated pool bits holding live tokens."""
        return (self.resident_bits_exact / self.resident_bits
                if self.resident_bits else 1.0)

    def apply(self, traffic: "Traffic") -> "Traffic":
        """Rebind a single-sequence Traffic to this batch's KV stream —

        the hook that lets the §4 DSE (Eq. 3 latency / Eq. 4 power) score a
        memory system under batched paged serving instead of the paper's
        batch-1 assumption."""
        return dataclasses.replace(
            traffic, name=f"{traffic.name}+paged_b{self.n_seqs}",
            kv_bits=self.kv_bits_per_step)


def kv_traffic_paged(cfg: ModelConfig, seq_lens, *, page: int = 16,
                     kv_dtype_bits: int = 16) -> PagedKVTraffic:
    """KV traffic/residency for a batch of sequences in the paged pool.

    ``seq_lens`` are the current lengths (prompt + generated so far) of the
    active sequences; each contributes ceil(len/page) pages. SSM state (the
    O(1) part of ``kv_bits_per_step``) is per-slot dense and not paged."""
    seq_lens = list(seq_lens)
    n_pages = 0
    bits = bits_exact = 0.0
    for length in seq_lens:
        p = pages_for(length, page)
        n_pages += p
        bits += kv_bits_per_step(cfg, p * page, kv_dtype_bits)
        bits_exact += kv_bits_per_step(cfg, int(length), kv_dtype_bits)
    # residency: decode streams the whole live cache each step, so one
    # step's stream IS the resident KV at these lengths
    return PagedKVTraffic(page=page, n_seqs=len(seq_lens),
                          n_pages=n_pages, kv_bits_per_step=bits,
                          kv_bits_per_step_exact=bits_exact,
                          resident_bits=bits, resident_bits_exact=bits_exact)


def make_traffic(cfg: ModelConfig, method: str, *, seq_len: int = 2048,
                 qmc: QMCConfig = QMCConfig(), mx: MXConfig = MXConfig(),
                 legacy_flash: bool = False) -> Traffic:
    """Traffic for one decode step under a quantization scheme.

    Methods: fp16 | rtn4 | awq | gptq | mx4 -> homogeneous weights in DRAM.
             qmc -> dual-precision split across MRAM/ReRAM.
             emems_mram / emems_reram -> homogeneous INT4 in a single NVM.
    """
    n_active = cfg.active_param_count()
    kv = kv_bits_per_step(cfg, seq_len)
    act = act_bits_per_step(cfg)

    if method in ("fp16", "rtn4", "awq", "gptq", "mx4"):
        bits = {"fp16": 16.0, "rtn4": 4.0, "awq": 4.0, "gptq": 4.0,
                "mx4": mx.avg_bits}[method]
        wbits = n_active * bits
        return Traffic(name=method, weight_bits_outlier=0.0,
                       weight_bits_inlier=wbits, kv_bits=kv, act_bits=act,
                       weight_cells_inlier=cfg.param_count() * bits,
                       weight_cells_outlier=0.0,
                       dram_resident_bits=cfg.param_count() * bits,
                       flash_resident_bits=(cfg.param_count() * bits
                                            if legacy_flash else 0.0))

    if method == "qmc":
        rho = qmc.rho
        out_bits = n_active * rho * qmc.bits_out
        in_bits = n_active * (1 - rho) * qmc.bits_in
        # capacity: inliers live in MLC cells (bits_in / cell_bits cells per
        # weight), outliers in (1-bit) MRAM cells
        in_cells = cfg.param_count() * (1 - rho) * qmc.bits_in \
            / qmc.cell_bits
        out_cells = cfg.param_count() * rho * qmc.bits_out
        return Traffic(name=f"qmc{qmc.cell_bits}b",
                       weight_bits_outlier=out_bits,
                       weight_bits_inlier=in_bits, kv_bits=kv, act_bits=act,
                       weight_cells_inlier=in_cells,
                       weight_cells_outlier=out_cells,
                       dram_resident_bits=0.0, flash_resident_bits=0.0)

    if method in ("emems_mram", "emems_reram"):
        wbits = n_active * 4.0
        if method == "emems_mram":
            return Traffic(name=method, weight_bits_outlier=wbits,
                           weight_bits_inlier=0.0, kv_bits=kv, act_bits=act,
                           weight_cells_inlier=0.0,
                           weight_cells_outlier=cfg.param_count() * 4.0,
                           dram_resident_bits=0.0, flash_resident_bits=0.0)
        return Traffic(name=method, weight_bits_outlier=0.0,
                       weight_bits_inlier=wbits, kv_bits=kv, act_bits=act,
                       weight_cells_inlier=cfg.param_count() * 4.0 / 3.0,
                       weight_cells_outlier=0.0,
                       dram_resident_bits=0.0, flash_resident_bits=0.0)
    raise ValueError(method)
