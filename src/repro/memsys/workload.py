"""Decode-step traffic model for a model config + quantization scheme.

LLM decode is read-dominated: every generated token streams all active
weights once, plus the KV cache / SSM state, plus (small) activations.
This module turns a ModelConfig + quant method into a byte/bit traffic
breakdown that the memory-system simulator consumes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.qconfig import MXConfig, QMCConfig
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class Traffic:
    """Per-decode-step traffic (bits) and residency (bits / cells)."""
    name: str
    # streamed per token
    weight_bits_outlier: float       # -> MRAM in QMC
    weight_bits_inlier: float        # -> ReRAM in QMC (or DRAM for baselines)
    kv_bits: float                   # -> LPDDR5 always
    act_bits: float                  # -> LPDDR5 always
    # storage
    weight_cells_inlier: float       # MLC cells (capacity accounting)
    weight_cells_outlier: float
    dram_resident_bits: float        # weights resident in DRAM (baselines)
    flash_resident_bits: float       # legacy hierarchy keeps a Flash copy

    @property
    def weight_bits(self) -> float:
        return self.weight_bits_outlier + self.weight_bits_inlier

    @property
    def total_cells(self) -> float:
        return self.weight_cells_inlier + self.weight_cells_outlier


def pages_for(n_tokens: int, page: int) -> int:
    """Pages needed to hold n_tokens (ceil division, min 1).

    Canonical page-granularity rule, shared by the serving allocator
    (``serve/paged_kv.py``) and this traffic model so the two accounts
    cannot drift."""
    return max(1, -(-int(n_tokens) // page))


def kv_bits_per_step(cfg: ModelConfig, seq_len: int, kv_dtype_bits: int = 16
                     ) -> float:
    """KV cache + SSM state bits read per decode step (batch=1)."""
    n_attn = sum(1 for k in cfg.pattern
                 if k.startswith(("attn", "hybrid"))) * cfg.n_groups
    kv = 2.0 * n_attn * cfg.kv_dim * seq_len * kv_dtype_bits
    n_ssm = sum(1 for k in cfg.pattern
                if k == "mamba" or k.startswith("hybrid")) * cfg.n_groups
    ssm = n_ssm * (cfg.ssm_nheads * cfg.ssm_headdim * cfg.d_state * 32
                   + (cfg.d_conv - 1) * cfg.conv_dim * kv_dtype_bits)
    if cfg.is_encdec:
        kv += 2.0 * cfg.n_layers * cfg.kv_dim * cfg.enc_seq * kv_dtype_bits
    return kv + ssm


def act_bits_per_step(cfg: ModelConfig, act_dtype_bits: int = 16) -> float:
    return 4.0 * cfg.n_layers * cfg.d_model * act_dtype_bits


@dataclasses.dataclass(frozen=True)
class PagedKVTraffic:
    """Batch-dependent KV stream under the paged serving pool.

    A block-table-aware attention kernel streams *whole live pages*, so
    per-step traffic is page-rounded; residency counts allocated pages, so
    pool sizing sees internal fragmentation explicitly. ``exact`` fields
    are the contiguous (unpadded) equivalents for comparison. The Pallas
    decode kernel (``kernels/paged_attention.py``) gathers exactly the
    ``live_only=True`` stream; ``live_only=False`` models the XLA
    reference gather's full-block-table reads instead."""
    page: int
    n_seqs: int
    n_pages: int                     # allocated across the batch
    kv_bits_per_step: float          # page-rounded, summed over the batch
    kv_bits_per_step_exact: float    # contiguous equivalent
    resident_bits: float             # pool bytes held by the batch
    resident_bits_exact: float

    @property
    def frag_bits_per_step(self) -> float:
        return self.kv_bits_per_step - self.kv_bits_per_step_exact

    @property
    def utilization(self) -> float:
        """Fraction of allocated pool bits holding live tokens."""
        return (self.resident_bits_exact / self.resident_bits
                if self.resident_bits else 1.0)

    def apply(self, traffic: "Traffic") -> "Traffic":
        """Rebind a single-sequence Traffic to this batch's KV stream —

        the hook that lets the §4 DSE (Eq. 3 latency / Eq. 4 power) score a
        memory system under batched paged serving instead of the paper's
        batch-1 assumption."""
        return dataclasses.replace(
            traffic, name=f"{traffic.name}+paged_b{self.n_seqs}",
            kv_bits=self.kv_bits_per_step)


def kv_traffic_paged(cfg: ModelConfig, seq_lens, *, page: int = 16,
                     kv_dtype_bits: int = 16, live_only: bool = True,
                     max_pages_per_seq: Optional[int] = None
                     ) -> PagedKVTraffic:
    """KV traffic/residency for a batch of sequences in the paged pool.

    ``seq_lens`` are the current lengths (prompt + generated so far) of the
    active sequences; each contributes ceil(len/page) pages. SSM state (the
    O(1) part of ``kv_bits_per_step``) is per-slot dense and not paged.

    ``live_only=True`` (default) charges the stream the page-table-aware
    Pallas kernel (``kernels/paged_attention.py``) actually gathers —
    live pages only, byte-for-byte (the DSE-vs-implementation contract
    pinned by ``tests/test_memsys.py``). ``live_only=False`` widens the
    per-step STREAM to the full block-table width (``max_pages_per_seq``
    pages per lane, required then) — what the XLA reference gather in
    ``models/attention.py`` materializes; the gap between the two is the
    dead-page bandwidth the kernel saves. Residency fields
    (``n_pages``/``resident_bits``/``utilization``) always describe the
    live allocation — the gather path never changes what the pool holds.
    """
    seq_lens = list(seq_lens)
    if not live_only and max_pages_per_seq is None:
        raise ValueError("live_only=False (full-width gather) needs "
                         "max_pages_per_seq, the block-table width")
    n_pages = 0
    live_bits = bits = bits_exact = 0.0
    for length in seq_lens:
        p = pages_for(length, page)
        n_pages += p
        live_bits += kv_bits_per_step(cfg, p * page, kv_dtype_bits)
        bits += kv_bits_per_step(
            cfg, (p if live_only else max_pages_per_seq) * page,
            kv_dtype_bits)
        bits_exact += kv_bits_per_step(cfg, int(length), kv_dtype_bits)
    # residency: decode streams the whole live cache each step, so one
    # step's LIVE stream IS the resident KV at these lengths
    return PagedKVTraffic(page=page, n_seqs=len(seq_lens),
                          n_pages=n_pages, kv_bits_per_step=bits,
                          kv_bits_per_step_exact=bits_exact,
                          resident_bits=live_bits,
                          resident_bits_exact=bits_exact)


def chunk_pages_streamed(q_start: int, n_new: int, *, page: int = 16,
                         q_block: int = 16) -> int:
    """Live pages the ragged paged-attention kernel streams for one chunk.

    Host-side mirror of the kernel's BlockSpec index map
    (``kernels/paged_attention.py``): a chunk of ``n_new`` query tokens at
    absolute positions ``q_start + t`` runs as ``ceil(n_new/q_block)`` q
    blocks, and block ``qb`` fetches exactly the pages causally visible
    to it — ``p * page < min(q_start + n_new, q_start + (qb+1)*q_block)``.
    Decode (``n_new == 1``) collapses to ``ceil((q_start+1)/page)``. The
    canonical page-granularity rule for chunk traffic, shared by the
    engine's ``prefill_kv_pages_live`` counter and
    :func:`kv_traffic_chunked` so the two accounts cannot drift."""
    q_start, n_new = int(q_start), int(n_new)
    if n_new <= 0:
        return 0
    kv_len = q_start + n_new
    total = 0
    for qb in range(-(-n_new // q_block)):
        limit = min(kv_len, q_start + (qb + 1) * q_block)
        total += -(-limit // page)
    return total


@dataclasses.dataclass(frozen=True)
class ChunkedPrefillTraffic:
    """KV traffic of one prompt's chunked prefill through the ragged path.

    Chunked prefill scatters each chunk's K/V into the arena (page-rounded
    **writes**) and then attends causally over everything written so far
    (**reads**, streamed per q block by the kernel — the online-softmax
    restream is quadratic in prompt length either way, so chunking leaves
    total read traffic within one chunk-boundary rounding of monolithic;
    what it buys is TTFT/ITL, which the serving benchmark measures). All
    counts are whole pages so the Eq. (3)/(4) DSE charges exactly what the
    engine's ``prefill_kv_pages_live`` / ``prefill_kv_pages_written``
    counters record — pinned page-for-page by ``tests/test_memsys.py``."""
    page: int
    chunk: int
    q_block: int
    n_chunks: int
    kv_pages_read: int               # live pages streamed across chunks
    kv_pages_written: int            # pages touched by chunk K/V writes
    kv_pages_read_monolithic: int    # one-shot (single chunk) equivalent
    kv_read_bits: float
    kv_write_bits: float

    def apply(self, traffic: "Traffic",
              amortize_tokens: int) -> "Traffic":
        """Rebind a Traffic's KV stream for the Eq. (3)/(4) DSE: the
        prefill's page reads+writes are spread over ``amortize_tokens``
        generated tokens and added to the per-step KV bits."""
        kv = traffic.kv_bits + ((self.kv_read_bits + self.kv_write_bits)
                                / amortize_tokens)
        return dataclasses.replace(
            traffic, name=f"{traffic.name}+chunked_c{self.chunk}",
            kv_bits=kv)


def kv_traffic_chunked(cfg: ModelConfig, prompt_len: int, *, chunk: int,
                       page: int = 16, q_block: int = 16,
                       cached_len: int = 0,
                       kv_dtype_bits: int = 16) -> ChunkedPrefillTraffic:
    """KV traffic for prefilling one prompt in fixed-size chunks.

    ``cached_len`` prompt tokens (whole pages) are served from adopted
    prefix-cache pages: they are neither re-written nor re-chunked, but
    later chunks still stream them as causal context. Chunk boundaries
    follow the engine's scheduler: ``cached_len``, ``cached_len+chunk``,
    ... (the last chunk is the remainder)."""
    prompt_len, cached_len = int(prompt_len), int(cached_len)
    if cached_len % page or cached_len > prompt_len:
        raise ValueError(
            f"cached length {cached_len} must be whole pages <= prompt "
            f"{prompt_len}")
    start = min(cached_len, prompt_len - 1) if prompt_len else 0

    def kv_token_bits(n_tokens: int) -> float:
        return (kv_bits_per_step(cfg, n_tokens, kv_dtype_bits)
                - kv_bits_per_step(cfg, 0, kv_dtype_bits))

    def sweep(width: int):
        reads = writes = n_chunks = 0
        s0 = start
        while s0 < prompt_len:
            n = min(width, prompt_len - s0)
            reads += chunk_pages_streamed(s0, n, page=page,
                                          q_block=q_block)
            writes += -(-(s0 + n) // page) - s0 // page
            n_chunks += 1
            s0 += n
        return reads, writes, n_chunks

    reads, writes, n_chunks = sweep(chunk)
    mono_reads, _, _ = sweep(max(prompt_len - start, 1))
    per_page = kv_token_bits(page)
    return ChunkedPrefillTraffic(
        page=page, chunk=chunk, q_block=q_block, n_chunks=n_chunks,
        kv_pages_read=reads, kv_pages_written=writes,
        kv_pages_read_monolithic=mono_reads,
        kv_read_bits=reads * per_page,
        kv_write_bits=writes * per_page)


@dataclasses.dataclass(frozen=True)
class PrefixKVTraffic:
    """Batched KV stream when prompt prefixes are served from cached pages.

    Two effects of the prefix cache (``serve/prefix_cache.py``) reach the
    memory system:

      * **Prefill writes disappear for hit pages** — a cached page is
        adopted by block-table aliasing, so its KV is never recomputed and
        never re-written over LPDDR5. ``prefill_write_bits`` charges only
        the uncached suffix pages (page-rounded, matching the pool's
        allocation granule); ``_nocache`` is the same batch without the
        cache.
      * **Residency dedups shared pages** — one physical page serves every
        sequence that aliases it, so the pool holds
        ``private + unique-shared`` pages, not the sum of per-sequence
        footprints.

    Decode *reads* do not change: every sequence still streams its whole
    mapped table each step (shared pages are re-read per sequence), so
    ``kv_bits_per_step`` equals the plain paged model's."""
    page: int
    n_seqs: int
    n_pages: int                      # physical pages held (dedup'd)
    n_pages_nocache: int              # sum of per-seq footprints
    hit_rate: float                   # cached / total prompt tokens
    prefill_write_bits: float         # KV written during prefill, with cache
    prefill_write_bits_nocache: float
    kv_bits_per_step: float           # decode stream (same as paged)
    resident_bits: float              # pool bits held (dedup'd)
    resident_bits_nocache: float

    @property
    def saved_prefill_write_bits(self) -> float:
        return self.prefill_write_bits_nocache - self.prefill_write_bits

    @property
    def saved_resident_bits(self) -> float:
        return self.resident_bits_nocache - self.resident_bits

    def apply(self, traffic: "Traffic",
              amortize_tokens: Optional[int] = None) -> "Traffic":
        """Rebind a Traffic's KV stream to this batch for the Eq. (3)/(4)
        DSE. With ``amortize_tokens`` (expected decode tokens per request)
        the per-request prefill writes the cache did NOT save are spread
        over the generated tokens and added to the per-step KV bits, so
        the DSE sees prefill traffic shrink with the hit rate."""
        kv = self.kv_bits_per_step
        if amortize_tokens:
            kv += self.prefill_write_bits / (self.n_seqs * amortize_tokens)
        return dataclasses.replace(
            traffic, name=f"{traffic.name}+prefix_b{self.n_seqs}",
            kv_bits=kv)


def kv_traffic_prefix(cfg: ModelConfig, prompt_lens, cached_lens,
                      seq_lens=None, *, unique_cached_tokens=None,
                      page: int = 16,
                      kv_dtype_bits: int = 16) -> PrefixKVTraffic:
    """KV traffic/residency for a batch whose prompts hit the prefix cache.

    ``prompt_lens[i]`` is sequence i's prompt length; ``cached_lens[i]``
    how many of those tokens were served from cached pages (whole pages,
    so a multiple of ``page``; 0 = miss). ``seq_lens`` are current total
    lengths for the decode stream (default: the prompts, i.e. step 1).
    ``unique_cached_tokens`` is the number of distinct cached tokens the
    hits alias (default: the longest cached prefix — the single shared
    system prompt case); sharing dedups residency but never decode reads.

    Sequences are *consumers* of the shared set. A publisher whose pages
    became the cached copy should be listed with its prefix as cached
    (its footprint IS the shared set) when computing residency — listing
    it as a miss charges those pages both privately and as shared. For
    prefill-write accounting the opposite holds: the publisher really
    wrote every page, so list it as a miss there (see
    ``benchmarks/serving.py`` for the two views side by side).
    """
    prompt_lens = [int(x) for x in prompt_lens]
    cached_lens = [int(x) for x in cached_lens]
    if len(prompt_lens) != len(cached_lens):
        raise ValueError("prompt_lens and cached_lens must align")
    for lp, lc in zip(prompt_lens, cached_lens):
        if lc % page or lc > lp:
            raise ValueError(
                f"cached length {lc} must be whole pages <= prompt {lp}")
    seq_lens = ([int(x) for x in seq_lens] if seq_lens is not None
                else prompt_lens)
    if unique_cached_tokens is None:
        unique_cached_tokens = max(cached_lens, default=0)

    def kv_token_bits(n_tokens: int) -> float:
        """Sequence-length-dependent KV bits (excludes O(1) SSM state)."""
        return (kv_bits_per_step(cfg, n_tokens, kv_dtype_bits)
                - kv_bits_per_step(cfg, 0, kv_dtype_bits))

    write = write_nocache = 0.0
    pages = pages_nocache = 0
    for lp, lc in zip(prompt_lens, cached_lens):
        full = pages_for(lp, page)
        pages_nocache += full
        pages += full - lc // page
        # prefill writes are page-rounded like the allocator's granule
        write += kv_token_bits(full * page - lc)
        write_nocache += kv_token_bits(full * page)
    shared_pages = pages_for(unique_cached_tokens, page) \
        if unique_cached_tokens else 0
    pages += shared_pages
    paged = kv_traffic_paged(cfg, seq_lens, page=page,
                             kv_dtype_bits=kv_dtype_bits)
    total_prompt = sum(prompt_lens)
    return PrefixKVTraffic(
        page=page, n_seqs=len(prompt_lens), n_pages=pages,
        n_pages_nocache=pages_nocache,
        hit_rate=(sum(cached_lens) / total_prompt if total_prompt else 0.0),
        prefill_write_bits=write,
        prefill_write_bits_nocache=write_nocache,
        kv_bits_per_step=paged.kv_bits_per_step,
        resident_bits=pages * kv_token_bits(page),
        resident_bits_nocache=pages_nocache * kv_token_bits(page))


@dataclasses.dataclass(frozen=True)
class ShardedServeTraffic:
    """Per-device traffic under the sharded serving step set.

    The sharded paged engine (``serve/steps.py``) splits the byte streams
    the Eq. (3)/(4) DSE charges:

      * **weights** — TP over ``model``: every device streams only its
        shard's quantized streams (``ShardedQTensor`` stacks are
        quantize-after-shard, so shard streams are equal-sized by
        construction); the ``data`` axis replicates weights at inference.
      * **KV** — the arena's page axis shards over ``data`` and the fused
        kv_dim over ``model``: a device streams its slice of each live
        page, i.e. ``1/(data*model)`` of the batch KV stream.
      * **activations** — batch shards over ``data`` (each device decodes
        its slot slice); the hidden dim stays replicated.

    ``apply`` rebinds a single-device :class:`Traffic` to the per-device
    streams so the memory-system DSE scores ONE shard of the mesh — the
    unit that actually owns an eMEM/LPDDR5 stack on a multi-device edge
    board (SLIM-style heterogeneous partitioning)."""
    data_shards: int = 1
    model_shards: int = 1

    @property
    def n_devices(self) -> int:
        return self.data_shards * self.model_shards

    def apply(self, traffic: "Traffic") -> "Traffic":
        d, m = self.data_shards, self.model_shards
        return dataclasses.replace(
            traffic,
            name=f"{traffic.name}+shard_d{d}m{m}",
            weight_bits_outlier=traffic.weight_bits_outlier / m,
            weight_bits_inlier=traffic.weight_bits_inlier / m,
            kv_bits=traffic.kv_bits / (d * m),
            act_bits=traffic.act_bits / d,
            weight_cells_inlier=traffic.weight_cells_inlier / m,
            weight_cells_outlier=traffic.weight_cells_outlier / m,
            dram_resident_bits=traffic.dram_resident_bits / m,
            flash_resident_bits=traffic.flash_resident_bits / m)


def shard_serve_traffic(traffic: Traffic, *, data_shards: int = 1,
                        model_shards: int = 1) -> Traffic:
    """One-shot convenience: per-device view of ``traffic`` on a
    (data, model) serving mesh."""
    return ShardedServeTraffic(data_shards=data_shards,
                               model_shards=model_shards).apply(traffic)


def make_traffic(cfg: ModelConfig, method: str, *, seq_len: int = 2048,
                 qmc: QMCConfig = QMCConfig(), mx: MXConfig = MXConfig(),
                 legacy_flash: bool = False) -> Traffic:
    """Traffic for one decode step under a quantization scheme.

    Methods: fp32 | fp16 | rtn4 | awq | gptq | mx4 -> homogeneous weights
             in DRAM (fp32 is the unquantized serving baseline the cost-
             attribution layer compares QMC against).
             qmc -> dual-precision split across MRAM/ReRAM.
             emems_mram / emems_reram -> homogeneous INT4 in a single NVM.
    """
    n_active = cfg.active_param_count()
    kv = kv_bits_per_step(cfg, seq_len)
    act = act_bits_per_step(cfg)

    if method in ("fp32", "fp16", "rtn4", "awq", "gptq", "mx4"):
        bits = {"fp32": 32.0, "fp16": 16.0, "rtn4": 4.0, "awq": 4.0,
                "gptq": 4.0, "mx4": mx.avg_bits}[method]
        wbits = n_active * bits
        return Traffic(name=method, weight_bits_outlier=0.0,
                       weight_bits_inlier=wbits, kv_bits=kv, act_bits=act,
                       weight_cells_inlier=cfg.param_count() * bits,
                       weight_cells_outlier=0.0,
                       dram_resident_bits=cfg.param_count() * bits,
                       flash_resident_bits=(cfg.param_count() * bits
                                            if legacy_flash else 0.0))

    if method == "qmc":
        rho = qmc.rho
        out_bits = n_active * rho * qmc.bits_out
        in_bits = n_active * (1 - rho) * qmc.bits_in
        # capacity: inliers live in MLC cells (bits_in / cell_bits cells per
        # weight), outliers in (1-bit) MRAM cells
        in_cells = cfg.param_count() * (1 - rho) * qmc.bits_in \
            / qmc.cell_bits
        out_cells = cfg.param_count() * rho * qmc.bits_out
        return Traffic(name=f"qmc{qmc.cell_bits}b",
                       weight_bits_outlier=out_bits,
                       weight_bits_inlier=in_bits, kv_bits=kv, act_bits=act,
                       weight_cells_inlier=in_cells,
                       weight_cells_outlier=out_cells,
                       dram_resident_bits=0.0, flash_resident_bits=0.0)

    if method in ("emems_mram", "emems_reram"):
        wbits = n_active * 4.0
        if method == "emems_mram":
            return Traffic(name=method, weight_bits_outlier=wbits,
                           weight_bits_inlier=0.0, kv_bits=kv, act_bits=act,
                           weight_cells_inlier=0.0,
                           weight_cells_outlier=cfg.param_count() * 4.0,
                           dram_resident_bits=0.0, flash_resident_bits=0.0)
        return Traffic(name=method, weight_bits_outlier=0.0,
                       weight_bits_inlier=wbits, kv_bits=kv, act_bits=act,
                       weight_cells_inlier=cfg.param_count() * 4.0 / 3.0,
                       weight_cells_outlier=0.0,
                       dram_resident_bits=0.0, flash_resident_bits=0.0)
    raise ValueError(method)
