"""Decode-step traffic model for a model config + quantization scheme.

LLM decode is read-dominated: every generated token streams all active
weights once, plus the KV cache / SSM state, plus (small) activations.
This module turns a ModelConfig + quant method into a byte/bit traffic
breakdown that the memory-system simulator consumes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.qconfig import MXConfig, QMCConfig
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class Traffic:
    """Per-decode-step traffic (bits) and residency (bits / cells)."""
    name: str
    # streamed per token
    weight_bits_outlier: float       # -> MRAM in QMC
    weight_bits_inlier: float        # -> ReRAM in QMC (or DRAM for baselines)
    kv_bits: float                   # -> LPDDR5 always
    act_bits: float                  # -> LPDDR5 always
    # storage
    weight_cells_inlier: float       # MLC cells (capacity accounting)
    weight_cells_outlier: float
    dram_resident_bits: float        # weights resident in DRAM (baselines)
    flash_resident_bits: float       # legacy hierarchy keeps a Flash copy

    @property
    def weight_bits(self) -> float:
        return self.weight_bits_outlier + self.weight_bits_inlier

    @property
    def total_cells(self) -> float:
        return self.weight_cells_inlier + self.weight_cells_outlier


def kv_bits_per_step(cfg: ModelConfig, seq_len: int, kv_dtype_bits: int = 16
                     ) -> float:
    """KV cache + SSM state bits read per decode step (batch=1)."""
    n_attn = sum(1 for k in cfg.pattern
                 if k.startswith(("attn", "hybrid"))) * cfg.n_groups
    kv = 2.0 * n_attn * cfg.kv_dim * seq_len * kv_dtype_bits
    n_ssm = sum(1 for k in cfg.pattern
                if k == "mamba" or k.startswith("hybrid")) * cfg.n_groups
    ssm = n_ssm * (cfg.ssm_nheads * cfg.ssm_headdim * cfg.d_state * 32
                   + (cfg.d_conv - 1) * cfg.conv_dim * kv_dtype_bits)
    if cfg.is_encdec:
        kv += 2.0 * cfg.n_layers * cfg.kv_dim * cfg.enc_seq * kv_dtype_bits
    return kv + ssm


def act_bits_per_step(cfg: ModelConfig, act_dtype_bits: int = 16) -> float:
    return 4.0 * cfg.n_layers * cfg.d_model * act_dtype_bits


def make_traffic(cfg: ModelConfig, method: str, *, seq_len: int = 2048,
                 qmc: QMCConfig = QMCConfig(), mx: MXConfig = MXConfig(),
                 legacy_flash: bool = False) -> Traffic:
    """Traffic for one decode step under a quantization scheme.

    Methods: fp16 | rtn4 | awq | gptq | mx4 -> homogeneous weights in DRAM.
             qmc -> dual-precision split across MRAM/ReRAM.
             emems_mram / emems_reram -> homogeneous INT4 in a single NVM.
    """
    n_active = cfg.active_param_count()
    kv = kv_bits_per_step(cfg, seq_len)
    act = act_bits_per_step(cfg)

    if method in ("fp16", "rtn4", "awq", "gptq", "mx4"):
        bits = {"fp16": 16.0, "rtn4": 4.0, "awq": 4.0, "gptq": 4.0,
                "mx4": mx.avg_bits}[method]
        wbits = n_active * bits
        return Traffic(name=method, weight_bits_outlier=0.0,
                       weight_bits_inlier=wbits, kv_bits=kv, act_bits=act,
                       weight_cells_inlier=cfg.param_count() * bits,
                       weight_cells_outlier=0.0,
                       dram_resident_bits=cfg.param_count() * bits,
                       flash_resident_bits=(cfg.param_count() * bits
                                            if legacy_flash else 0.0))

    if method == "qmc":
        rho = qmc.rho
        out_bits = n_active * rho * qmc.bits_out
        in_bits = n_active * (1 - rho) * qmc.bits_in
        # capacity: inliers live in MLC cells (bits_in / cell_bits cells per
        # weight), outliers in (1-bit) MRAM cells
        in_cells = cfg.param_count() * (1 - rho) * qmc.bits_in \
            / qmc.cell_bits
        out_cells = cfg.param_count() * rho * qmc.bits_out
        return Traffic(name=f"qmc{qmc.cell_bits}b",
                       weight_bits_outlier=out_bits,
                       weight_bits_inlier=in_bits, kv_bits=kv, act_bits=act,
                       weight_cells_inlier=in_cells,
                       weight_cells_outlier=out_cells,
                       dram_resident_bits=0.0, flash_resident_bits=0.0)

    if method in ("emems_mram", "emems_reram"):
        wbits = n_active * 4.0
        if method == "emems_mram":
            return Traffic(name=method, weight_bits_outlier=wbits,
                           weight_bits_inlier=0.0, kv_bits=kv, act_bits=act,
                           weight_cells_inlier=0.0,
                           weight_cells_outlier=cfg.param_count() * 4.0,
                           dram_resident_bits=0.0, flash_resident_bits=0.0)
        return Traffic(name=method, weight_bits_outlier=0.0,
                       weight_bits_inlier=wbits, kv_bits=kv, act_bits=act,
                       weight_cells_inlier=cfg.param_count() * 4.0 / 3.0,
                       weight_cells_outlier=0.0,
                       dram_resident_bits=0.0, flash_resident_bits=0.0)
    raise ValueError(method)
