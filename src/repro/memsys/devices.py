"""Memory device models — paper Table 1 (+ Flash for the legacy baseline).

All constants are the paper's cited measurements:
  MRAM   [43,44]: 3.5 ns read, 36.57 GiB/s per channel, 1 pJ/bit, 66 Mb/mm2
  ReRAM  [40,45]: <5 ns read, 1.8 GiB/s per 256x256 array, 1.56 pJ/bit
                  (3-bit mode), 30.1 Mb/mm2 (3-bit mode)
  LPDDR5 [46]   : 1.7 ns, 186.26 GiB/s, 3.5 pJ/bit, 209.9 Mb/mm2
UCIe 3.0 chiplet link: 64 GT/s per IO x 64 IOs for on-chip MRAM access.
"""
from __future__ import annotations

import dataclasses

GiB = 1024 ** 3


@dataclasses.dataclass(frozen=True)
class MemDevice:
    name: str
    read_latency_ns: float          # intrinsic access latency t_access
    bandwidth_gibs: float           # per channel/array unit
    read_energy_pj_per_bit: float
    density_mb_per_mm2: float
    cell_bits: int = 1              # logical bits per cell (MLC)

    def bandwidth_bytes(self, units: int = 1) -> float:
        return self.bandwidth_gibs * GiB * units


MRAM = MemDevice("MRAM", read_latency_ns=3.5, bandwidth_gibs=36.57,
                 read_energy_pj_per_bit=1.0, density_mb_per_mm2=66.0)

RERAM_3B = MemDevice("MLC-ReRAM-3b", read_latency_ns=5.0, bandwidth_gibs=1.8,
                     read_energy_pj_per_bit=1.56,
                     density_mb_per_mm2=30.1, cell_bits=3)

# 2-bit mode: fewer levels -> lower BER; density and per-bit energy scale
# with cells/bit (2/3 of the 3-bit-mode density; same current per access).
RERAM_2B = MemDevice("MLC-ReRAM-2b", read_latency_ns=5.0, bandwidth_gibs=1.8,
                     read_energy_pj_per_bit=1.56 * 3.0 / 2.0,
                     density_mb_per_mm2=30.1 * 2.0 / 3.0, cell_bits=2)

LPDDR5 = MemDevice("LPDDR5", read_latency_ns=1.7, bandwidth_gibs=186.26,
                   read_energy_pj_per_bit=3.5, density_mb_per_mm2=209.9)

# NAND Flash: dense cold storage, used only for weight initialization in the
# conventional hierarchy (paper §1); read bandwidth is the PCIe-class limit.
FLASH = MemDevice("Flash", read_latency_ns=25_000.0, bandwidth_gibs=4.0,
                  read_energy_pj_per_bit=2.5, density_mb_per_mm2=1300.0)

# Interconnect energy per bit crossing the package network (Eq. 4 E_network)
E_NETWORK_PJ_PER_BIT = 0.25
# UCIe 3.0 link to the MRAM chiplet: 64 GT/s x 64 IOs = 512 GiB/s ceiling
UCIE_BW_GIBS = 64 * 64 / 8
# Dual-clock FIFO synchronization between memory clock domains [39]
T_SYNC_NS = 3 * 0.303               # 2-4 cycles at 3.3 GHz -> ~1 ns
RERAM_BUS_GHZ = 3.3                 # ReRAM module bus: 3.3 GHz, 64-byte IO
RERAM_BUS_BYTES = 64
