"""Paged KV-cache pool: block-table paging over one shared device arena.

The QMC deployment splits the memory system so LPDDR5 carries *only* the
dynamic KV stream (weights live in eMEMs). This module is the serving-side
half of that bargain: instead of one contiguous ``[1, max_len, kv_dim]``
slab per decode slot, every sequence draws fixed-size pages from a single
``[n_pages, page, kv_dim]`` arena (per layer group), addressed through a
per-sequence block table. That gives

  * O(page) internal fragmentation instead of O(max_len) over-allocation,
  * free-list recycling the moment a sequence finishes, and
  * a single batched decode step over all slots (the gather path in
    ``models/attention.py``) rather than N sequential batch-1 calls.

Page-size choice is a memory-system knob, not just a software one: a page
is the granule the paged gather streams from DRAM, so it should be a
multiple of the LPDDR5 burst (64 B bus transactions in
``memsys/devices.py``). The default ``page=16`` tokens keeps every
per-head page a whole number of bursts for both the fp and int8 cache
layouts; ``memsys.workload.kv_traffic_paged`` charges this page-rounded
traffic — the live pages the block-table-aware Pallas kernel
(``kernels/paged_attention.py``, engine ``paged_attention=True``) really
streams. (The XLA reference gather in ``models/attention.py``
materializes the full table width instead — ``live_only=False`` in the
traffic model.)

Host-side metadata (free list, block tables, per-slot lengths) lives here;
the device arena itself is an ordinary cache pytree built by
``models.kvcache.paged_init_cache`` and threaded through ``jax.jit`` by the
engine. Page 0 is reserved as the null page for inactive decode lanes.

Pages are **reference counted** so the prefix cache
(``serve/prefix_cache.py``) can alias one physical page into many block
tables: a page's refcount is the number of slot mappings plus one if the
prefix-cache index holds it. A shared page (refcount > 1) is never
scattered into — the first divergent write goes through :meth:`cow`,
which hands the slot a private copy and decrements the shared count.
The free list only ever holds refcount-0 pages; double-frees and frees of
still-referenced pages raise instead of silently corrupting the arena.
"""
from __future__ import annotations

from collections import deque
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.memsys.workload import pages_for  # noqa: F401  (canonical rule)
from repro.models import kvcache as KV
from repro.models.config import ModelConfig


class PoolExhausted(Exception):
    """Raised when an allocation cannot be satisfied even after preemption."""


class PageAccountingError(AssertionError):
    """Refcount / free-list invariant violation (a COW or lifetime bug)."""


class PagedKVPool:
    """Free-list page allocator + per-slot block tables + page refcounts.

    Pure host-side bookkeeping: device state is the arena pytree the engine
    owns. ``n_pages`` counts usable pages; one extra null page (id 0) is
    always added to the arena.
    """

    def __init__(self, cfg: ModelConfig, *, n_pages: int, page: int,
                 max_slots: int, max_pages_per_seq: int,
                 cache_dtype=jnp.float32):
        if page & (page - 1):
            raise ValueError(f"page size must be a power of 2, got {page}")
        self.cfg = cfg
        self.page = page
        self.n_pages = n_pages
        self.max_slots = max_slots
        self.max_pages_per_seq = max_pages_per_seq
        self.cache_dtype = cache_dtype
        # page 0 = null page -> usable ids are 1..n_pages
        self.free: deque = deque(range(1, n_pages + 1))
        self._free_set = set(self.free)      # O(1) double-free detection
        self.ref = np.zeros(n_pages + 1, np.int32)   # refcount per page id
        self.slot_pages: List[List[int]] = [[] for _ in range(max_slots)]
        self.block_tables = np.zeros((max_slots, max_pages_per_seq),
                                     np.int32)
        self.pages_peak = 0
        self.cow_copies = 0
        # host↔device page-op round-trip counters (cumulative over the
        # pool's life; the engine diffs them per run into EngineStats —
        # these quantify the prefix-cache adopt/COW host overhead):
        # adopt_calls counts page-adoption events (block-table rewrites
        # for cached/dedup'd pages), tables_rebuilds counts device_tables
        # host→device uploads the content cache could not elide
        self.adopt_calls = 0
        self.tables_rebuilds = 0
        self._tbl_cache = None       # (key, device array) — see below
        # set on every block-table mutation, cleared by the engine after
        # it pushes the tables to the device (the fused apply_page_ops
        # flush) — pure decode rounds skip the rebuild entirely
        self.tables_dirty = True

    # ---- allocation ----------------------------------------------------
    @property
    def free_count(self) -> int:
        return len(self.free)

    @property
    def used_count(self) -> int:
        return self.n_pages - len(self.free)

    @property
    def pinned_count(self) -> int:
        """Pages mapped by at least one live slot (never evictable)."""
        return len({p for pages in self.slot_pages for p in pages})

    @property
    def cached_only_count(self) -> int:
        """Pages held only by the prefix-cache index (evictable)."""
        return self.used_count - self.pinned_count

    def can_fit(self, n_tokens: int) -> bool:
        return pages_for(n_tokens, self.page) <= len(self.free)

    def _pop_free(self) -> int:
        pid = self.free.popleft()
        self._free_set.discard(pid)
        if self.ref[pid] != 0:
            raise PageAccountingError(
                f"page {pid} on the free list with refcount "
                f"{self.ref[pid]}")
        self.ref[pid] = 1
        return pid

    def release(self, pid: int) -> bool:
        """Drop one reference to pid; recycle it when the count hits 0.

        Returns True when the page actually went back to the free list.
        Raises :class:`PageAccountingError` on double-free (page already
        free) or on a refcount underflow."""
        if pid in self._free_set:
            raise PageAccountingError(f"double free of page {pid}")
        if self.ref[pid] <= 0:
            raise PageAccountingError(
                f"release of page {pid} with refcount {self.ref[pid]}")
        self.ref[pid] -= 1
        if self.ref[pid] == 0:
            self.free.append(pid)
            self._free_set.add(pid)
            return True
        return False

    def retain(self, pid: int) -> None:
        """Add a reference (the prefix cache publishing a page)."""
        if pid in self._free_set or self.ref[pid] <= 0:
            raise PageAccountingError(
                f"retain of unallocated page {pid}")
        self.ref[pid] += 1

    def ensure(self, slot: int, n_tokens: int) -> Optional[List[int]]:
        """Grow slot's allocation to cover n_tokens positions.

        Returns the list of newly allocated page ids, or None if the free
        list cannot satisfy the request (caller decides whom to preempt or
        which cached pages to evict)."""
        have = len(self.slot_pages[slot])
        need = pages_for(n_tokens, self.page)
        if need > self.max_pages_per_seq:
            raise PoolExhausted(
                f"sequence needs {need} pages > max_pages_per_seq="
                f"{self.max_pages_per_seq}")
        if need <= have:
            return []
        if need - have > len(self.free):
            return None
        fresh = [self._pop_free() for _ in range(need - have)]
        self.tables_dirty = True
        for j, pid in enumerate(fresh, start=have):
            self.slot_pages[slot].append(pid)
            self.block_tables[slot, j] = pid
        self.pages_peak = max(self.pages_peak, self.used_count)
        return fresh

    def adopt(self, slot: int, page_ids: List[int]) -> None:
        """Map already-live (cache-held) pages into an empty slot's table.

        The slot shares the pages read-only: each gains a reference, and a
        later write into one must go through :meth:`cow` first."""
        if self.slot_pages[slot]:
            raise PageAccountingError(
                f"adopt into non-empty slot {slot}")
        self.adopt_calls += 1
        self.tables_dirty = True
        for j, pid in enumerate(page_ids):
            if pid == 0:
                raise PageAccountingError(
                    "adopt of the reserved null page 0 (would alias every "
                    "inactive lane's scratch page into a live table)")
            self.retain(pid)
            self.slot_pages[slot].append(pid)
            self.block_tables[slot, j] = pid
        self.pages_peak = max(self.pages_peak, self.used_count)

    def cow(self, slot: int, token_pos: int):
        """Make the page holding ``token_pos`` private to ``slot``.

        Returns None when the page is already private (refcount 1),
        ``(src, dst)`` when a fresh page ``dst`` was mapped in place of the
        shared ``src`` — the caller must copy the device page contents —
        or False when the free list is empty (caller evicts/preempts and
        retries). The shared page's refcount is decremented; it is never
        written."""
        j = token_pos // self.page
        pid = self.slot_pages[slot][j]
        if self.ref[pid] == 1:
            return None
        if not self.free:
            return False
        dst = self._pop_free()
        self.tables_dirty = True
        self.slot_pages[slot][j] = dst
        self.block_tables[slot, j] = dst
        self.ref[pid] -= 1          # shared copy stays live elsewhere
        self.cow_copies += 1
        self.pages_peak = max(self.pages_peak, self.used_count)
        return pid, dst

    def trim(self, slot: int, n_tokens: int) -> int:
        """Shrink the slot's allocation back to what ``n_tokens``
        positions need; returns how many pages were recycled.

        The speculative-decode rollback — and the pipelined engine's
        EOS-lag rollback, which is the same move: a verify step
        allocates pages out to the full draft length (a pipelined round
        allocates for the one token dispatched past an EOS that landed
        during the readback lag), and when the model rejects a suffix
        (or the EOS retires) the tail pages hold only garbage K/V
        (already masked by ``valid_len`` until real tokens overwrite
        those positions). Tail pages were freshly allocated for
        positions past the live prefix, so they are never
        prefix-cache-shared; release still goes through the refcount
        for safety."""
        keep = pages_for(n_tokens, self.page)
        n = 0
        while len(self.slot_pages[slot]) > keep:
            pid = self.slot_pages[slot].pop()
            self.block_tables[slot, len(self.slot_pages[slot])] = 0
            self.tables_dirty = True
            n += bool(self.release(pid))
        return n

    def free_slot(self, slot: int) -> int:
        """Drop the slot's references; returns how many pages were recycled
        (pages still held by the prefix cache stay allocated)."""
        n = 0
        for pid in self.slot_pages[slot]:
            n += bool(self.release(pid))
        self.slot_pages[slot] = []
        self.block_tables[slot, :] = 0
        self.tables_dirty = True
        return n

    def device_tables(self, n_groups: int) -> jax.Array:
        """Block tables as a device array broadcast over layer groups.

        Cached by table *content* — but only on CPU, where the serving
        steps disable arena donation (``serve/steps.py``). On accelerator
        backends the steps donate the arena and the tables ride inside
        it, so a cached device buffer would be invalidated by the
        donation the first time it was reused; there the array is rebuilt
        per call (a few hundred int32s — negligible next to the step)."""
        key = (n_groups, self.block_tables.tobytes())
        if self._tbl_cache is not None and self._tbl_cache[0] == key:
            return self._tbl_cache[1]
        self.tables_rebuilds += 1
        tbl = jnp.asarray(self.block_tables)
        dev = jnp.broadcast_to(tbl[None], (n_groups,) + tbl.shape)
        if jax.default_backend() == "cpu":
            self._tbl_cache = (key, dev)
        return dev

    # ---- device arena --------------------------------------------------
    def init_arena(self):
        """Fresh zeroed arena pytree (leading n_groups dim, +1 null page)."""
        return KV.paged_init_cache(self.cfg, self.n_pages + 1, self.page,
                                   self.max_slots, self.max_pages_per_seq,
                                   self.cache_dtype)

    def check_tables(self) -> None:
        """Null-page aliasing guard: page 0 must never appear in a live
        region of a block table, and every live region must mirror
        ``slot_pages``. Until now only convention protected this — a
        corrupted table would silently attend over null-page garbage (or
        another sequence's KV). Raises :class:`PageAccountingError`
        instead. O(max_slots * max_pages_per_seq) host ints per step."""
        for s, pages in enumerate(self.slot_pages):
            n = len(pages)
            live = self.block_tables[s, :n]
            if (live == 0).any() or live.tolist() != pages:
                raise PageAccountingError(
                    f"slot {s} block table {self.block_tables[s].tolist()} "
                    f"diverged from its page map {pages} (null page in a "
                    f"live region, or a stale/corrupted table)")
            if self.block_tables[s, n:].any():
                raise PageAccountingError(
                    f"slot {s} maps pages beyond its {n} live entries: "
                    f"{self.block_tables[s].tolist()}")

    def install_tables(self, arena):
        """Return arena with current block tables written into every group.

        Tables are validated by :meth:`check_tables` on every install, so
        a corrupted mapping raises before any step can attend over
        garbage."""
        self.check_tables()
        tbl = self.device_tables(self.cfg.n_groups)
        out = {}
        for key, grp in arena.items():
            grp = dict(grp)
            if "attn" in grp:
                attn = dict(grp["attn"])
                attn["block_tbl"] = tbl
                grp["attn"] = attn
            out[key] = grp
        return out


