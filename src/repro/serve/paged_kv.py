"""Paged KV-cache pool: block-table paging over one shared device arena.

The QMC deployment splits the memory system so LPDDR5 carries *only* the
dynamic KV stream (weights live in eMEMs). This module is the serving-side
half of that bargain: instead of one contiguous ``[1, max_len, kv_dim]``
slab per decode slot, every sequence draws fixed-size pages from a single
``[n_pages, page, kv_dim]`` arena (per layer group), addressed through a
per-sequence block table. That gives

  * O(page) internal fragmentation instead of O(max_len) over-allocation,
  * free-list recycling the moment a sequence finishes, and
  * a single batched decode step over all slots (the gather path in
    ``models/attention.py``) rather than N sequential batch-1 calls.

Page-size choice is a memory-system knob, not just a software one: a page
is the granule the paged gather streams from DRAM, so it should be a
multiple of the LPDDR5 burst (64 B bus transactions in
``memsys/devices.py``). The default ``page=16`` tokens keeps every
per-head page a whole number of bursts for both the fp and int8 cache
layouts; ``memsys.workload.kv_traffic_paged`` charges this page-rounded
traffic — the live pages a block-table-aware attention kernel streams.
(The CPU reference gather in ``models/attention.py`` materializes the
full table width instead; the traffic model describes the target
hardware path, not that XLA fallback.)

Host-side metadata (free list, block tables, per-slot lengths) lives here;
the device arena itself is an ordinary cache pytree built by
``models.kvcache.paged_init_cache`` and threaded through ``jax.jit`` by the
engine. Page 0 is reserved as the null page for inactive decode lanes.

Pages are **reference counted** so the prefix cache
(``serve/prefix_cache.py``) can alias one physical page into many block
tables: a page's refcount is the number of slot mappings plus one if the
prefix-cache index holds it. A shared page (refcount > 1) is never
scattered into — the first divergent write goes through :meth:`cow`,
which hands the slot a private copy and decrements the shared count.
The free list only ever holds refcount-0 pages; double-frees and frees of
still-referenced pages raise instead of silently corrupting the arena.
"""
from __future__ import annotations

import functools
from collections import deque
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.memsys.workload import pages_for  # noqa: F401  (canonical rule)
from repro.models import kvcache as KV
from repro.models.config import ModelConfig


class PoolExhausted(Exception):
    """Raised when an allocation cannot be satisfied even after preemption."""


class PageAccountingError(AssertionError):
    """Refcount / free-list invariant violation (a COW or lifetime bug)."""


class PagedKVPool:
    """Free-list page allocator + per-slot block tables + page refcounts.

    Pure host-side bookkeeping: device state is the arena pytree the engine
    owns. ``n_pages`` counts usable pages; one extra null page (id 0) is
    always added to the arena.
    """

    def __init__(self, cfg: ModelConfig, *, n_pages: int, page: int,
                 max_slots: int, max_pages_per_seq: int,
                 cache_dtype=jnp.float32):
        if page & (page - 1):
            raise ValueError(f"page size must be a power of 2, got {page}")
        self.cfg = cfg
        self.page = page
        self.n_pages = n_pages
        self.max_slots = max_slots
        self.max_pages_per_seq = max_pages_per_seq
        self.cache_dtype = cache_dtype
        # page 0 = null page -> usable ids are 1..n_pages
        self.free: deque = deque(range(1, n_pages + 1))
        self._free_set = set(self.free)      # O(1) double-free detection
        self.ref = np.zeros(n_pages + 1, np.int32)   # refcount per page id
        self.slot_pages: List[List[int]] = [[] for _ in range(max_slots)]
        self.block_tables = np.zeros((max_slots, max_pages_per_seq),
                                     np.int32)
        self.pages_peak = 0
        self.cow_copies = 0
        self._tbl_dirty = True
        self._tbl_dev = None

    # ---- allocation ----------------------------------------------------
    @property
    def free_count(self) -> int:
        return len(self.free)

    @property
    def used_count(self) -> int:
        return self.n_pages - len(self.free)

    @property
    def pinned_count(self) -> int:
        """Pages mapped by at least one live slot (never evictable)."""
        return len({p for pages in self.slot_pages for p in pages})

    @property
    def cached_only_count(self) -> int:
        """Pages held only by the prefix-cache index (evictable)."""
        return self.used_count - self.pinned_count

    def can_fit(self, n_tokens: int) -> bool:
        return pages_for(n_tokens, self.page) <= len(self.free)

    def _pop_free(self) -> int:
        pid = self.free.popleft()
        self._free_set.discard(pid)
        if self.ref[pid] != 0:
            raise PageAccountingError(
                f"page {pid} on the free list with refcount "
                f"{self.ref[pid]}")
        self.ref[pid] = 1
        return pid

    def release(self, pid: int) -> bool:
        """Drop one reference to pid; recycle it when the count hits 0.

        Returns True when the page actually went back to the free list.
        Raises :class:`PageAccountingError` on double-free (page already
        free) or on a refcount underflow."""
        if pid in self._free_set:
            raise PageAccountingError(f"double free of page {pid}")
        if self.ref[pid] <= 0:
            raise PageAccountingError(
                f"release of page {pid} with refcount {self.ref[pid]}")
        self.ref[pid] -= 1
        if self.ref[pid] == 0:
            self.free.append(pid)
            self._free_set.add(pid)
            return True
        return False

    def retain(self, pid: int) -> None:
        """Add a reference (the prefix cache publishing a page)."""
        if pid in self._free_set or self.ref[pid] <= 0:
            raise PageAccountingError(
                f"retain of unallocated page {pid}")
        self.ref[pid] += 1

    def ensure(self, slot: int, n_tokens: int) -> Optional[List[int]]:
        """Grow slot's allocation to cover n_tokens positions.

        Returns the list of newly allocated page ids, or None if the free
        list cannot satisfy the request (caller decides whom to preempt or
        which cached pages to evict)."""
        have = len(self.slot_pages[slot])
        need = pages_for(n_tokens, self.page)
        if need > self.max_pages_per_seq:
            raise PoolExhausted(
                f"sequence needs {need} pages > max_pages_per_seq="
                f"{self.max_pages_per_seq}")
        if need <= have:
            return []
        if need - have > len(self.free):
            return None
        fresh = [self._pop_free() for _ in range(need - have)]
        for j, pid in enumerate(fresh, start=have):
            self.slot_pages[slot].append(pid)
            self.block_tables[slot, j] = pid
        self._tbl_dirty = True
        self.pages_peak = max(self.pages_peak, self.used_count)
        return fresh

    def adopt(self, slot: int, page_ids: List[int]) -> None:
        """Map already-live (cache-held) pages into an empty slot's table.

        The slot shares the pages read-only: each gains a reference, and a
        later write into one must go through :meth:`cow` first."""
        if self.slot_pages[slot]:
            raise PageAccountingError(
                f"adopt into non-empty slot {slot}")
        for j, pid in enumerate(page_ids):
            self.retain(pid)
            self.slot_pages[slot].append(pid)
            self.block_tables[slot, j] = pid
        self._tbl_dirty = True
        self.pages_peak = max(self.pages_peak, self.used_count)

    def cow(self, slot: int, token_pos: int):
        """Make the page holding ``token_pos`` private to ``slot``.

        Returns None when the page is already private (refcount 1),
        ``(src, dst)`` when a fresh page ``dst`` was mapped in place of the
        shared ``src`` — the caller must copy the device page contents —
        or False when the free list is empty (caller evicts/preempts and
        retries). The shared page's refcount is decremented; it is never
        written."""
        j = token_pos // self.page
        pid = self.slot_pages[slot][j]
        if self.ref[pid] == 1:
            return None
        if not self.free:
            return False
        dst = self._pop_free()
        self.slot_pages[slot][j] = dst
        self.block_tables[slot, j] = dst
        self.ref[pid] -= 1          # shared copy stays live elsewhere
        self._tbl_dirty = True
        self.cow_copies += 1
        self.pages_peak = max(self.pages_peak, self.used_count)
        return pid, dst

    def free_slot(self, slot: int) -> int:
        """Drop the slot's references; returns how many pages were recycled
        (pages still held by the prefix cache stay allocated)."""
        n = 0
        for pid in self.slot_pages[slot]:
            n += bool(self.release(pid))
        self.slot_pages[slot] = []
        self.block_tables[slot, :] = 0
        self._tbl_dirty = True
        return n

    def device_tables(self, n_groups: int) -> jax.Array:
        """Block tables as a device array broadcast over layer groups."""
        if self._tbl_dirty or self._tbl_dev is None:
            tbl = jnp.asarray(self.block_tables)
            self._tbl_dev = jnp.broadcast_to(
                tbl[None], (n_groups,) + tbl.shape)
            self._tbl_dirty = False
        return self._tbl_dev

    # ---- device arena --------------------------------------------------
    def init_arena(self):
        """Fresh zeroed arena pytree (leading n_groups dim, +1 null page)."""
        return KV.paged_init_cache(self.cfg, self.n_pages + 1, self.page,
                                   self.max_slots, self.max_pages_per_seq,
                                   self.cache_dtype)

    def install_tables(self, arena, slot: Optional[int] = None):
        """Return arena with current block tables written into every group.

        ``slot`` narrows the tables to that one slot's row (batch 1) — the
        view the paged suffix prefill runs against."""
        tbl = self.device_tables(self.cfg.n_groups)
        if slot is not None:
            tbl = tbl[:, slot:slot + 1]
        out = {}
        for key, grp in arena.items():
            grp = dict(grp)
            if "attn" in grp:
                attn = dict(grp["attn"])
                attn["block_tbl"] = tbl
                grp["attn"] = attn
            out[key] = grp
        return out


# -------------------------------------------------------------------------
# prefill adoption: contiguous batch-1 cache -> arena pages
# -------------------------------------------------------------------------
_CONTIG_TO_PAGED = (("k", "k_pages"), ("v", "v_pages"),
                    ("k_scale", "k_scale_pages"),
                    ("v_scale", "v_scale_pages"))


@functools.lru_cache(maxsize=None)
def make_adopt(cfg: ModelConfig, page: int):
    """jit'd (arena, contig_cache, page_ids, slot) -> arena.

    Copies a batch-1 contiguous prefill cache (bucket length T, a multiple
    of ``page``) into the arena pages listed in ``page_ids`` (length
    T//page; trailing ids may repeat the null page 0 when the prompt needs
    fewer pages than the bucket holds — null-page contents are never read).
    SSM/conv state is dense per-slot and lands in row ``slot``. One compile
    per prefill bucket length."""

    @jax.jit
    def adopt(arena, contig, page_ids, slot):
        out = {}
        for i, kind in enumerate(cfg.pattern):
            key = f"b{i}"
            grp = dict(arena[key])
            if "attn" in grp:
                attn = dict(grp["attn"])
                src = contig[key]["attn"]
                n = page_ids.shape[0]
                for c_name, p_name in _CONTIG_TO_PAGED:
                    if c_name not in src:
                        continue
                    s = src[c_name]                    # [G, 1, T, X]
                    g, _, t, x = s.shape
                    s = s.reshape(g, n, page, x)
                    attn[p_name] = attn[p_name].at[:, page_ids].set(s)
                grp["attn"] = attn
            if "mamba" in grp:
                mm = dict(grp["mamba"])
                src = contig[key]["mamba"]
                mm["ssm"] = mm["ssm"].at[:, slot].set(src["ssm"][:, 0])
                mm["conv"] = mm["conv"].at[:, slot].set(src["conv"][:, 0])
                grp["mamba"] = mm
            out[key] = grp
        return out

    return adopt


@functools.lru_cache(maxsize=None)
def make_bucketed_prefill(cfg: ModelConfig, cache_dtype=jnp.float32):
    """Returns prefill(params, tokens [1,T], valid_len [1]) ->

    (full_logits [1,T,V], cache). Unlike ``models.model.prefill`` this
    keeps the full logits so the caller can read the logit at the true
    (pre-padding) last prompt token — right padding is causally invisible
    to attention, and ``valid_len`` keeps the recurrent SSM state clean.
    Compiles once per bucket T."""
    from repro.models.model import forward

    @jax.jit
    def _prefill(params, tokens, valid_len):
        cache = KV.init_cache(cfg, 1, tokens.shape[1], cache_dtype)
        logits, new_cache, _ = forward(cfg, params, tokens, cache=cache,
                                       valid_len=valid_len)
        return logits, new_cache

    return _prefill


@functools.lru_cache(maxsize=None)
def make_paged_prefill(cfg: ModelConfig):
    """Returns suffix_prefill(params, arena_slice, tokens [1,T], start [1],
    valid [1]) -> (full_logits [1,T,V], arena_slice).

    Prefills an uncached prompt *suffix* directly against the paged arena:
    queries run at absolute positions ``start + t`` and attend the slot's
    whole block table, so cached prefix pages adopted by the prefix cache
    are visible without any contiguous round-trip. ``valid`` is the
    absolute position bound start + true_suffix_len: reads past it are
    masked and writes of right-padding bucket garbage are routed to the
    null page. ``arena_slice`` is the arena with ``block_tbl`` narrowed to
    the one admitting slot (batch 1). Compiles once per suffix bucket T."""
    from repro.models.model import forward

    @jax.jit
    def _suffix_prefill(params, arena, tokens, start, valid):
        t = tokens.shape[1]
        positions = start[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]
        logits, new_arena, _ = forward(cfg, params, tokens,
                                       positions=positions, cache=arena,
                                       valid_len=valid)
        return logits, new_arena

    return _suffix_prefill


@functools.lru_cache(maxsize=None)
def make_page_copy(cfg: ModelConfig):
    """jit'd (arena, src, dst) -> arena with page dst a copy of page src
    in every attention leaf of every group — the device half of
    :meth:`PagedKVPool.cow` (the host half swaps the block-table entry)."""

    @jax.jit
    def _copy(arena, src, dst):
        out = {}
        for i, kind in enumerate(cfg.pattern):
            key = f"b{i}"
            grp = dict(arena[key])
            if "attn" in grp:
                attn = dict(grp["attn"])
                for name, leaf in attn.items():
                    if name.endswith("_pages"):
                        attn[name] = leaf.at[:, dst].set(leaf[:, src])
                grp["attn"] = attn
            out[key] = grp
        return out

    return _copy
