"""Paged KV-cache pool: block-table paging over one shared device arena.

The QMC deployment splits the memory system so LPDDR5 carries *only* the
dynamic KV stream (weights live in eMEMs). This module is the serving-side
half of that bargain: instead of one contiguous ``[1, max_len, kv_dim]``
slab per decode slot, every sequence draws fixed-size pages from a single
``[n_pages, page, kv_dim]`` arena (per layer group), addressed through a
per-sequence block table. That gives

  * O(page) internal fragmentation instead of O(max_len) over-allocation,
  * free-list recycling the moment a sequence finishes, and
  * a single batched decode step over all slots (the gather path in
    ``models/attention.py``) rather than N sequential batch-1 calls.

Page-size choice is a memory-system knob, not just a software one: a page
is the granule the paged gather streams from DRAM, so it should be a
multiple of the LPDDR5 burst (64 B bus transactions in
``memsys/devices.py``). The default ``page=16`` tokens keeps every
per-head page a whole number of bursts for both the fp and int8 cache
layouts; ``memsys.workload.kv_traffic_paged`` charges this page-rounded
traffic — the live pages a block-table-aware attention kernel streams.
(The CPU reference gather in ``models/attention.py`` materializes the
full table width instead; the traffic model describes the target
hardware path, not that XLA fallback.)

Host-side metadata (free list, block tables, per-slot lengths) lives here;
the device arena itself is an ordinary cache pytree built by
``models.kvcache.paged_init_cache`` and threaded through ``jax.jit`` by the
engine. Page 0 is reserved as the null page for inactive decode lanes.
"""
from __future__ import annotations

import functools
from collections import deque
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.memsys.workload import pages_for  # noqa: F401  (canonical rule)
from repro.models import kvcache as KV
from repro.models.config import ModelConfig


class PoolExhausted(Exception):
    """Raised when an allocation cannot be satisfied even after preemption."""


class PagedKVPool:
    """Free-list page allocator + per-slot block tables.

    Pure host-side bookkeeping: device state is the arena pytree the engine
    owns. ``n_pages`` counts usable pages; one extra null page (id 0) is
    always added to the arena.
    """

    def __init__(self, cfg: ModelConfig, *, n_pages: int, page: int,
                 max_slots: int, max_pages_per_seq: int,
                 cache_dtype=jnp.float32):
        if page & (page - 1):
            raise ValueError(f"page size must be a power of 2, got {page}")
        self.cfg = cfg
        self.page = page
        self.n_pages = n_pages
        self.max_slots = max_slots
        self.max_pages_per_seq = max_pages_per_seq
        self.cache_dtype = cache_dtype
        # page 0 = null page -> usable ids are 1..n_pages
        self.free: deque = deque(range(1, n_pages + 1))
        self.slot_pages: List[List[int]] = [[] for _ in range(max_slots)]
        self.block_tables = np.zeros((max_slots, max_pages_per_seq),
                                     np.int32)
        self.pages_peak = 0
        self._tbl_dirty = True
        self._tbl_dev = None

    # ---- allocation ----------------------------------------------------
    @property
    def free_count(self) -> int:
        return len(self.free)

    @property
    def used_count(self) -> int:
        return self.n_pages - len(self.free)

    def can_fit(self, n_tokens: int) -> bool:
        return pages_for(n_tokens, self.page) <= len(self.free)

    def ensure(self, slot: int, n_tokens: int) -> Optional[List[int]]:
        """Grow slot's allocation to cover n_tokens positions.

        Returns the list of newly allocated page ids, or None if the free
        list cannot satisfy the request (caller decides whom to preempt)."""
        have = len(self.slot_pages[slot])
        need = pages_for(n_tokens, self.page)
        if need > self.max_pages_per_seq:
            raise PoolExhausted(
                f"sequence needs {need} pages > max_pages_per_seq="
                f"{self.max_pages_per_seq}")
        if need <= have:
            return []
        if need - have > len(self.free):
            return None
        fresh = [self.free.popleft() for _ in range(need - have)]
        for j, pid in enumerate(fresh, start=have):
            self.slot_pages[slot].append(pid)
            self.block_tables[slot, j] = pid
        self._tbl_dirty = True
        self.pages_peak = max(self.pages_peak, self.used_count)
        return fresh

    def free_slot(self, slot: int) -> int:
        """Recycle all of a slot's pages; returns how many were freed."""
        pages = self.slot_pages[slot]
        n = len(pages)
        self.free.extend(pages)
        self.slot_pages[slot] = []
        self.block_tables[slot, :] = 0
        self._tbl_dirty = True
        return n

    def device_tables(self, n_groups: int) -> jax.Array:
        """Block tables as a device array broadcast over layer groups."""
        if self._tbl_dirty or self._tbl_dev is None:
            tbl = jnp.asarray(self.block_tables)
            self._tbl_dev = jnp.broadcast_to(
                tbl[None], (n_groups,) + tbl.shape)
            self._tbl_dirty = False
        return self._tbl_dev

    # ---- device arena --------------------------------------------------
    def init_arena(self):
        """Fresh zeroed arena pytree (leading n_groups dim, +1 null page)."""
        return KV.paged_init_cache(self.cfg, self.n_pages + 1, self.page,
                                   self.max_slots, self.max_pages_per_seq,
                                   self.cache_dtype)

    def install_tables(self, arena):
        """Return arena with current block tables written into every group."""
        tbl = self.device_tables(self.cfg.n_groups)
        out = {}
        for key, grp in arena.items():
            grp = dict(grp)
            if "attn" in grp:
                attn = dict(grp["attn"])
                attn["block_tbl"] = tbl
                grp["attn"] = attn
            out[key] = grp
        return out


# -------------------------------------------------------------------------
# prefill adoption: contiguous batch-1 cache -> arena pages
# -------------------------------------------------------------------------
_CONTIG_TO_PAGED = (("k", "k_pages"), ("v", "v_pages"),
                    ("k_scale", "k_scale_pages"),
                    ("v_scale", "v_scale_pages"))


@functools.lru_cache(maxsize=None)
def make_adopt(cfg: ModelConfig, page: int):
    """jit'd (arena, contig_cache, page_ids, slot) -> arena.

    Copies a batch-1 contiguous prefill cache (bucket length T, a multiple
    of ``page``) into the arena pages listed in ``page_ids`` (length
    T//page; trailing ids may repeat the null page 0 when the prompt needs
    fewer pages than the bucket holds — null-page contents are never read).
    SSM/conv state is dense per-slot and lands in row ``slot``. One compile
    per prefill bucket length."""

    @jax.jit
    def adopt(arena, contig, page_ids, slot):
        out = {}
        for i, kind in enumerate(cfg.pattern):
            key = f"b{i}"
            grp = dict(arena[key])
            if "attn" in grp:
                attn = dict(grp["attn"])
                src = contig[key]["attn"]
                n = page_ids.shape[0]
                for c_name, p_name in _CONTIG_TO_PAGED:
                    if c_name not in src:
                        continue
                    s = src[c_name]                    # [G, 1, T, X]
                    g, _, t, x = s.shape
                    s = s.reshape(g, n, page, x)
                    attn[p_name] = attn[p_name].at[:, page_ids].set(s)
                grp["attn"] = attn
            if "mamba" in grp:
                mm = dict(grp["mamba"])
                src = contig[key]["mamba"]
                mm["ssm"] = mm["ssm"].at[:, slot].set(src["ssm"][:, 0])
                mm["conv"] = mm["conv"].at[:, slot].set(src["conv"][:, 0])
                grp["mamba"] = mm
            out[key] = grp
        return out

    return adopt


@functools.lru_cache(maxsize=None)
def make_bucketed_prefill(cfg: ModelConfig, cache_dtype=jnp.float32):
    """Returns prefill(params, tokens [1,T], valid_len [1]) ->

    (full_logits [1,T,V], cache). Unlike ``models.model.prefill`` this
    keeps the full logits so the caller can read the logit at the true
    (pre-padding) last prompt token — right padding is causally invisible
    to attention, and ``valid_len`` keeps the recurrent SSM state clean.
    Compiles once per bucket T."""
    from repro.models.model import forward

    @jax.jit
    def _prefill(params, tokens, valid_len):
        cache = KV.init_cache(cfg, 1, tokens.shape[1], cache_dtype)
        logits, new_cache, _ = forward(cfg, params, tokens, cache=cache,
                                       valid_len=valid_len)
        return logits, new_cache

    return _prefill
