"""Serving step builders — the ONE place jit/pjit step functions are built.

Both consumers of the serve subsystem go through this module:

  * ``serve.engine.ServeEngine`` (and ``launch/serve.py``, which drives it)
    uses :func:`build_paged_steps` — the paged continuous-batching step
    set: batched paged decode, bucketed contiguous prefill, suffix prefill
    straight into the arena, prefill-adopt, and the COW page copy.
  * ``launch/dryrun.py`` uses :func:`build_prefill` / :func:`build_decode`
    — the contiguous production-mesh cells it lowers and costs.

Every builder takes ``(cfg, mesh, params_struct)``. With ``mesh=None`` the
builders emit plain single-device ``jax.jit`` functions (byte-identical to
the pre-sharding engine closures, and lru-cached per config so engines
sharing a ModelConfig reuse XLA executables). With a mesh they emit jit
functions with **explicit input/output shardings**.

Sharding contract (what shards, what replicates)
------------------------------------------------
  * **Weights** — ``launch/sharding.py`` rules: TP dims on ``model``,
    the non-TP dim of large dense weights on ``data`` (FSDP-style);
    ShardedQTensor stream stacks shard their leading TP-shard dim on
    ``model`` and run TP-local through ``qmm_shard_map`` (the QMC
    serving format's quantize-after-shard contract).
  * **Paged KV arena** — the ``n_pages`` axis shards over ``data`` (each
    data shard owns a horizontal slice of the page pool), the fused
    ``kv_dim`` (and int8 scale head dim) over ``model``. See
    ``launch.sharding.paged_cache_spec``.
  * **Block tables** — replicated: any shard must resolve any logical
    position to a (possibly remote) page; GSPMD routes the cross-shard
    gather/scatter that results.
  * **Decode batch** — tokens/positions/logits shard batch over the dp
    axes when the slot count divides; batch-1 prefill paths replicate.
  * **SSM/conv state** — dense per-slot, batch on dp when divisible.

Arena buffers are donated on non-CPU backends (decode/suffix-prefill/
adopt/page-copy all rewrite the arena in place); the CPU backend cannot
donate and would warn on every call, so donation is disabled there.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import runtime_context as ctx
from repro.launch import mesh as meshlib
from repro.launch import sharding as shd
from repro.models import kvcache as KV
from repro.models.config import ModelConfig
from repro.models.model import decode_step as _decode
from repro.models.model import forward as _forward
from repro.models.model import prefill as _prefill


# ==========================================================================
# contiguous builders (dry-run cells)
# ==========================================================================
def cache_struct(cfg: ModelConfig, batch: int, max_len: int,
                 dtype=jnp.bfloat16):
    if cfg.is_encdec:
        return {"b0": jax.eval_shape(
            lambda: KV.init_encdec_cache(cfg, batch, max_len, dtype))}
    return jax.eval_shape(lambda: KV.init_cache(cfg, batch, max_len, dtype))


def build_prefill(cfg: ModelConfig, mesh, *, batch: int, seq: int,
                  cache_len: Optional[int] = None, params_struct=None,
                  scan_layers: bool = True):
    """Returns (fn, jit_fn). fn(params, tokens, extras...) ->

    (last_logits, cache)."""
    cache_len = cache_len or seq + cfg.n_vis_tokens

    def fn(params, tokens, extras):
        with ctx.use_mesh(mesh, meshlib.dp_axes(mesh)):
            return _prefill(cfg, params, tokens, max_len=cache_len,
                            vis_embeds=extras.get("vis_embeds"),
                            frames=extras.get("frames"),
                            scan_layers=scan_layers)

    def make_jit(params_struct, extras_struct=None):
        p_sh = shd.shard_params_tree(params_struct, mesh)
        t_sh = NamedSharding(mesh, shd.batch_spec(mesh, batch))
        c_struct = cache_struct(cfg, batch, cache_len)
        c_sh = shd.shard_cache_tree(c_struct, mesh, batch)
        l_sh = _logits2d(mesh, batch, cfg)
        e_sh = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, shd.batch_spec(mesh, batch)),
            extras_struct or {})
        return jax.jit(fn, in_shardings=(p_sh, t_sh, e_sh),
                       out_shardings=(l_sh, c_sh))
    return fn, make_jit


def build_decode(cfg: ModelConfig, mesh, *, batch: int, cache_len: int,
                 scan_layers: bool = True):
    """Returns (fn, make_jit). fn(params, token, cache, pos) ->

    (logits, cache). Cache is donated (in-place update)."""
    def fn(params, token, cache, pos):
        with ctx.use_mesh(mesh, meshlib.dp_axes(mesh)):
            return _decode(cfg, params, token, cache, pos,
                           scan_layers=scan_layers)

    def make_jit(params_struct):
        p_sh = shd.shard_params_tree(params_struct, mesh)
        t_sh = NamedSharding(mesh, shd.batch_spec(mesh, batch))
        c_struct = cache_struct(cfg, batch, cache_len)
        c_sh = shd.shard_cache_tree(c_struct, mesh, batch)
        l_sh = _logits2d(mesh, batch, cfg)
        pos_sh = NamedSharding(mesh, P())
        return jax.jit(fn,
                       in_shardings=(p_sh, t_sh, c_sh, pos_sh),
                       out_shardings=(l_sh, c_sh),
                       donate_argnums=(2,))
    return fn, make_jit


def _logits2d(mesh, batch: int, cfg) -> NamedSharding:
    """[B, V] sharding: batch on dp when divisible; vocab on model when

    divisible (odd vocabs like 92553 replicate)."""
    bs = shd.batch_spec(mesh, batch)
    b_ax = None
    if len(bs) >= 1:
        b_ax = bs[0] if len(bs) > 0 else None
    tp_n = meshlib.axis_size(mesh, "model")
    v_ax = "model" if ("model" in mesh.axis_names
                       and cfg.vocab % tp_n == 0) else None
    return NamedSharding(mesh, P(b_ax, v_ax))


@functools.lru_cache(maxsize=None)
def contiguous_decode(cfg: ModelConfig,
                      paged_attention: bool = False) -> Callable:
    """Single-device contiguous decode step (the legacy per-slot engine

    and the mesh-less paged engine share this executable): one jit per
    (ModelConfig, paged_attention) — the flag only changes how paged
    caches are read, contiguous caches trace identically."""
    return jax.jit(lambda p, t, c, pos: _decode(
        cfg, p, t, c, pos, paged_attention=paged_attention))


# ==========================================================================
# paged serving step set (ServeEngine + launch/serve.py)
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class PagedServeSteps:
    """The jitted step functions one paged engine instance runs, plus the

    geometry they were built for (the engine validates compatibility).

      decode(params, token [B,1], arena, pos [B]) -> (logits [B,V], arena)
      prefill(params, tokens [1,T], valid_len [1]) -> (logits [1,T,V],
          contiguous cache)                    (compiles once per bucket T)
      suffix_prefill(params, arena_slice, tokens [1,T], start [1],
          valid [1]) -> (logits [1,T,V], arena_slice)
      adopt(arena, contig_cache, page_ids, slot) -> arena
      page_copy(arena, src, dst) -> arena
    """
    cfg: ModelConfig
    mesh: Optional[object]
    page: int
    n_pages: int                     # usable pages (arena holds +1 null)
    max_slots: int
    max_pages_per_seq: int
    cache_dtype: object
    decode: Callable
    prefill: Callable
    suffix_prefill: Callable
    adopt: Callable
    page_copy: Callable
    paged_attention: bool = False    # decode via the Pallas paged kernel

    def compatible_with(self, *, page, n_pages, max_slots,
                        max_pages_per_seq, cache_dtype,
                        paged_attention=False) -> bool:
        return (self.page == page and self.n_pages == n_pages
                and self.max_slots == max_slots
                and self.max_pages_per_seq == max_pages_per_seq
                and self.cache_dtype == cache_dtype
                and self.paged_attention == paged_attention)


def default_n_pages(slots: int, max_pages_per_seq: int, mesh=None) -> int:
    """Default pool size: every slot at full length — rounded UP so the

    arena's total page count (usable + the null page) divides the mesh's
    ``data`` axis; otherwise ``paged_cache_spec`` would silently
    replicate the page axis and the sharded arena no-ops."""
    n = slots * max_pages_per_seq
    d = meshlib.axis_size(mesh, "data") if mesh is not None else 1
    if d > 1:
        n += (-(n + 1)) % d
    return n


def arena_struct(cfg: ModelConfig, *, n_pages: int, page: int,
                 max_slots: int, max_pages_per_seq: int,
                 cache_dtype=jnp.float32):
    """Abstract arena pytree (``n_pages`` usable pages + the null page)."""
    return jax.eval_shape(
        lambda: KV.paged_init_cache(cfg, n_pages + 1, page, max_slots,
                                    max_pages_per_seq, cache_dtype))


def _donate(argnums: Tuple[int, ...]) -> dict:
    """Arena donation kwargs — disabled on CPU, where XLA cannot alias

    buffers and jax warns on every call."""
    if jax.default_backend() == "cpu":
        return {}
    return {"donate_argnums": argnums}


def _logits3d(mesh, cfg) -> NamedSharding:
    """[1, T, V] prefill logits: batch-1 replicated, vocab on model."""
    tp_n = meshlib.axis_size(mesh, "model")
    v_ax = "model" if ("model" in mesh.axis_names
                       and cfg.vocab % tp_n == 0) else None
    return NamedSharding(mesh, P(None, None, v_ax))


def _contig_prefill_cache_shardings(cfg: ModelConfig, mesh,
                                    cache_dtype):
    """Sharding tree for the batch-1 bucketed-prefill cache.

    Bucket length T varies per compile, so only shape-independent dims
    shard: the fused kv_dim (and int8 scale head dim) on ``model``;
    batch-1 and the sequence dim replicate. Structure is T-independent, so
    one tree (built at a nominal T) serves every bucket."""
    struct = cache_struct(cfg, 1, 16, cache_dtype)
    tp_n = meshlib.axis_size(mesh, "model")

    def leaf_sharding(path, leaf):
        name = shd._path_str(path)
        last = leaf.shape[-1]
        ax = ("model" if ("model" in mesh.axis_names and tp_n > 1
                          and last % tp_n == 0
                          and (name.endswith("/k") or name.endswith("/v")
                               or name.endswith("_scale"))) else None)
        spec = [None] * leaf.ndim
        spec[-1] = ax
        return NamedSharding(mesh, P(*spec))

    flat, treedef = jax.tree_util.tree_flatten_with_path(struct)
    return jax.tree_util.tree_unflatten(
        treedef, [leaf_sharding(p, l) for p, l in flat])


def build_paged_steps(cfg: ModelConfig, mesh=None, params_struct=None, *,
                      page: int, n_pages: int, max_slots: int,
                      max_pages_per_seq: int,
                      cache_dtype=jnp.float32,
                      paged_attention: bool = False) -> PagedServeSteps:
    """Build the full paged serving step set for one engine geometry.

    ``mesh=None`` → plain single-device jit (lru-shared per config where
    the function is geometry-independent). With a mesh, every step runs
    under the runtime mesh context (so ShardedQTensor weights dispatch to
    ``qmm_shard_map`` and the paged gather/scatter picks up its sharding
    constraints) and carries explicit input/output shardings per the
    module-level contract; ``params_struct`` (a pytree of
    ShapeDtypeStructs matching the serving weights) is then required.

    ``paged_attention=True`` builds the decode step over the Pallas
    page-table kernel (``kernels/paged_attention.py``): only live pages
    stream per lane. Under a mesh the kernel runs shard-local (pages over
    ``data``, KV heads over ``model``, flash-decoding softmax merge) —
    the arena geometry must divide the mesh (``shard_compatible``), which
    ``default_n_pages`` guarantees for the page axis; unsupported
    geometries fall back to the XLA gather inside the traced step.
    """
    if mesh is None:
        return PagedServeSteps(
            cfg=cfg, mesh=None, page=page, n_pages=n_pages,
            max_slots=max_slots, max_pages_per_seq=max_pages_per_seq,
            cache_dtype=cache_dtype, paged_attention=paged_attention,
            decode=contiguous_decode(cfg, paged_attention),
            prefill=_bucketed_prefill_jit(cfg, cache_dtype),
            suffix_prefill=_suffix_prefill_jit(cfg),
            adopt=_adopt_jit(cfg, page),
            page_copy=_page_copy_jit(cfg))

    if params_struct is None:
        raise ValueError("sharded step builders need params_struct to "
                         "emit explicit input shardings")
    dp = meshlib.dp_axes(mesh)
    a_struct = arena_struct(cfg, n_pages=n_pages, page=page,
                            max_slots=max_slots,
                            max_pages_per_seq=max_pages_per_seq,
                            cache_dtype=cache_dtype)
    p_sh = shd.shard_params_tree(params_struct, mesh)
    a_sh = shd.shard_paged_cache_tree(a_struct, mesh)
    rep = NamedSharding(mesh, P())
    b_sh = NamedSharding(mesh, shd.batch_spec(mesh, max_slots))
    tok_sh = NamedSharding(mesh, P(*(tuple(shd.batch_spec(mesh, max_slots))
                                     + (None,))))
    l2_sh = _logits2d(mesh, max_slots, cfg)
    l3_sh = _logits3d(mesh, cfg)
    c_sh = _contig_prefill_cache_shardings(cfg, mesh, cache_dtype)

    # shared single-device bodies, traced under the mesh context so
    # matmul dispatch and the paged-cache sharding constraints see it
    prefill_body = _bucketed_prefill_body(cfg, cache_dtype)
    suffix_body = _suffix_prefill_body(cfg)

    def decode_fn(params, token, arena, pos):
        with ctx.use_mesh(mesh, dp):
            return _decode(cfg, params, token, arena, pos,
                           paged_attention=paged_attention)

    def prefill_fn(params, tokens, valid_len):
        with ctx.use_mesh(mesh, dp):
            return prefill_body(params, tokens, valid_len)

    def suffix_fn(params, arena, tokens, start, valid):
        with ctx.use_mesh(mesh, dp):
            return suffix_body(params, arena, tokens, start, valid)

    return PagedServeSteps(
        cfg=cfg, mesh=mesh, page=page, n_pages=n_pages,
        max_slots=max_slots, max_pages_per_seq=max_pages_per_seq,
        cache_dtype=cache_dtype, paged_attention=paged_attention,
        decode=jax.jit(decode_fn,
                       in_shardings=(p_sh, tok_sh, a_sh, b_sh),
                       out_shardings=(l2_sh, a_sh),
                       **_donate((2,))),
        prefill=jax.jit(prefill_fn,
                        in_shardings=(p_sh, rep, rep),
                        out_shardings=(l3_sh, c_sh)),
        suffix_prefill=jax.jit(suffix_fn,
                               in_shardings=(p_sh, a_sh, rep, rep, rep),
                               out_shardings=(l3_sh, a_sh),
                               **_donate((1,))),
        # adopt's contiguous-cache input varies per bucket T, so its
        # shardings are inherited from the prefill output; the arena
        # output is pinned to the arena contract
        adopt=jax.jit(_adopt_body(cfg, page), out_shardings=a_sh,
                      **_donate((0,))),
        page_copy=jax.jit(_page_copy_body(cfg),
                          in_shardings=(a_sh, rep, rep),
                          out_shardings=a_sh, **_donate((0,))))


# --------------------------------------------------------------------------
# step bodies (shared by the mesh-less lru-cached jits and the sharded
# builders above)
# --------------------------------------------------------------------------
_CONTIG_TO_PAGED = (("k", "k_pages"), ("v", "v_pages"),
                    ("k_scale", "k_scale_pages"),
                    ("v_scale", "v_scale_pages"))


def _adopt_body(cfg: ModelConfig, page: int):
    """(arena, contig_cache, page_ids, slot) -> arena.

    Copies a batch-1 contiguous prefill cache (bucket length T, a multiple
    of ``page``) into the arena pages listed in ``page_ids`` (length
    T//page; trailing ids may repeat the null page 0 when the prompt needs
    fewer pages than the bucket holds — null-page contents are never
    read). SSM/conv state is dense per-slot and lands in row ``slot``.
    One compile per prefill bucket length."""

    def adopt(arena, contig, page_ids, slot):
        out = {}
        for i, kind in enumerate(cfg.pattern):
            key = f"b{i}"
            grp = dict(arena[key])
            if "attn" in grp:
                attn = dict(grp["attn"])
                src = contig[key]["attn"]
                n = page_ids.shape[0]
                for c_name, p_name in _CONTIG_TO_PAGED:
                    if c_name not in src:
                        continue
                    s = src[c_name]                    # [G, 1, T, X]
                    g, _, t, x = s.shape
                    s = s.reshape(g, n, page, x)
                    attn[p_name] = attn[p_name].at[:, page_ids].set(s)
                grp["attn"] = attn
            if "mamba" in grp:
                mm = dict(grp["mamba"])
                src = contig[key]["mamba"]
                mm["ssm"] = mm["ssm"].at[:, slot].set(src["ssm"][:, 0])
                mm["conv"] = mm["conv"].at[:, slot].set(src["conv"][:, 0])
                grp["mamba"] = mm
            out[key] = grp
        return out

    return adopt


def _page_copy_body(cfg: ModelConfig):
    """(arena, src, dst) -> arena with page dst a copy of page src in

    every attention leaf of every group — the device half of
    ``PagedKVPool.cow`` (the host half swaps the block-table entry)."""

    def _copy(arena, src, dst):
        out = {}
        for i, kind in enumerate(cfg.pattern):
            key = f"b{i}"
            grp = dict(arena[key])
            if "attn" in grp:
                attn = dict(grp["attn"])
                for name, leaf in attn.items():
                    if name.endswith("_pages"):
                        attn[name] = leaf.at[:, dst].set(leaf[:, src])
                grp["attn"] = attn
            out[key] = grp
        return out

    return _copy


@functools.lru_cache(maxsize=None)
def _adopt_jit(cfg: ModelConfig, page: int):
    return jax.jit(_adopt_body(cfg, page))


@functools.lru_cache(maxsize=None)
def _page_copy_jit(cfg: ModelConfig):
    return jax.jit(_page_copy_body(cfg))


def _bucketed_prefill_body(cfg: ModelConfig, cache_dtype=jnp.float32):
    """prefill(params, tokens [1,T], valid_len [1]) ->

    (full_logits [1,T,V], cache). Unlike ``models.model.prefill`` this
    keeps the full logits so the caller can read the logit at the true
    (pre-padding) last prompt token — right padding is causally invisible
    to attention, and ``valid_len`` keeps the recurrent SSM state clean.
    Compiles once per bucket T."""

    def _bucketed(params, tokens, valid_len):
        cache = KV.init_cache(cfg, 1, tokens.shape[1], cache_dtype)
        logits, new_cache, _ = _forward(cfg, params, tokens, cache=cache,
                                        valid_len=valid_len)
        return logits, new_cache

    return _bucketed


def _suffix_prefill_body(cfg: ModelConfig):
    """suffix_prefill(params, arena_slice, tokens [1,T], start [1],
    valid [1]) -> (full_logits [1,T,V], arena_slice).

    Prefills an uncached prompt *suffix* directly against the paged arena:
    queries run at absolute positions ``start + t`` and attend the slot's
    whole block table, so cached prefix pages adopted by the prefix cache
    are visible without any contiguous round-trip. ``valid`` is the
    absolute position bound start + true_suffix_len: reads past it are
    masked and writes of right-padding bucket garbage are routed to the
    null page. ``arena_slice`` is the arena with ``block_tbl`` narrowed to
    the one admitting slot (batch 1). Compiles once per suffix bucket T."""

    def _suffix(params, arena, tokens, start, valid):
        t = tokens.shape[1]
        positions = start[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]
        logits, new_arena, _ = _forward(cfg, params, tokens,
                                        positions=positions, cache=arena,
                                        valid_len=valid)
        return logits, new_arena

    return _suffix


@functools.lru_cache(maxsize=None)
def _bucketed_prefill_jit(cfg: ModelConfig, cache_dtype=jnp.float32):
    return jax.jit(_bucketed_prefill_body(cfg, cache_dtype))


@functools.lru_cache(maxsize=None)
def _suffix_prefill_jit(cfg: ModelConfig):
    return jax.jit(_suffix_prefill_body(cfg))
