"""Serving step builders — the ONE place jit/pjit step functions are built.

Both consumers of the serve subsystem go through this module:

  * ``serve.engine.ServeEngine`` (and ``launch/serve.py``, which drives it)
    uses :func:`build_paged_steps` — the paged continuous-batching step
    set: ONE unified ragged step (chunked prefill + batched decode in the
    same jit), the COW page copy, and the per-slot SSM-state reset.
  * ``launch/dryrun.py`` uses :func:`build_prefill` / :func:`build_decode`
    — the contiguous production-mesh cells it lowers and costs.

Every builder takes ``(cfg, mesh, params_struct)``. With ``mesh=None`` the
builders emit plain single-device ``jax.jit`` functions (lru-shared per
FULL step geometry — cfg, page, pool/slot sizes, cache dtype, chunk and
the ``paged_attention`` flag are all part of the cache key, so a late
flag flip can never reuse a stale jit). With a mesh they emit jit
functions with **explicit input/output shardings**.

The unified step contract
-------------------------
  ``step(params, tokens [B, C], arena, start [B], n_new [B], sampling)
  -> (tokens [B, C] int32, logprobs [B, C] float32, arena)``

  Lane ``b`` runs ``n_new[b]`` new tokens at absolute positions
  ``start[b] + t``: a decode lane carries one token (``n_new = 1``), a
  prefill lane carries a chunk of its prompt (``1 <= n_new <= C``), and
  an idle lane carries ``n_new = 0`` (its writes route to the null page
  and its output rows are dead). K/V scatter straight into the paged
  arena and the ragged attention read happen inside the one traced
  function — there is no contiguous prefill cache and no adopt copy any
  more. The engine drives exactly two shapes per geometry: ``C = 1``
  (decode-only rounds) and ``C = chunk`` (rounds with a prefill chunk in
  flight), which is the whole compile surface — the pow2 bucket zoo is
  gone. ``reset_state(arena, slot)`` zeroes a slot's dense SSM/conv rows
  at admission (``None`` for attention-only stacks); ``page_copy`` is
  the device half of ``PagedKVPool.cow``.

  **Sampling head** — token selection is FUSED into the step: the raw
  ``[B, C, V]`` logits never cross the jit boundary (they used to feed
  a stray out-of-jit ``jnp.argmax`` dispatch per round, invisible to
  cost attribution). ``sampling`` is a pytree of traced ``[B]`` lane
  params — ``{"temp" f32, "top_k" i32, "top_p" f32, "key" [B,2] u32}``
  (see ``serve.sampling.lane_inputs``) — so one compile per width C
  serves every parameter combo. A ``temp <= 0`` lane takes the argmax
  path bitwise (greedy stays the oracle); sampled lanes draw via
  ``jax.random.categorical`` with top-k/top-p masks, per-column keys
  folded from the lane key + the token's absolute position (layout-
  independent streams — see ``serve/sampling.py`` for the full
  contract). The returned logprobs are the model-distribution
  log-softmax at the selected token; dead columns (at or past
  ``n_new[b]``) return ``sampling.DEAD_TOKEN`` = -1, never a vocab id.

  **Verify steps** (self-speculative decode) are the SAME step at the
  same rungs: a lane verifying k draft tokens runs ``n_new = 1 + k``
  through the smallest ``width_ladder`` rung covering it — column 0
  carries the last real token, columns 1..k the draft, and the
  selected-token row doubles as the per-column verdict
  (``serve/speculative.py``). Zero new compiled shapes; the dispatch
  lands in the step's ``C<rung>`` cost row like any prefill chunk.

  **Batched page-ops** — ``apply_page_ops(arena, copy_src [S],
  copy_dst [S], table_updates [S, P], reset_mask [S])`` coalesces ALL of
  a round's page maintenance into one jitted call: every COW page copy
  (vectors padded with 0 -> 0 null-page self-copies, which are no-ops),
  the device block-table rebuild (broadcast into every group's
  ``block_tbl`` leaf), and the admission SSM/conv state resets (masked
  zeroing). The engine queues copies/resets host-side during admit and
  flushes once before the step — and skips the call entirely on rounds
  where nothing changed (pure decode), so the admit path's serialized
  per-seat device round-trips collapse to at most one per round.
  ``page_copy``/``reset_state`` remain as the single-op forms.

  **Device-resident token carry** — the step's selected-token output is
  a device array with exactly the aval a ``C = 1`` dispatch consumes, so
  a pipelined engine may feed round N's ``tok`` straight back in as
  round N+1's ``tokens`` for pure-decode rounds
  (:func:`carry_decode_tokens`) without a host round-trip; host-uploaded
  tokens remain the path for prefill chunks, verify columns, and
  admission. Dead lanes carry ``DEAD_TOKEN`` and are masked by
  ``n_new = 0``, and the sampling keys fold from absolute positions
  only, so carried and re-uploaded tokens are bitwise interchangeable.

  **Solo-lane fast path** — ``solo_step(params, tokens [1, C], arena,
  slot, start [1], n_new [1])`` runs a round with exactly one live lane
  at batch width 1: the slot's ``block_tbl``/SSM/conv rows are
  dynamic-sliced out of the arena inside the jit, the unified step body
  runs at ``B = 1``, and the recurrent rows are scattered back (page
  leaves are global and pass through). ``slot`` is a traced scalar, so
  one compile per width C serves every slot. This is what keeps a
  prefix-cache leader prefill (one miss in flight, ``max_slots - 1``
  idle lanes) from paying the full batch width in dead compute.
  Single-device engines only; mesh engines keep the batched step.

Sharding contract (what shards, what replicates)
------------------------------------------------
  * **Weights** — ``launch/sharding.py`` rules: TP dims on ``model``,
    the non-TP dim of large dense weights on ``data`` (FSDP-style);
    ShardedQTensor stream stacks shard their leading TP-shard dim on
    ``model`` and run TP-local through ``qmm_shard_map`` (the QMC
    serving format's quantize-after-shard contract).
  * **Paged KV arena** — the ``n_pages`` axis shards over ``data`` (each
    data shard owns a horizontal slice of the page pool), the fused
    ``kv_dim`` (and int8 scale head dim) over ``model``. See
    ``launch.sharding.paged_cache_spec``.
  * **Block tables** — replicated: any shard must resolve any logical
    position to a (possibly remote) page; GSPMD routes the cross-shard
    gather/scatter that results.
  * **Step batch** — tokens/positions/logits shard batch over the dp
    axes when the slot count divides (prefill chunks ride the same
    batched step, so they shard with it).
  * **SSM/conv state** — dense per-slot, batch on dp when divisible.

Arena buffers are donated on non-CPU backends (the step, state reset and
page copy all rewrite the arena in place); the CPU backend cannot donate
and would warn on every call, so donation is disabled there.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import runtime_context as ctx
from repro.obs import costs as obs_costs
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.launch import mesh as meshlib
from repro.launch import sharding as shd
from repro.models import kvcache as KV
from repro.models.config import ModelConfig
from repro.models.model import decode_step as _decode
from repro.models.model import forward as _forward
from repro.models.model import prefill as _prefill
from repro.serve import sampling


# ==========================================================================
# contiguous builders (dry-run cells + legacy engine)
# ==========================================================================
def cache_struct(cfg: ModelConfig, batch: int, max_len: int,
                 dtype=jnp.bfloat16):
    if cfg.is_encdec:
        return {"b0": jax.eval_shape(
            lambda: KV.init_encdec_cache(cfg, batch, max_len, dtype))}
    return jax.eval_shape(lambda: KV.init_cache(cfg, batch, max_len, dtype))


def build_prefill(cfg: ModelConfig, mesh, *, batch: int, seq: int,
                  cache_len: Optional[int] = None, params_struct=None,
                  scan_layers: bool = True):
    """Returns (fn, jit_fn). fn(params, tokens, extras...) ->

    (last_logits, cache)."""
    cache_len = cache_len or seq + cfg.n_vis_tokens

    def fn(params, tokens, extras):
        with ctx.use_mesh(mesh, meshlib.dp_axes(mesh)):
            return _prefill(cfg, params, tokens, max_len=cache_len,
                            vis_embeds=extras.get("vis_embeds"),
                            frames=extras.get("frames"),
                            scan_layers=scan_layers)

    def make_jit(params_struct, extras_struct=None):
        p_sh = shd.shard_params_tree(params_struct, mesh)
        t_sh = NamedSharding(mesh, shd.batch_spec(mesh, batch))
        c_struct = cache_struct(cfg, batch, cache_len)
        c_sh = shd.shard_cache_tree(c_struct, mesh, batch)
        l_sh = _logits2d(mesh, batch, cfg)
        e_sh = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, shd.batch_spec(mesh, batch)),
            extras_struct or {})
        return jax.jit(fn, in_shardings=(p_sh, t_sh, e_sh),
                       out_shardings=(l_sh, c_sh))
    return fn, make_jit


def build_decode(cfg: ModelConfig, mesh, *, batch: int, cache_len: int,
                 scan_layers: bool = True):
    """Returns (fn, make_jit). fn(params, token, cache, pos) ->

    (logits, cache). Cache is donated (in-place update)."""
    def fn(params, token, cache, pos):
        with ctx.use_mesh(mesh, meshlib.dp_axes(mesh)):
            return _decode(cfg, params, token, cache, pos,
                           scan_layers=scan_layers)

    def make_jit(params_struct):
        p_sh = shd.shard_params_tree(params_struct, mesh)
        t_sh = NamedSharding(mesh, shd.batch_spec(mesh, batch))
        c_struct = cache_struct(cfg, batch, cache_len)
        c_sh = shd.shard_cache_tree(c_struct, mesh, batch)
        l_sh = _logits2d(mesh, batch, cfg)
        pos_sh = NamedSharding(mesh, P())
        return jax.jit(fn,
                       in_shardings=(p_sh, t_sh, c_sh, pos_sh),
                       out_shardings=(l_sh, c_sh),
                       donate_argnums=(2,))
    return fn, make_jit


def _logits2d(mesh, batch: int, cfg) -> NamedSharding:
    """[B, V] sharding: batch on dp when divisible; vocab on model when

    divisible (odd vocabs like 92553 replicate)."""
    bs = shd.batch_spec(mesh, batch)
    b_ax = None
    if len(bs) >= 1:
        b_ax = bs[0] if len(bs) > 0 else None
    tp_n = meshlib.axis_size(mesh, "model")
    v_ax = "model" if ("model" in mesh.axis_names
                       and cfg.vocab % tp_n == 0) else None
    return NamedSharding(mesh, P(b_ax, v_ax))


@functools.lru_cache(maxsize=None)
def contiguous_decode(cfg: ModelConfig) -> Callable:
    """Single-device contiguous decode step (the legacy per-slot engine's

    executable): one jit per ModelConfig."""
    return jax.jit(lambda p, t, c, pos: _decode(cfg, p, t, c, pos))


# ==========================================================================
# jit observability: compile / retrace counters around every serving jit
# ==========================================================================
class TracedJit:
    """Callable wrapper over a serving jit that detects (re)compiles.

    Every call snapshots the underlying jit's executable-cache size
    (``_cache_size``); growth across a call means that call traced a new
    shape — the call's wall time is attributed to compile, a
    ``jit/compile`` instant fires on the process tracer, and
    ``serve_jit_compiles_total{fn}`` increments on the process registry.

    ``expected_shapes`` declares this wrapper's compile surface — the
    number of distinct shapes ONE engine should ever drive through it
    (the unified step compiles C ∈ {1, chunk}, so 2). Compiles beyond it
    raise ``serve_jit_retraces_unexpected_total{fn}`` and a
    ``jit/unexpected_retrace`` instant: the late-flag-flip / geometry-
    drift bug class becomes a visible metric instead of a silent 10x
    round stall. Counters are per wrapper (one per
    :func:`build_paged_steps` call), so engines sharing an lru-cached
    warm jit correctly count zero compiles of their own.

    With cost capture on (``obs.costs.enable_capture``) the wrapper also
    keeps per-call-shape tables for the attribution layer: the first
    call of each shape AOT-lowers it (BEFORE execution — donated buffers
    are still live) and records ``cost_analysis()`` FLOPs/bytes in
    ``cost_by_key``; every call then lands in ``calls_by_key`` /
    ``seconds_by_key``, measured synchronously (``block_until_ready``
    inside the timed window, so the table holds device time rather than
    async dispatch time), and emits a cumulative ``cost/<fn>`` Perfetto
    counter track. ``cost_key(args, kw) -> str`` names the shape (the
    unified step keys on its token width C); default one key, "call".
    Capture keys on shapes this WRAPPER has seen, not on jit-cache
    growth, so fresh engines over an lru-warm jit still capture.
    Capture off — the default — costs one module-bool branch per call.
    """

    def __init__(self, name: str, fn: Callable,
                 expected_shapes: Optional[int] = None,
                 cost_key: Optional[Callable] = None):
        self.name = name
        self._fn = fn
        self.expected_shapes = expected_shapes
        self.calls = 0
        self.compiles = 0
        self.compile_seconds = 0.0
        self._cost_key = cost_key
        self.cost_by_key: dict = {}      # key -> {"flops", "bytes"}/call
        self.calls_by_key: dict = {}
        self.seconds_by_key: dict = {}
        self._cum_flops = 0.0
        self._cum_bytes = 0.0

    def _cache_size(self) -> Optional[int]:
        try:
            return self._fn._cache_size()
        except Exception:
            return None        # non-jit callable or a jax without the API

    def __call__(self, *args, **kw):
        capture = obs_costs.capture_enabled()
        if capture:
            try:
                key = self._cost_key(args, kw) if self._cost_key \
                    else "call"
            except Exception:
                key = "call"
            if key not in self.cost_by_key:
                self.cost_by_key[key] = obs_costs.capture_costs(
                    self._fn, args, kw)
        before = self._cache_size()
        t0 = time.perf_counter()
        out = self._fn(*args, **kw)
        if capture:
            try:
                jax.block_until_ready(out)
            except Exception:
                pass           # non-array outputs: wall stays dispatch time
        dt = time.perf_counter() - t0
        self.calls += 1
        if capture:
            self.calls_by_key[key] = self.calls_by_key.get(key, 0) + 1
            self.seconds_by_key[key] = \
                self.seconds_by_key.get(key, 0.0) + dt
            cost = self.cost_by_key[key]
            self._cum_flops += cost["flops"]
            self._cum_bytes += cost["bytes"]
            if self._cum_flops or self._cum_bytes:
                obs_trace.get_tracer().counter(
                    f"cost/{self.name}", flops=self._cum_flops,
                    bytes=self._cum_bytes)
        after = self._cache_size()
        if before is not None and after is not None and after > before:
            grew = after - before
            self.compiles += grew
            self.compile_seconds += dt
            trc = obs_trace.get_tracer()
            trc.instant("jit/compile", fn=self.name, cache_size=after,
                        seconds=dt)
            reg = obs_metrics.get_registry()
            reg.counter(
                "serve_jit_compiles_total",
                "serving-jit executable-cache growth events",
                labels=("fn",)).inc(grew, fn=self.name)
            if self.expected_shapes is not None \
                    and self.compiles > self.expected_shapes:
                over = min(grew,
                           self.compiles - self.expected_shapes)
                trc.instant("jit/unexpected_retrace", fn=self.name,
                            compiles=self.compiles,
                            expected=self.expected_shapes)
                reg.counter(
                    "serve_jit_retraces_unexpected_total",
                    "compiles beyond a step's declared compile surface",
                    labels=("fn",)).inc(over, fn=self.name)
        return out


def _step_cost_key(args, kw) -> str:
    """Call-shape key for the unified step's cost tables: its token
    width C (``tokens`` is positional arg 1) — the engine drives C = 1
    plus ``width_ladder`` rungs (prefill chunks AND speculative verify
    steps alike), so the attribution table gets one row per width."""
    return f"C{args[1].shape[1]}"


def carry_decode_tokens(prev_tok, slot=None):
    """Device-resident token carry for pipelined pure-decode rounds.

    ``prev_tok`` is the previous step's on-device selected-token output
    (``[B, 1]`` int32 from the batched step, ``[1, 1]`` from
    ``solo_step``); the returned array feeds the NEXT dispatch's
    ``tokens`` argument directly, so steady-state decode tokens never
    round-trip through host. ``slot=None`` keeps the full batch (the
    batched step reads its own lane rows; dead lanes carry
    ``sampling.DEAD_TOKEN`` and are masked by ``n_new = 0``).
    Passing ``slot`` slices lane ``slot``'s row out for a ``solo_step``
    dispatch — a no-op when the previous round was itself solo (same
    single live lane, the ``[1, 1]`` output passes straight through;
    the engine drains the pipeline on any lane-set change, so the solo
    lane's identity is stable while carried). Either way the result has
    the same aval as the host-uploaded tokens of the matching width, so
    the carry never adds a compiled shape."""
    if slot is None or prev_tok.shape[0] == 1:
        return prev_tok
    return jax.lax.dynamic_slice_in_dim(prev_tok, int(slot), 1, axis=0)


# ==========================================================================
# paged serving step set (ServeEngine + launch/serve.py)
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class PagedServeSteps:
    """The jitted step functions one paged engine instance runs, plus the

    geometry they were built for (the engine validates compatibility).

      step(params, tokens [B,C], arena, start [B], n_new [B], sampling)
          -> (tok [B,C], logp [B,C], arena)
          (compiles once per C in {1} + width_ladder(chunk); token
          selection is fused — raw logits never leave the jit)
      page_copy(arena, src, dst) -> arena
      reset_state(arena, slot) -> arena    (None for attention-only cfgs)
      apply_page_ops(arena, copy_src [S], copy_dst [S],
                     table_updates [S,P], reset_mask [S]) -> arena
          (one fused call per round: COW copies + table rebuild + resets)
      solo_step(params, tokens [1,C], arena, slot, start [1], n_new [1],
                sampling) -> (tok [1,C], logp [1,C], arena)
          (single-live-lane rounds at B=1; None under a mesh — compiles
          once per C, slot is traced)
    """
    cfg: ModelConfig
    mesh: Optional[object]
    page: int
    n_pages: int                     # usable pages (arena holds +1 null)
    max_slots: int
    max_pages_per_seq: int
    cache_dtype: object
    chunk: int                       # prefill chunk width (the C > 1 shape)
    step: Callable
    page_copy: Callable
    reset_state: Optional[Callable] = None
    apply_page_ops: Optional[Callable] = None
    solo_step: Optional[Callable] = None
    paged_attention: bool = False    # attention via the ragged Pallas kernel

    def compatible_with(self, *, page, n_pages, max_slots,
                        max_pages_per_seq, cache_dtype, chunk,
                        paged_attention=False) -> bool:
        return (self.page == page and self.n_pages == n_pages
                and self.max_slots == max_slots
                and self.max_pages_per_seq == max_pages_per_seq
                and self.cache_dtype == cache_dtype
                and self.chunk == chunk
                and self.paged_attention == paged_attention)

    def jit_counters(self) -> Tuple[int, int, float]:
        """Aggregate (calls, compiles, compile_seconds) over this step
        set's :class:`TracedJit` members — the engine diffs these around
        a run to attribute compile time in ``EngineStats``."""
        calls = compiles = 0
        seconds = 0.0
        for fn in (self.step, self.page_copy, self.reset_state,
                   self.apply_page_ops, self.solo_step):
            if isinstance(fn, TracedJit):
                calls += fn.calls
                compiles += fn.compiles
                seconds += fn.compile_seconds
        return calls, compiles, seconds


def width_ladder(chunk: int) -> tuple:
    """Compiled ``C > 1`` step widths: pow2 rungs from 4 up to ``chunk``.

    A short prefill chunk — a cached-prefix suffix, a prompt tail —
    runs at the smallest rung that covers it instead of the full chunk:
    device time scales with the padded width, so the prefix cache's
    saved tokens only turn into saved wall clock if the step width
    shrinks with them. The 4 rung exists for short speculative verify
    steps (``1 + k`` columns at k < 7 used to pad all the way to 8);
    the rung floor and pow2 spacing bound the compile surface to
    log2(chunk/4) + 2 shapes per engine geometry (lru-shared across
    engines), so this stays a ladder, not a zoo."""
    if chunk <= 1:
        return ()
    w, out = 4, []
    while w < chunk:
        out.append(w)
        w *= 2
    out.append(chunk)
    return tuple(out)


def default_chunk(max_pages_per_seq: int, page: int) -> int:
    """Default prefill chunk width: the pow2 that covers the longest
    admissible sequence, so every prompt is a single chunk ("monolithic"
    prefill through the same ragged path). THE one copy of this rule —
    the builder, ``ServeEngine`` and ``launch/serve.py`` must agree or
    ``compatible_with`` rejects the step set."""
    from repro.serve.scheduler import bucket_len
    return bucket_len(max_pages_per_seq * page, page)


def default_n_pages(slots: int, max_pages_per_seq: int, mesh=None) -> int:
    """Default pool size: every slot at full length — rounded UP so the

    arena's total page count (usable + the null page) divides the mesh's
    ``data`` axis; otherwise ``paged_cache_spec`` would silently
    replicate the page axis and the sharded arena no-ops."""
    n = slots * max_pages_per_seq
    d = meshlib.axis_size(mesh, "data") if mesh is not None else 1
    if d > 1:
        n += (-(n + 1)) % d
    return n


def arena_struct(cfg: ModelConfig, *, n_pages: int, page: int,
                 max_slots: int, max_pages_per_seq: int,
                 cache_dtype=jnp.float32):
    """Abstract arena pytree (``n_pages`` usable pages + the null page)."""
    return jax.eval_shape(
        lambda: KV.paged_init_cache(cfg, n_pages + 1, page, max_slots,
                                    max_pages_per_seq, cache_dtype))


def _donate(argnums: Tuple[int, ...]) -> dict:
    """Arena donation kwargs — disabled on CPU, where XLA cannot alias

    buffers and jax warns on every call."""
    if jax.default_backend() == "cpu":
        return {}
    return {"donate_argnums": argnums}


def build_paged_steps(cfg: ModelConfig, mesh=None, params_struct=None, *,
                      page: int, n_pages: int, max_slots: int,
                      max_pages_per_seq: int,
                      cache_dtype=jnp.float32,
                      chunk: Optional[int] = None,
                      paged_attention: bool = False) -> PagedServeSteps:
    """Build the full paged serving step set for one engine geometry.

    ``chunk`` is the prefill chunk width (the ``C > 1`` step shape); the
    default — the pow2 that covers a full-length sequence — makes every
    prompt a single chunk ("monolithic" prefill through the same ragged
    path), matching ``ServeEngine``'s default.

    ``mesh=None`` → plain single-device jit, lru-shared per FULL geometry
    (every keyword above is part of the cache key). With a mesh, every
    step runs under the runtime mesh context (so ShardedQTensor weights
    dispatch to ``qmm_shard_map`` and the paged gather/scatter picks up
    its sharding constraints) and carries explicit input/output shardings
    per the module-level contract; ``params_struct`` (a pytree of
    ShapeDtypeStructs matching the serving weights) is then required.

    ``paged_attention=True`` runs the step's attention through the ragged
    Pallas page-table kernel (``kernels/paged_attention.py``): only
    causally-live pages stream per lane, for decode tokens and prefill
    chunks alike. Under a mesh the kernel runs shard-local (pages over
    ``data``, KV heads over ``model``, flash-decoding softmax merge) —
    the arena geometry must divide the mesh (``shard_compatible``), which
    ``default_n_pages`` guarantees for the page axis; unsupported
    geometries fall back to the XLA gather inside the traced step.
    """
    if chunk is None:
        chunk = default_chunk(max_pages_per_seq, page)
    # one engine drives the decode width (C = 1) plus the pow2 prefill
    # width ladder (``width_ladder``) and a single shape through
    # page_copy/reset — that is each wrapper's declared compile surface
    step_shapes = len(width_ladder(chunk)) + 1
    if mesh is None:
        step, page_copy, reset, apply_ops, solo = _single_device_steps(
            cfg, page, n_pages, max_slots, max_pages_per_seq,
            cache_dtype, chunk, paged_attention)
        return PagedServeSteps(
            cfg=cfg, mesh=None, page=page, n_pages=n_pages,
            max_slots=max_slots, max_pages_per_seq=max_pages_per_seq,
            cache_dtype=cache_dtype, chunk=chunk,
            paged_attention=paged_attention,
            step=TracedJit("step", step, step_shapes,
                           cost_key=_step_cost_key),
            page_copy=TracedJit("page_copy", page_copy, 1),
            reset_state=(None if reset is None
                         else TracedJit("reset_state", reset, 1)),
            apply_page_ops=TracedJit("apply_page_ops", apply_ops, 1),
            solo_step=TracedJit("solo_step", solo, step_shapes,
                                cost_key=_step_cost_key))

    if params_struct is None:
        raise ValueError("sharded step builders need params_struct to "
                         "emit explicit input shardings")
    dp = meshlib.dp_axes(mesh)
    a_struct = arena_struct(cfg, n_pages=n_pages, page=page,
                            max_slots=max_slots,
                            max_pages_per_seq=max_pages_per_seq,
                            cache_dtype=cache_dtype)
    p_sh = shd.shard_params_tree(params_struct, mesh)
    a_sh = shd.shard_paged_cache_tree(a_struct, mesh)
    rep = NamedSharding(mesh, P())
    b_sh = NamedSharding(mesh, shd.batch_spec(mesh, max_slots))
    tok_sh = NamedSharding(mesh, P(*(tuple(shd.batch_spec(mesh, max_slots))
                                     + (None,))))
    # traced sampling lane params: [B] knobs shard with the batch, the
    # [B, 2] raw key rides the token spec; [B, C] outputs likewise
    samp_sh = {"temp": b_sh, "top_k": b_sh, "top_p": b_sh, "key": tok_sh}
    step_body = _step_body(cfg, paged_attention)

    def step_fn(params, tokens, arena, start, n_new, samp):
        with ctx.use_mesh(mesh, dp):
            return step_body(params, tokens, arena, start, n_new, samp)

    reset = None
    if any(k == "mamba" or k.startswith("hybrid") for k in cfg.pattern):
        reset = TracedJit(
            "reset_state",
            jax.jit(_reset_state_body(cfg),
                    in_shardings=(a_sh, rep), out_shardings=a_sh,
                    **_donate((0,))), 1)
    return PagedServeSteps(
        cfg=cfg, mesh=mesh, page=page, n_pages=n_pages,
        max_slots=max_slots, max_pages_per_seq=max_pages_per_seq,
        cache_dtype=cache_dtype, chunk=chunk,
        paged_attention=paged_attention,
        step=TracedJit(
            "step",
            jax.jit(step_fn,
                    in_shardings=(p_sh, tok_sh, a_sh, b_sh, b_sh, samp_sh),
                    out_shardings=(tok_sh, tok_sh, a_sh),
                    **_donate((2,))), step_shapes,
            cost_key=_step_cost_key),
        page_copy=TracedJit(
            "page_copy",
            jax.jit(_page_copy_body(cfg),
                    in_shardings=(a_sh, rep, rep),
                    out_shardings=a_sh, **_donate((0,))), 1),
        reset_state=reset,
        apply_page_ops=TracedJit(
            "apply_page_ops",
            jax.jit(_apply_page_ops_body(cfg),
                    in_shardings=(a_sh, rep, rep, rep, rep),
                    out_shardings=a_sh, **_donate((0,))), 1),
        solo_step=None)


# --------------------------------------------------------------------------
# step bodies (shared by the mesh-less lru-cached jits and the sharded
# builders above)
# --------------------------------------------------------------------------
def _step_body(cfg: ModelConfig, paged_attention: bool):
    """The ONE serving step: ragged chunked prefill + batched decode.

    ``tokens [B, C]`` are lane-local new tokens; lane ``b`` runs its
    first ``n_new[b]`` columns at absolute positions ``start[b] + t``.
    ``valid_len = start + n_new`` masks reads past each lane's bound,
    routes right-padding K/V writes to the null page, and (converted to
    a relative count inside ``blocks.apply_block``) keeps recurrent SSM
    state clean for idle and padded lanes. The fused
    ``sampling.select_tokens`` epilogue turns the logits into selected
    token ids + logprobs before anything leaves the jit."""

    def step(params, tokens, arena, start, n_new, samp):
        c = tokens.shape[1]
        positions = start[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
        valid = start + n_new
        logits, new_arena, _ = _forward(cfg, params, tokens,
                                        positions=positions, cache=arena,
                                        valid_len=valid,
                                        paged_attention=paged_attention)
        tok, logp = sampling.select_tokens(
            logits, samp["temp"], samp["top_k"], samp["top_p"],
            samp["key"], positions, n_new)
        return tok, logp, new_arena

    return step


def _page_copy_body(cfg: ModelConfig):
    """(arena, src, dst) -> arena with page dst a copy of page src in

    every attention leaf of every group — the device half of
    ``PagedKVPool.cow`` (the host half swaps the block-table entry)."""

    def _copy(arena, src, dst):
        out = {}
        for i, kind in enumerate(cfg.pattern):
            key = f"b{i}"
            grp = dict(arena[key])
            if "attn" in grp:
                attn = dict(grp["attn"])
                for name, leaf in attn.items():
                    if name.endswith("_pages"):
                        attn[name] = leaf.at[:, dst].set(leaf[:, src])
                grp["attn"] = attn
            out[key] = grp
        return out

    return _copy


def _apply_page_ops_body(cfg: ModelConfig):
    """(arena, copy_src [S], copy_dst [S], table_updates [S, P],
    reset_mask [S]) -> arena: one round's page maintenance, fused.

    Copy vectors are padded with 0 -> 0 null-page self-copies (real COW
    destinations are freshly allocated and distinct, so duplicate-index
    scatter writes only ever collide on the identity no-op).
    ``table_updates`` is the host block table, broadcast into every
    group's ``block_tbl`` leaf; ``reset_mask`` zeroes freshly admitted
    slots' dense SSM/conv rows."""

    def _apply(arena, copy_src, copy_dst, tables, reset_mask):
        out = {}
        for i, kind in enumerate(cfg.pattern):
            key = f"b{i}"
            grp = dict(arena[key])
            if "attn" in grp:
                attn = dict(grp["attn"])
                for name, leaf in attn.items():
                    if name.endswith("_pages"):
                        attn[name] = leaf.at[:, copy_dst].set(
                            leaf[:, copy_src])
                g = attn["block_tbl"].shape[0]
                attn["block_tbl"] = jnp.broadcast_to(
                    tables.astype(jnp.int32)[None],
                    (g,) + tables.shape)
                grp["attn"] = attn
            if "mamba" in grp:
                mm = dict(grp["mamba"])
                for name in ("ssm", "conv"):
                    leaf = mm[name]
                    mask = reset_mask.reshape(
                        (1, -1) + (1,) * (leaf.ndim - 2))
                    mm[name] = jnp.where(mask, jnp.zeros((), leaf.dtype),
                                         leaf)
                grp["mamba"] = mm
            out[key] = grp
        return out

    return _apply


def _solo_step_body(cfg: ModelConfig, paged_attention: bool):
    """Single-live-lane round at batch width 1 (see module docstring).

    The slot's per-slot rows (``block_tbl``, SSM, conv) are dynamic-
    sliced into a B=1 view, the unified step body runs on the view, and
    the recurrent rows scatter back; page leaves are global, so the
    step's K/V writes land in the real arena pages directly. The block
    table is read-only inside the step, so the full-width original is
    kept on the way out."""
    step = _step_body(cfg, paged_attention)

    def solo(params, tokens, arena, slot, start, n_new, samp):
        view = {}
        for i, kind in enumerate(cfg.pattern):
            key = f"b{i}"
            grp = dict(arena[key])
            if "attn" in grp:
                attn = dict(grp["attn"])
                attn["block_tbl"] = jax.lax.dynamic_slice_in_dim(
                    attn["block_tbl"], slot, 1, axis=1)
                grp["attn"] = attn
            if "mamba" in grp:
                mm = dict(grp["mamba"])
                mm["ssm"] = jax.lax.dynamic_slice_in_dim(
                    mm["ssm"], slot, 1, axis=1)
                mm["conv"] = jax.lax.dynamic_slice_in_dim(
                    mm["conv"], slot, 1, axis=1)
                grp["mamba"] = mm
            view[key] = grp
        tok, logp, stepped = step(params, tokens, view, start, n_new, samp)
        out = {}
        for i, kind in enumerate(cfg.pattern):
            key = f"b{i}"
            grp = dict(arena[key])
            sg = stepped[key]
            if "attn" in grp:
                attn = dict(grp["attn"])
                for name, leaf in sg["attn"].items():
                    if name.endswith("_pages"):
                        attn[name] = leaf
                grp["attn"] = attn
            if "mamba" in grp:
                mm = dict(grp["mamba"])
                mm["ssm"] = jax.lax.dynamic_update_slice_in_dim(
                    mm["ssm"], sg["mamba"]["ssm"], slot, axis=1)
                mm["conv"] = jax.lax.dynamic_update_slice_in_dim(
                    mm["conv"], sg["mamba"]["conv"], slot, axis=1)
                grp["mamba"] = mm
            out[key] = grp
        return tok, logp, out

    return solo


def _reset_state_body(cfg: ModelConfig):
    """(arena, slot) -> arena with the slot's dense SSM/conv rows zeroed.

    A freshly admitted slot's recurrent state must start from zero — the
    chunked prefill accumulates it in place (there is no per-admission
    contiguous cache to adopt from any more), and the row may hold a
    previous occupant's garbage."""

    def _reset(arena, slot):
        out = {}
        for i, kind in enumerate(cfg.pattern):
            key = f"b{i}"
            grp = dict(arena[key])
            if "mamba" in grp:
                mm = dict(grp["mamba"])
                mm["ssm"] = mm["ssm"].at[:, slot].set(0.0)
                mm["conv"] = mm["conv"].at[:, slot].set(0.0)
                grp["mamba"] = mm
            out[key] = grp
        return out

    return _reset


@functools.lru_cache(maxsize=None)
def _single_device_steps(cfg: ModelConfig, page: int, n_pages: int,
                         max_slots: int, max_pages_per_seq: int,
                         cache_dtype, chunk: int, paged_attention: bool):
    """Single-device jits, cached on the FULL step geometry.

    The key is exactly the tuple ``PagedServeSteps.compatible_with``
    checks — a flag (or geometry knob) passed late can never silently
    reuse a jit traced for a different configuration."""
    step = jax.jit(_step_body(cfg, paged_attention))
    page_copy = jax.jit(_page_copy_body(cfg))
    apply_ops = jax.jit(_apply_page_ops_body(cfg))
    solo = jax.jit(_solo_step_body(cfg, paged_attention))
    reset = None
    if any(k == "mamba" or k.startswith("hybrid") for k in cfg.pattern):
        reset = jax.jit(_reset_state_body(cfg))
    return step, page_copy, reset, apply_ops, solo
