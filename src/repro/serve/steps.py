"""Serving step builders: pjit'd prefill and decode with sharded caches

and QMC-quantized weights (the paper's deployment configuration).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import runtime_context as ctx
from repro.launch import mesh as meshlib
from repro.launch import sharding as shd
from repro.models import kvcache as KV
from repro.models.config import ModelConfig
from repro.models.model import decode_step as _decode
from repro.models.model import prefill as _prefill


def cache_struct(cfg: ModelConfig, batch: int, max_len: int,
                 dtype=jnp.bfloat16):
    if cfg.is_encdec:
        return {"b0": jax.eval_shape(
            lambda: KV.init_encdec_cache(cfg, batch, max_len, dtype))}
    return jax.eval_shape(lambda: KV.init_cache(cfg, batch, max_len, dtype))


def build_prefill(cfg: ModelConfig, mesh, *, batch: int, seq: int,
                  cache_len: Optional[int] = None, params_struct=None,
                  scan_layers: bool = True):
    """Returns (fn, jit_fn). fn(params, tokens, extras...) ->

    (last_logits, cache)."""
    cache_len = cache_len or seq + cfg.n_vis_tokens

    def fn(params, tokens, extras):
        with ctx.use_mesh(mesh, meshlib.dp_axes(mesh)):
            return _prefill(cfg, params, tokens, max_len=cache_len,
                            vis_embeds=extras.get("vis_embeds"),
                            frames=extras.get("frames"),
                            scan_layers=scan_layers)

    def make_jit(params_struct, extras_struct=None):
        p_sh = shd.shard_params_tree(params_struct, mesh)
        t_sh = NamedSharding(mesh, shd.batch_spec(mesh, batch))
        c_struct = cache_struct(cfg, batch, cache_len)
        c_sh = shd.shard_cache_tree(c_struct, mesh, batch)
        l_sh = _logits2d(mesh, batch, cfg)
        e_sh = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, shd.batch_spec(mesh, batch)),
            extras_struct or {})
        return jax.jit(fn, in_shardings=(p_sh, t_sh, e_sh),
                       out_shardings=(l_sh, c_sh))
    return fn, make_jit


def build_decode(cfg: ModelConfig, mesh, *, batch: int, cache_len: int,
                 scan_layers: bool = True):
    """Returns (fn, make_jit). fn(params, token, cache, pos) ->

    (logits, cache). Cache is donated (in-place update)."""
    def fn(params, token, cache, pos):
        with ctx.use_mesh(mesh, meshlib.dp_axes(mesh)):
            return _decode(cfg, params, token, cache, pos,
                           scan_layers=scan_layers)

    def make_jit(params_struct):
        p_sh = shd.shard_params_tree(params_struct, mesh)
        t_sh = NamedSharding(mesh, shd.batch_spec(mesh, batch))
        c_struct = cache_struct(cfg, batch, cache_len)
        c_sh = shd.shard_cache_tree(c_struct, mesh, batch)
        l_sh = _logits2d(mesh, batch, cfg)
        pos_sh = NamedSharding(mesh, P())
        return jax.jit(fn,
                       in_shardings=(p_sh, t_sh, c_sh, pos_sh),
                       out_shardings=(l_sh, c_sh),
                       donate_argnums=(2,))
    return fn, make_jit


def _logits2d(mesh, batch: int, cfg) -> NamedSharding:
    """[B, V] sharding: batch on dp when divisible; vocab on model when

    divisible (odd vocabs like 92553 replicate)."""
    bs = shd.batch_spec(mesh, batch)
    b_ax = None
    if len(bs) >= 1:
        b_ax = bs[0] if len(bs) > 0 else None
    tp_n = meshlib.axis_size(mesh, "model")
    v_ax = "model" if ("model" in mesh.axis_names
                       and cfg.vocab % tp_n == 0) else None
    return NamedSharding(mesh, P(b_ax, v_ax))
