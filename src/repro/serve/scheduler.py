"""Admission scheduling for the paged continuous-batching engine.

Policy (deliberately simple, the paper's edge target is one device):

  * **FIFO admission** — queued requests enter decode slots in arrival
    order; a request is admitted only when a slot is free AND the pool can
    cover its prompt pages.
  * **Token-budget prefill bucketing** — prompts are right-padded to
    power-of-2 lengths (floored at one page) so the jit'd prefill compiles
    for a bounded set of shapes, and each admission round prefills at most
    ``max_prefill_tokens`` padded tokens so a burst of long prompts
    cannot starve in-flight decodes (continuous batching's
    prefill/decode interleave knob).
  * **Preemption on pool exhaustion** — when a running sequence needs its
    next page and the free list is empty, the *youngest* admitted slot is
    evicted (recompute-style: its pages are freed and the request re-enters
    the queue head to be prefilled again later). Youngest-first preserves
    FIFO completion order and, under greedy decoding, restarting is
    output-identical.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.serve.paged_kv import pages_for


def bucket_len(n: int, page: int) -> int:
    """Smallest power of two >= max(n, page).

    ``page`` is itself a power of two, so every bucket is a whole number of
    pages — the invariant the prefill-adopt copy relies on."""
    b = page
    while b < n:
        b <<= 1
    return b


@dataclasses.dataclass
class SchedulerConfig:
    page: int = 16
    max_prefill_tokens: int = 512     # padded prefill tokens per round
    max_len: int = 256                # per-sequence logical capacity


@dataclasses.dataclass
class Admission:
    """One admitted request plus its prefix-cache split.

    ``cached_pages`` alias the index's pages for the first ``cached_len``
    prompt tokens (whole pages; empty on a miss). ``suffix_start`` is where
    prefill must actually run from — ``cached_len``, except for a
    whole-prompt hit where it is ``len(prompt) - 1`` so the final token's
    logit is recomputed (its KV write COWs the shared page it lands in).
    ``dedup`` marks an in-flight dedup: the pages alias a *live slot's*
    prompt pages (an identical prompt admitted earlier in this run) rather
    than the radix index's."""
    req: object
    cached_pages: List[int] = dataclasses.field(default_factory=list)
    cached_len: int = 0
    dedup: bool = False
    first_in_round: bool = False     # budget-exempt (anti-deadlock rule)

    @property
    def suffix_start(self) -> int:
        return min(self.cached_len, len(self.req.prompt) - 1)


class FifoScheduler:
    """FIFO queue + per-round prefill token budget + preemption policy.

    With a ``prefix_cache``, admission matches the head request's prompt
    against the radix index and hands the engine an :class:`Admission`
    split — the prefill token budget and the pool-capacity check are then
    charged only for the uncached suffix (still pow2-bucketed).

    **In-flight dedup** (``pool`` given): a *pending-prefill table* maps
    each prompt currently occupying a slot to that leader slot. When the
    queue head's prompt is identical to a pending one, admission aliases
    the leader's full-page prompt prefix into the follower's block table
    (the same adopt→COW→suffix-prefill path a radix hit takes) instead of
    prefilling it again — identical prompts admitted in the same round
    share KV even when the prefix-cache index is disabled, or before the
    leader's pages are published to it. The leader's full prompt pages
    are append-stable while it decodes (new tokens land in later pages;
    a page-aligned boundary write goes to a *new* page), so aliasing live
    slot pages is safe; entries drop when the leader finishes or is
    preempted, after which the radix index (if any) takes over."""

    def __init__(self, cfg: SchedulerConfig, prefix_cache=None, pool=None):
        self.cfg = cfg
        self.prefix_cache = prefix_cache
        self.pool = pool              # enables in-flight dedup
        self.queue: Deque = deque()
        self._admit_seq = 0           # monotonically increasing admit stamp
        self.admitted_at: dict = {}   # slot -> admit stamp
        self.preemptions = 0
        self._round_budget = cfg.max_prefill_tokens
        self._round_first = True
        self.pending_prefill: Dict[bytes, int] = {}   # prompt key -> slot
        self._slot_keys: Dict[int, bytes] = {}
        self._match_memo = None   # (req id, index version, pages, len)

    def enqueue(self, req) -> None:
        self.queue.append(req)

    def requeue_front(self, req) -> None:
        """Preempted request goes back to the queue head (FIFO fairness)."""
        self.queue.appendleft(req)

    @property
    def pending(self) -> int:
        return len(self.queue)

    def start_round(self) -> None:
        self._round_budget = self.cfg.max_prefill_tokens
        self._round_first = True

    def next_admission(self, free_pages: int) -> Optional[Admission]:
        """Pop the queue head if this round's budget and the pool allow it.

        Returns an :class:`Admission` (request + prefix-cache split), or
        None (empty queue / budget spent / pool cannot hold the prompt
        right now). ``free_pages`` may include pages the engine can evict
        from the prefix cache on demand. The first admission of a round
        ignores the token budget — the budget throttles prefill *bursts*,
        it must never deadlock a long prompt."""
        if not self.queue:
            return None
        req = self.queue[0]
        adm = Admission(req)
        if self.prefix_cache is not None:
            # memoized per (head request, index version): a head blocked
            # on capacity for several rounds must not charge the index's
            # lookup stats or refresh its LRU stamps once per round
            memo = self._match_memo
            key = (id(req), self.prefix_cache.version)
            if memo is not None and memo[0] == key:
                adm.cached_pages, adm.cached_len = memo[1]
            else:
                adm.cached_pages, adm.cached_len = \
                    self.prefix_cache.match(req.prompt)
                self._match_memo = (key, (adm.cached_pages,
                                          adm.cached_len))
        self._match_pending(adm)
        padded = bucket_len(len(req.prompt) - adm.suffix_start,
                            self.cfg.page)
        if not self._round_first and padded > self._round_budget:
            return None
        # fresh pages to cover the prompt beyond the adopted prefix, plus
        # one for the COW of a whole-prompt hit's recomputed final token
        need = (pages_for(len(req.prompt), self.cfg.page)
                - len(adm.cached_pages)
                + (1 if adm.cached_len >= len(req.prompt) else 0))
        if need > free_pages:
            return None
        self._round_budget -= padded
        adm.first_in_round = self._round_first
        self._round_first = False
        self.queue.popleft()
        return adm

    def upgrade_budget(self, adm: Admission) -> bool:
        """Charge the degrade of a hit admission to a FULL prefill.

        ``next_admission`` budgeted the hit for its suffix bucket only;
        when the engine cannot honor the hit (its promised pages
        vanished) and falls back to an uncached prefill, the difference
        to the full-prompt bucket must still fit this round's budget —
        otherwise a failed 16-token-suffix hit could silently burst a
        1024-token prefill past ``max_prefill_tokens``, the exact decode
        stall the budget bounds. Returns False when it does not fit (the
        caller requeues; the round's first admission stays exempt, so a
        long prompt can never deadlock)."""
        full = bucket_len(len(adm.req.prompt), self.cfg.page)
        suffix = bucket_len(len(adm.req.prompt) - adm.suffix_start,
                            self.cfg.page)
        extra = full - suffix
        if not adm.first_in_round and extra > self._round_budget:
            return False
        self._round_budget -= extra
        return True

    # ---- in-flight dedup (pending-prefill table) -----------------------
    @staticmethod
    def prompt_key(prompt) -> bytes:
        return np.asarray(prompt, np.int32).tobytes()

    def note_prefill(self, req, slot: int) -> None:
        """Record that ``slot`` holds a live prefill of ``req.prompt`` —
        later identical prompts adopt its full pages instead of
        prefilling. First prompt in wins; the entry lives until the slot
        finishes or is preempted."""
        if self.pool is None:
            return
        key = self.prompt_key(req.prompt)
        if key not in self.pending_prefill:
            self.pending_prefill[key] = slot
            self._slot_keys[slot] = key

    def _drop_pending(self, slot: int) -> None:
        key = self._slot_keys.pop(slot, None)
        if key is not None and self.pending_prefill.get(key) == slot:
            del self.pending_prefill[key]

    def _match_pending(self, adm: Admission) -> None:
        """Upgrade ``adm`` to alias an in-flight identical prompt's pages
        when that beats the radix match (a slot holds the WHOLE prompt,
        the index at best its published prefix)."""
        if self.pool is None:
            return
        leader = self.pending_prefill.get(self.prompt_key(adm.req.prompt))
        if leader is None:
            return
        n_full = len(adm.req.prompt) // self.cfg.page
        pages = self.pool.slot_pages[leader][:n_full]
        if len(pages) == n_full and n_full * self.cfg.page > adm.cached_len:
            adm.cached_pages = list(pages)
            adm.cached_len = n_full * self.cfg.page
            adm.dedup = True

    def on_admit(self, slot: int) -> None:
        self.admitted_at[slot] = self._admit_seq
        self._admit_seq += 1

    def on_finish(self, slot: int) -> None:
        self.admitted_at.pop(slot, None)
        self._drop_pending(slot)

    def choose_victim(self, requester: int) -> Optional[int]:
        """Youngest slot admitted strictly AFTER the requester (or None).

        Only younger slots are evictable: letting a freshly restarted
        (hence younger) sequence evict an older one livelocks — the two
        ping-pong, erasing each other's progress forever. With this order
        the oldest admitted slot is never preempted, so it always runs to
        completion and frees its pages: global progress is guaranteed.
        A requester with no younger victim preempts *itself* and waits.

        The max over (stamp, slot) tuples is a deterministic total order:
        equal stamps (possible when admission records are restored or
        injected out of band) fall through to the higher slot id, never to
        dict iteration order. Pinned by a regression test."""
        stamp_r = self.admitted_at[requester]
        candidates = [(stamp, slot) for slot, stamp in
                      self.admitted_at.items() if stamp > stamp_r]
        if not candidates:
            return None
        _, slot = max(candidates)
        return slot

    def on_preempt(self, slot: int) -> None:
        self.preemptions += 1
        self.admitted_at.pop(slot, None)
        self._drop_pending(slot)
