"""Admission + chunk scheduling for the paged continuous-batching engine.

Policy (deliberately simple, the paper's edge target is one device):

  * **FIFO admission** — queued requests enter decode slots in arrival
    order; a request is admitted only when a slot is free AND the pool can
    cover its FIRST prefill chunk (later chunks allocate lazily, round by
    round).
  * **Chunked prefill with a per-round token budget** — prompts are
    consumed in fixed-size chunks of ``chunk`` tokens that run in the
    same jit step as the active decode lanes (the engine's unified ragged
    step), so a long prompt never stalls in-flight decodes for more than
    one chunk. Each round grants at most ``max_prefill_tokens`` prefill
    tokens across all prefilling lanes; the round's FIRST grant is exempt
    (the budget throttles bursts, it must never deadlock a long prompt).
    The fixed chunk width replaces the old power-of-2 prefill bucketing —
    the engine compiles exactly two step shapes (decode-only and chunk)
    instead of a bucket zoo.
  * **Preemption on pool exhaustion** — when a running sequence needs its
    next page (or chunk of pages) and the free list is empty, the
    *youngest* admitted slot is evicted (recompute-style: its pages are
    freed and the request re-enters the queue head to be prefilled again
    later). A lane preempted mid-prompt releases exactly the pages its
    chunks have written — page refcounts stay clean. Youngest-first
    preserves FIFO completion order and, under greedy decoding,
    restarting is output-identical.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.obs import trace as obs_trace
from repro.serve.paged_kv import pages_for


def bucket_len(n: int, page: int) -> int:
    """Smallest power of two >= max(n, page).

    ``page`` is itself a power of two, so every bucket is a whole number
    of pages. Chunked prefill killed the per-prompt pow2 bucketing; this
    survives as the default-chunk rule (one chunk covers the longest
    admissible prompt unless the caller opts into smaller chunks)."""
    b = page
    while b < n:
        b <<= 1
    return b


@dataclasses.dataclass
class SchedulerConfig:
    page: int = 16
    max_prefill_tokens: int = 512     # prefill tokens granted per round
    max_len: int = 256                # per-sequence logical capacity
    chunk: int = 64                   # prefill chunk width (tokens)


@dataclasses.dataclass
class Admission:
    """One admitted request plus its prefix-cache split.

    ``cached_pages`` alias the index's pages for the first ``cached_len``
    prompt tokens (whole pages; empty on a miss). ``suffix_start`` is where
    chunked prefill must actually run from — ``cached_len``, except for a
    whole-prompt hit where it is ``len(prompt) - 1`` so the final token's
    logit is recomputed (its KV write COWs the shared page it lands in).
    ``dedup`` marks an in-flight dedup: the pages alias a *live slot's*
    prompt pages (an identical prompt admitted earlier in this run) rather
    than the radix index's."""
    req: object
    cached_pages: List[int] = dataclasses.field(default_factory=list)
    cached_len: int = 0
    dedup: bool = False

    @property
    def suffix_start(self) -> int:
        return min(self.cached_len, len(self.req.prompt) - 1)


class FifoScheduler:
    """FIFO queue + per-round chunk budget + preemption policy.

    With a ``prefix_cache``, admission matches the head request's prompt
    against the radix index and hands the engine an :class:`Admission`
    split — chunked prefill then starts at the uncached suffix, and the
    pool-capacity check covers only the first chunk beyond the adopted
    pages.

    **In-flight dedup** (``pool`` given): a *pending-prefill table* maps
    each prompt currently occupying a slot to that leader slot. When the
    queue head's prompt is identical to a pending one, admission aliases
    the leader's full-page prompt prefix into the follower's block table
    (the same adopt→COW→chunked-suffix path a radix hit takes) instead of
    prefilling it again — identical prompts share KV even when the
    prefix-cache index is disabled, or before the leader's pages are
    published to it. Chunked prefill rebases the flow onto chunk
    boundaries: while the leader is still mid-prompt its trailing pages
    are only partially written, so the head *waits* (admission returns
    None) until the leader's prefill completes — ``note_progress`` is the
    engine's per-chunk progress feed. Entries drop when the leader
    finishes or is preempted; the radix index takes over afterwards.

    ``tracer`` (else the process default, ``obs.trace``) receives
    ``sched/admit`` / ``sched/preempt`` instants and the two admission
    stall events — ``sched/dedup_wait`` (head waiting for an in-flight
    identical prompt) and ``sched/miss_wait`` (head serialized behind
    the one open prefix-cache miss) — so queueing decisions are visible
    on the trace timeline, not just in aggregate counters."""

    def __init__(self, cfg: SchedulerConfig, prefix_cache=None, pool=None,
                 tracer=None):
        self.cfg = cfg
        self.prefix_cache = prefix_cache
        self.pool = pool              # enables in-flight dedup
        self._tracer = tracer
        self.queue: Deque = deque()
        self._admit_seq = 0           # monotonically increasing admit stamp
        self.admitted_at: dict = {}   # slot -> admit stamp
        self.preemptions = 0
        self._round_budget = cfg.max_prefill_tokens
        self._round_first = True
        self.pending_prefill: Dict[bytes, int] = {}   # prompt key -> slot
        self._slot_keys: Dict[int, bytes] = {}
        self.filled: Dict[int, int] = {}  # slot -> prompt tokens in KV
        self._open_miss: set = set()  # slots mid-prefill of index misses
        self._match_memo = None   # (req id, index version, pages, len)

    def enqueue(self, req) -> None:
        self.queue.append(req)

    def requeue_front(self, req) -> None:
        """Preempted request goes back to the queue head (FIFO fairness)."""
        self.queue.appendleft(req)

    @property
    def pending(self) -> int:
        return len(self.queue)

    def start_round(self) -> None:
        self._round_budget = self.cfg.max_prefill_tokens
        self._round_first = True

    # ---- per-round chunk budget ----------------------------------------
    def grant_chunk(self, n_remaining: int) -> int:
        """Prefill tokens one lane may run this round (0 = idle a round).

        Grants are ``min(chunk, remaining)``, capped by what is left of
        this round's ``max_prefill_tokens``. The round's FIRST grant
        ignores the cap — the budget throttles prefill *bursts* relative
        to decode lanes, it must never wedge a chunk wider than the
        budget. Invariant (pinned by tests): after the first grant, the
        sum of a round's grants never exceeds ``max_prefill_tokens``."""
        want = min(self.cfg.chunk, int(n_remaining))
        if want <= 0:
            return 0
        if self._round_first:
            self._round_first = False
            self._round_budget -= want
            return want
        n = min(want, self._round_budget)
        if n <= 0:
            return 0
        self._round_budget -= n
        return n

    def grant_verify(self, n_draft: int) -> int:
        """Draft tokens a decode lane may verify this round (speculative
        decode), drawn from the SAME per-round ``max_prefill_tokens``
        budget as chunk grants: verify columns are extra step width
        exactly like prefill tokens, so they must not starve prefill
        lanes the budget was sized for. Unlike :meth:`grant_chunk` there
        is no first-grant exemption — drafts are optional work; a lane
        that gets 0 here simply decodes one token as usual (the carried
        token is never charged)."""
        n = min(int(n_draft), self._round_budget)
        if n <= 0:
            return 0
        self._round_budget -= n
        return n

    def grant_decode(self, n_emitted: int, max_new: int, pos: int,
                     max_len: int, lead: int = 0) -> bool:
        """May a decode lane take one more token, ``lead`` tokens ahead
        of its retired state? The pipelined engine grants round N+1
        while round N's token is still in flight (``lead=1``): budget
        (``max_new_tokens``) and capacity (``max_len``) finishes are
        deterministic, so counting the in-flight token here means those
        lanes are never overrun — only an EOS landing during the lag
        computes one extra token, trimmed via ``PagedKVPool.trim``
        exactly like a rejected speculative draft. ``lead=0`` is the
        synchronous engine's termination test, pre-emit."""
        return n_emitted + lead < max_new and pos + lead < max_len

    def next_admission(self, free_pages: int) -> Optional[Admission]:
        """Pop the queue head if a slot's first chunk can start now.

        Returns an :class:`Admission` (request + prefix-cache split), or
        None (empty queue / pool cannot hold the first chunk beyond the
        adopted prefix / the head must wait for an in-flight identical
        prompt to finish prefilling). ``free_pages`` may include pages
        the engine can evict from the prefix cache on demand. Chunk
        tokens are charged per round via :meth:`grant_chunk`, not here —
        admission is seat-only."""
        if not self.queue:
            return None
        req = self.queue[0]
        adm = Admission(req)
        if self.prefix_cache is not None:
            # memoized per (head request, index version): a head blocked
            # on capacity for several rounds must not charge the index's
            # lookup stats or refresh its LRU stamps once per round
            memo = self._match_memo
            key = (id(req), self.prefix_cache.version)
            if memo is not None and memo[0] == key:
                adm.cached_pages, adm.cached_len = memo[1]
            else:
                adm.cached_pages, adm.cached_len = \
                    self.prefix_cache.match(req.prompt)
                self._match_memo = (key, (adm.cached_pages,
                                          adm.cached_len))
        if self._match_pending(adm):
            obs_trace.active(self._tracer).instant(
                "sched/dedup_wait", uid=getattr(req, "uid", None))
            return None               # wait for the in-flight leader
        if (self.prefix_cache is not None and not adm.cached_pages
                and self._open_miss):
            # one index MISS in flight at a time: a miss's pages publish
            # to the radix when its chunked prefill completes, so the
            # head behind it — which often shares the prefix (the
            # multi-tenant system prompt) — admits as a HIT once the
            # leader finishes instead of re-prefilling the same pages in
            # parallel. Hits admit freely; pre-chunking prefill was
            # fully serial anyway, so this never loses to the old path.
            obs_trace.active(self._tracer).instant(
                "sched/miss_wait", uid=getattr(req, "uid", None))
            return None
        L = len(req.prompt)
        start = adm.suffix_start
        first_end = min(L, start + self.cfg.chunk)
        # fresh pages to cover the first chunk beyond the adopted prefix,
        # plus one for the COW of a whole-prompt hit's recomputed token
        need = (pages_for(first_end, self.cfg.page)
                - len(adm.cached_pages)
                + (1 if adm.cached_len >= L else 0))
        if need > free_pages:
            return None
        self.queue.popleft()
        return adm

    def miss_open(self, slot: int) -> None:
        """A cache-miss admission started chunking in ``slot`` — further
        misses wait until :meth:`miss_closed` (publish gate above). A
        set, not a scalar: a hit that degrades to a miss mid-admission
        can open a second slot while one is already chunking, and the
        gate must hold until the LAST open miss publishes."""
        self._open_miss.add(slot)

    def miss_closed(self, slot: int) -> None:
        """The slot's prefill completed (pages published), finished, or
        was preempted — miss admissions may flow again once no miss is
        left in flight."""
        self._open_miss.discard(slot)

    # ---- in-flight dedup (pending-prefill table) -----------------------
    @staticmethod
    def prompt_key(prompt) -> bytes:
        return np.asarray(prompt, np.int32).tobytes()

    def note_prefill(self, req, slot: int) -> None:
        """Record that ``slot`` holds a live prefill of ``req.prompt`` —
        later identical prompts adopt its full pages instead of
        prefilling. First prompt in wins; the entry lives until the slot
        finishes or is preempted."""
        if self.pool is None:
            return
        key = self.prompt_key(req.prompt)
        if key not in self.pending_prefill:
            self.pending_prefill[key] = slot
            self._slot_keys[slot] = key

    def note_progress(self, slot: int, n_tokens: int) -> None:
        """Engine feed: ``slot`` now holds ``n_tokens`` prompt tokens in
        KV (advanced after every chunk). Gates when a pending-prefill
        leader's pages become aliasable — a page is safe to share only
        once every token in it has been written."""
        self.filled[slot] = int(n_tokens)

    def _drop_pending(self, slot: int) -> None:
        key = self._slot_keys.pop(slot, None)
        if key is not None and self.pending_prefill.get(key) == slot:
            del self.pending_prefill[key]
        self.filled.pop(slot, None)

    def _match_pending(self, adm: Admission) -> bool:
        """Upgrade ``adm`` to alias an in-flight identical prompt's pages
        when that beats the radix match (a slot holds the WHOLE prompt,
        the index at best its published prefix). Returns True when the
        head should WAIT instead: the leader is still mid-prefill, so its
        trailing pages are not fully written yet — one round later they
        will be, and aliasing beats recomputing the whole prompt."""
        if self.pool is None:
            return False
        leader = self.pending_prefill.get(self.prompt_key(adm.req.prompt))
        if leader is None:
            return False
        L = len(adm.req.prompt)
        n_full = L // self.cfg.page
        if adm.cached_len >= n_full * self.cfg.page:
            return False              # radix already covers the max share
        if self.filled.get(leader, 0) < L:
            return True               # leader mid-prefill: wait a round
        pages = self.pool.slot_pages[leader][:n_full]
        if len(pages) == n_full and n_full * self.cfg.page > adm.cached_len:
            adm.cached_pages = list(pages)
            adm.cached_len = n_full * self.cfg.page
            adm.dedup = True
        return False

    def on_admit(self, slot: int) -> None:
        self.admitted_at[slot] = self._admit_seq
        obs_trace.active(self._tracer).instant(
            "sched/admit", slot=slot, stamp=self._admit_seq)
        self._admit_seq += 1

    def on_finish(self, slot: int) -> None:
        self.admitted_at.pop(slot, None)
        self._drop_pending(slot)
        self.miss_closed(slot)

    def choose_victim(self, requester: int) -> Optional[int]:
        """Youngest slot admitted strictly AFTER the requester (or None).

        Only younger slots are evictable: letting a freshly restarted
        (hence younger) sequence evict an older one livelocks — the two
        ping-pong, erasing each other's progress forever. With this order
        the oldest admitted slot is never preempted, so it always runs to
        completion and frees its pages: global progress is guaranteed.
        A requester with no younger victim preempts *itself* and waits.

        The max over (stamp, slot) tuples is a deterministic total order:
        equal stamps (possible when admission records are restored or
        injected out of band) fall through to the higher slot id, never to
        dict iteration order. Pinned by a regression test."""
        stamp_r = self.admitted_at[requester]
        candidates = [(stamp, slot) for slot, stamp in
                      self.admitted_at.items() if stamp > stamp_r]
        if not candidates:
            return None
        _, slot = max(candidates)
        return slot

    def on_preempt(self, slot: int) -> None:
        self.preemptions += 1
        obs_trace.active(self._tracer).instant("sched/preempt", slot=slot)
        self.admitted_at.pop(slot, None)
        self._drop_pending(slot)
        self.miss_closed(slot)
