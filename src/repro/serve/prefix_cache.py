"""Prefix cache: radix block index + copy-on-write page sharing.

Multi-tenant edge traffic is dominated by shared system prompts and
few-shot preambles. Because the paged pool (``serve/paged_kv.py``) already
addresses KV through per-sequence block tables, a shared prompt prefix can
be served by *aliasing* the same physical pages into many tables — which
removes both the prefill FLOPs for the cached tokens and the page-rounded
LPDDR5 KV writes the memsys DSE charges for them
(``memsys.workload.kv_traffic_prefix``).

Index structure
---------------
A radix tree over **full KV pages**: each edge is keyed by the tuple of
``page`` token ids a page holds, so a node at depth d is the unique page
caching tokens ``[(d-1)*page, d*page)`` of every prompt that shares that
token path. Matching walks the tree block by block and returns the longest
cached page run; insertion publishes a finished prefill's full pages,
creating nodes for blocks not yet present.

Lifetime rules
--------------
The pool's per-page refcount is the single source of truth:

  * publishing a page into the index adds one reference
    (``pool.retain``) — the index keeps the page alive after its
    producing sequence finishes;
  * a match that is adopted into a slot adds one reference per page
    (``pool.adopt``) — adopted pages are *pinned*: they can never be
    evicted or written while any slot maps them;
  * a cached page whose refcount is exactly 1 (index-only) is
    **evictable**; eviction is leaf-first LRU (a node may only be removed
    once all of its children are gone, so every cached path always starts
    at the root) and returns the page to the pool free list;
  * a shared page is **never scattered into**: the first divergent write
    goes through ``pool.cow`` — the writer gets a private copy (device
    copy via the ``serve.steps`` page-copy builder) and the shared
    refcount drops by one.

Only *full* pages are cached, and a match never covers the final prompt
token (the engine must compute its logit), so at most
``floor((len(prompt) - 1) / page)`` pages can be served from cache; when a
whole page-aligned prompt is cached the engine adopts every page and
re-computes just the last token, COW-privatizing the page it lands in.

The index never touches device memory itself: it stores page *ids*; all
device copies happen in the engine through the pool's jitted helpers.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs import trace as obs_trace
from repro.serve.paged_kv import PagedKVPool


class _Node:
    """One cached page. ``key`` is the page's token-id tuple under its
    parent; ``stamp`` is the LRU clock value of the last touch."""

    __slots__ = ("key", "page_id", "children", "parent", "stamp")

    def __init__(self, key: Optional[Tuple[int, ...]], page_id: int,
                 parent: Optional["_Node"], stamp: int):
        self.key = key
        self.page_id = page_id
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.parent = parent
        self.stamp = stamp


@dataclasses.dataclass
class PrefixCacheStats:
    lookups: int = 0
    hits: int = 0                 # lookups that matched >= 1 page
    hit_tokens: int = 0           # tokens served from cache across lookups
    lookup_tokens: int = 0        # prompt tokens across lookups
    published_pages: int = 0      # new pages inserted into the index
    evicted_pages: int = 0

    @property
    def hit_rate(self) -> float:
        """Token hit rate: fraction of looked-up prompt tokens served from
        cached pages."""
        return (self.hit_tokens / self.lookup_tokens
                if self.lookup_tokens else 0.0)


class PrefixCache:
    """Radix index of cached full KV pages over a :class:`PagedKVPool`.

    Host-side only; see the module docstring for lifetime rules.

    ``tracer`` (else the process default, ``obs.trace``) receives
    ``cache/published`` and ``cache/evicted`` instants — each marks a
    host-side index mutation whose pages a slot must later adopt or
    re-prefill, i.e. a page-op round trip in the making."""

    def __init__(self, pool: PagedKVPool, tracer=None):
        self.pool = pool
        self._tracer = tracer
        self.page = pool.page
        self.root = _Node(None, 0, None, 0)
        self._clock = 0
        self._nodes: Dict[int, _Node] = {}      # page_id -> node
        self.stats = PrefixCacheStats()
        self.version = 0      # bumped on insert/evict: match memo key

    # ---- introspection -------------------------------------------------
    def __len__(self) -> int:
        return len(self._nodes)

    def cached_pages(self) -> int:
        return len(self._nodes)

    def evictable_pages(self) -> int:
        """Cached pages no live slot maps (refcount 1 = index only).

        Eviction is leaf-first, but any index-only page is reachable by
        repeated leaf eviction: a slot pinning a descendant pins nothing
        above it only in the tree sense — refcounts are per page — so every
        refcount-1 page is eventually evictable and may be promised to the
        admission capacity check."""
        return sum(1 for pid in self._nodes if self.pool.ref[pid] == 1)

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    # ---- lookup --------------------------------------------------------
    def match(self, tokens: np.ndarray) -> Tuple[List[int], int]:
        """Longest cached full-page prefix of ``tokens``.

        Returns (page_ids, n_cached_tokens) over *complete* pages only, so
        n_cached <= floor(len / page) * page. A match may cover the whole
        prompt (page-aligned repeat); the engine then recomputes just the
        final token, COW-privatizing the page its write lands in, because
        the last token's logit is never cached. Touches every matched
        node's LRU stamp."""
        toks = [int(t) for t in tokens]
        self.stats.lookups += 1
        self.stats.lookup_tokens += len(toks)
        node, pages = self.root, []
        stamp = self._tick()
        while (len(pages) + 1) * self.page <= len(toks):
            key = tuple(toks[len(pages) * self.page:
                             (len(pages) + 1) * self.page])
            child = node.children.get(key)
            if child is None:
                break
            child.stamp = stamp
            node = child
            pages.append(child.page_id)
        if pages:
            self.stats.hits += 1
            self.stats.hit_tokens += len(pages) * self.page
        return pages, len(pages) * self.page

    # ---- publish -------------------------------------------------------
    def insert(self, tokens: np.ndarray, page_ids: List[int]) -> int:
        """Publish a prefilled prompt's full pages; returns #new entries.

        ``page_ids`` are the producing slot's pages, in token order; only
        the ``len(tokens) // page`` complete pages are indexed. A block
        already present keeps its existing page (concurrent duplicate
        prefills are not deduplicated retroactively — the newcomer's page
        simply stays private to its slot). Newly indexed pages gain one
        pool reference so they outlive the producing sequence."""
        toks = [int(t) for t in tokens]
        n_full = min(len(toks) // self.page, len(page_ids))
        node, new = self.root, 0
        stamp = self._tick()
        for j in range(n_full):
            key = tuple(toks[j * self.page:(j + 1) * self.page])
            child = node.children.get(key)
            if child is None:
                child = _Node(key, page_ids[j], node, stamp)
                node.children[key] = child
                self._nodes[page_ids[j]] = child
                self.pool.retain(page_ids[j])
                new += 1
            else:
                child.stamp = stamp
            node = child
        self.stats.published_pages += new
        if new:
            self.version += 1
            obs_trace.active(self._tracer).instant(
                "cache/published", pages=new,
                cached_total=len(self._nodes))
        return new

    # ---- eviction ------------------------------------------------------
    def _evictable_leaves(self) -> List[_Node]:
        return [n for n in self._nodes.values()
                if not n.children and self.pool.ref[n.page_id] == 1]

    def evict(self, n_pages: int) -> int:
        """Free >= n_pages unreferenced cached pages if possible (LRU,
        leaf-first); returns how many were actually freed."""
        freed = 0
        while freed < n_pages:
            leaves = self._evictable_leaves()
            if not leaves:
                break
            # oldest-stamp first; pop as many distinct leaves as the
            # deficit allows before recomputing (children removals can
            # surface newly-evictable parents)
            for node in sorted(leaves, key=lambda n: n.stamp):
                if freed >= n_pages:
                    break
                self._remove(node)
                freed += 1
        self.stats.evicted_pages += freed
        if freed:
            obs_trace.active(self._tracer).instant(
                "cache/evicted", pages=freed,
                cached_total=len(self._nodes))
        return freed

    def _remove(self, node: _Node) -> None:
        assert not node.children
        del node.parent.children[node.key]
        del self._nodes[node.page_id]
        self.pool.release(node.page_id)
        self.version += 1

    def clear(self) -> int:
        """Evict everything evictable (e.g. before resizing the pool)."""
        return self.evict(len(self._nodes))

    # ---- invariant checking (used by the hypothesis tests) -------------
    def check_invariants(self) -> None:
        """Raise if index/pool bookkeeping has drifted."""
        for pid, node in self._nodes.items():
            assert node.page_id == pid
            assert pid not in self.pool._free_set, f"cached page {pid} free"
            assert self.pool.ref[pid] >= 1, f"cached page {pid} unref'd"
            assert node.parent.children.get(node.key) is node
        # every node is reachable from the root (paths never dangle)
        seen = set()
        stack = [self.root]
        while stack:
            n = stack.pop()
            for c in n.children.values():
                seen.add(c.page_id)
                stack.append(c)
        assert seen == set(self._nodes)
