"""Jitted sampling head: token selection INSIDE the serving step.

Token selection used to be a stray un-jitted ``jnp.argmax`` dispatched
after every ``step``/``solo_step`` call — a vocab-sized ``[B, C, V]``
logits array crossed the jit boundary each round and the selection work
was invisible to the cost attribution (``obs/costs.py``). This module is
the fused epilogue ``serve/steps.py`` appends to the forward pass: the
step now returns selected token ids ``[B, C]`` (+ per-token logprobs
``[B, C]``), and only those leave the device.

Contract
--------
:func:`select_tokens` is pure jax, traced into every serving step:

  * **Greedy is the oracle.** A lane with ``temperature <= 0`` takes
    ``argmax(logits)`` — bitwise the same selection the engine used to
    run out of jit, so greedy decode is token-identical to the pre-head
    engine and stays the parity baseline for every other path.
  * **Sampling** (``temperature > 0``): logits are scaled by
    ``1/temperature``, masked by top-k (keep the k highest; ``0`` =
    off) and top-p (keep the smallest set whose cumulative mass reaches
    ``p``, always at least the top token; ``1.0`` = off; the two masks
    are computed on the scaled logits and intersected), then drawn via
    ``jax.random.categorical``. All sampling knobs are traced ``[B]``
    arrays — one compile per step width serves every parameter combo.
  * **PRNG keys** fold per the SNIPPETS ``fold_in_str`` idiom: the
    engine derives one key per request, ``fold_in_str(PRNGKey(seed),
    f"req/{uid}")`` (:func:`request_key`), and the head folds the
    token's absolute position in per column. A token's key therefore
    depends only on ``(seed, uid, position)`` — never on batch layout —
    so solo-lane vs batched rounds, preemption recompute, speculative
    re-verification, and the pipelined engine's on-device token carry
    (``steps.carry_decode_tokens`` — the input token arrives as a device
    array instead of a host re-upload, but the key folds from the same
    absolute position) all draw the same stream.
  * **Logprobs** are the model-distribution log-softmax at the selected
    token (temperature-independent — the probability the MODEL assigned,
    the serving-API convention), for greedy and sampled lanes alike.
  * **Dead lanes read a sentinel.** Columns at or past ``n_new[b]``
    (idle lanes, right padding) return :data:`DEAD_TOKEN` = -1 — an id
    no vocab contains — so an emit-path bug that reads a dead lane
    surfaces as an impossible token instead of hiding behind a
    legitimate vocab id 0.
"""
from __future__ import annotations

import dataclasses
import functools
import zlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

# token id returned for dead lanes / padding columns: never a vocab id,
# so it cannot masquerade as a real emission (see module docstring)
DEAD_TOKEN = -1


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request token-selection policy (``Request.sampling``).

    The default is greedy — ``temperature=0`` takes the argmax path that
    is bitwise the pre-sampling-head oracle. ``top_k=0`` / ``top_p=1.0``
    disable those filters; ``seed`` roots the request's PRNG stream
    (folded with the request uid and each token's absolute position);
    ``logprobs=True`` asks the engine to record the selected token's
    model logprob in ``Request.out_logprobs`` alongside each emission.
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    logprobs: bool = False

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got "
                             f"{self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")


GREEDY = SamplingParams()


def fold_in_str(key, s: str):
    """Fold a string into a PRNG key (the SNIPPETS ``fold_in_str``
    idiom): names the derivation instead of magic integer folds."""
    return jax.random.fold_in(key, np.uint32(zlib.crc32(s.encode("utf-8"))))


@functools.lru_cache(maxsize=None)
def _base_key(seed: int):
    return jax.random.PRNGKey(seed)


def request_key(seed: int, uid: int) -> np.ndarray:
    """Per-request raw key data ``[2] uint32``: the seed's base key with
    ``req/<uid>`` folded in. Depends only on (seed, uid), so a preempted
    request re-draws its exact stream on recompute."""
    return np.asarray(fold_in_str(_base_key(int(seed)), f"req/{uid}"),
                      np.uint32)


def lane_inputs(n: int) -> Dict[str, np.ndarray]:
    """Greedy-initialized host-side per-lane sampling arrays, the pytree
    the step's ``sampling`` argument is built from (lane ``b`` holds its
    request's :class:`SamplingParams` fields)."""
    return {"temp": np.zeros(n, np.float32),
            "top_k": np.zeros(n, np.int32),
            "top_p": np.ones(n, np.float32),
            "key": np.zeros((n, 2), np.uint32)}


def set_lane(samp: Dict[str, np.ndarray], lane: int,
             sp: Optional[SamplingParams], uid: int = 0) -> None:
    """Write one request's params into its lane of a :func:`lane_inputs`
    table (``sp=None`` resets the lane to greedy)."""
    sp = sp or GREEDY
    samp["temp"][lane] = sp.temperature
    samp["top_k"][lane] = sp.top_k
    samp["top_p"][lane] = sp.top_p
    samp["key"][lane] = (request_key(sp.seed, uid) if sp.temperature > 0
                         else 0)


def select_tokens(logits, temp, top_k, top_p, key, positions, n_new):
    """The fused token-selection epilogue (see module docstring).

    ``logits [B, C, V]``; ``temp``/``top_k``/``top_p`` ``[B]`` traced
    lane params; ``key [B, 2]`` raw per-request key data; ``positions
    [B, C]`` absolute token positions (folded into the per-column keys);
    ``n_new [B]`` live-column counts. Returns ``(tokens [B, C] int32,
    logprobs [B, C] float32)`` with dead columns at :data:`DEAD_TOKEN` /
    0.0 logprob.
    """
    v = logits.shape[-1]
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logp_model = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)

    def lane(lg, t, k, p, kd, pos):
        # lg [C, V]; everything else lane-scalar (pos [C])
        scaled = lg.astype(jnp.float32) / jnp.maximum(t, 1e-6)
        asc = jnp.sort(scaled, axis=-1)
        kk = jnp.clip(k, 1, v)
        kth = jnp.take_along_axis(
            asc, jnp.full((scaled.shape[0], 1), v - kk), axis=-1)[:, 0]
        keep_k = jnp.where(k > 0, scaled >= kth[:, None], True)
        desc = asc[:, ::-1]
        cum = jnp.cumsum(jax.nn.softmax(desc, axis=-1), axis=-1)
        n_keep = jnp.sum(cum < p, axis=-1) + 1   # smallest set, mass >= p
        pth = jnp.take_along_axis(desc, (n_keep - 1)[:, None],
                                  axis=-1)[:, 0]
        keep_p = jnp.where(p < 1.0, scaled >= pth[:, None], True)
        masked = jnp.where(keep_k & keep_p, scaled, -jnp.inf)
        keys_c = jax.vmap(lambda q: jax.random.fold_in(kd, q))(pos)
        return jax.vmap(jax.random.categorical)(keys_c,
                                                masked).astype(jnp.int32)

    sampled = jax.vmap(lane)(logits, temp, top_k, top_p, key, positions)
    tok = jnp.where((temp > 0.0)[:, None], sampled, greedy)
    logp = jnp.take_along_axis(logp_model, tok[..., None], axis=-1)[..., 0]
    cols = jnp.arange(tok.shape[1], dtype=jnp.int32)[None, :]
    live = cols < n_new[:, None].astype(jnp.int32)
    return (jnp.where(live, tok, DEAD_TOKEN),
            jnp.where(live, logp, 0.0))
