"""serve subsystem: paged KV pool + continuous-batching engines.

Public surface:
  * ``engine.ServeEngine``        — paged, batched-decode engine (default)
  * ``engine.LegacyServeEngine``  — per-slot baseline
  * ``engine.Request`` / ``engine.EngineStats``
  * ``paged_kv.PagedKVPool``      — block-table page allocator
  * ``scheduler.FifoScheduler``   — admission + preemption policy
"""
from repro.serve.engine import (EngineStats, LegacyServeEngine,  # noqa: F401
                                Request, ServeEngine)
from repro.serve.paged_kv import PagedKVPool, PoolExhausted  # noqa: F401
from repro.serve.scheduler import (FifoScheduler,  # noqa: F401
                                   SchedulerConfig, bucket_len)
