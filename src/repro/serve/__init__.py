"""serve subsystem: paged KV pool + continuous-batching engines.

Public surface:
  * ``engine.ServeEngine``        — paged, batched-decode engine (default;
    ``prefix_cache=True`` shares prompt-prefix pages copy-on-write)
  * ``engine.LegacyServeEngine``  — per-slot baseline
  * ``engine.Request`` / ``engine.EngineStats``
  * ``paged_kv.PagedKVPool``      — refcounted block-table page allocator
  * ``prefix_cache.PrefixCache``  — radix index of cached full KV pages
  * ``scheduler.FifoScheduler``   — admission + preemption policy
"""
from repro.serve.engine import (EngineStats, LegacyServeEngine,  # noqa: F401
                                Request, ServeEngine)
from repro.serve.paged_kv import (PageAccountingError,  # noqa: F401
                                  PagedKVPool, PoolExhausted)
from repro.serve.prefix_cache import (PrefixCache,  # noqa: F401
                                      PrefixCacheStats)
from repro.serve.scheduler import (Admission, FifoScheduler,  # noqa: F401
                                   SchedulerConfig, bucket_len)
