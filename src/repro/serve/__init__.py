"""serve subsystem."""
