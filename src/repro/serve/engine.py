"""Batched serving engine: request queue -> prefill -> interleaved decode.

Continuous-batching-lite: requests are grouped into fixed-size slots; a slot
becomes free when its sequence emits EOS or hits max_new_tokens, and the
next queued request is prefilled into it. Weights may be dense bf16 or the
QMC serving format (ShardedQTensor / QTensor stacks) — the engine is
agnostic; matmul dispatch handles it.

Single-process implementation (CPU container); the pjit'd steps are the
same ones the multi-pod dry-run lowers for the 256/512-chip meshes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.model import decode_step, prefill


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # [S] int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0
    decode_steps: int = 0
    tokens_out: int = 0
    wall_s: float = 0.0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / self.wall_s if self.wall_s else 0.0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 256, cache_dtype=jnp.float32):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        self.stats = EngineStats()
        self._decode = jax.jit(
            lambda p, t, c, pos: decode_step(cfg, p, t, c, pos))

    def _prefill_one(self, prompt: np.ndarray):
        tokens = jnp.asarray(prompt)[None, :]
        logits, cache = prefill(self.cfg, self.params, tokens,
                                max_len=self.max_len,
                                cache_dtype=self.cache_dtype)
        self.stats.prefills += 1
        return int(jnp.argmax(logits[0])), cache

    def run(self, requests: List[Request],
            greedy: bool = True) -> List[Request]:
        """Process all requests to completion; returns them with outputs."""
        t0 = time.monotonic()
        queue = list(requests)
        # slot state: per-slot cache (batch dim 1) + active request
        active: List[Optional[Request]] = [None] * self.slots
        caches: List = [None] * self.slots
        positions = [0] * self.slots
        next_tok = [0] * self.slots

        def refill():
            for s in range(self.slots):
                if active[s] is None and queue:
                    req = queue.pop(0)
                    tok, cache = self._prefill_one(req.prompt)
                    active[s] = req
                    caches[s] = cache
                    positions[s] = len(req.prompt)
                    next_tok[s] = tok
                    req.out_tokens.append(tok)
                    self.stats.tokens_out += 1

        refill()
        while any(a is not None for a in active):
            for s in range(self.slots):
                req = active[s]
                if req is None:
                    continue
                if len(req.out_tokens) >= req.max_new_tokens or \
                        (req.eos_id is not None
                         and req.out_tokens[-1] == req.eos_id) or \
                        positions[s] + 1 >= self.max_len:
                    req.done = True
                    active[s] = None
                    caches[s] = None
                    continue
                tok = jnp.asarray([[next_tok[s]]], jnp.int32)
                logits, caches[s] = self._decode(
                    self.params, tok, caches[s],
                    jnp.asarray(positions[s], jnp.int32))
                positions[s] += 1
                nxt = int(jnp.argmax(logits[0]))
                next_tok[s] = nxt
                req.out_tokens.append(nxt)
                self.stats.decode_steps += 1
                self.stats.tokens_out += 1
            refill()
        self.stats.wall_s = time.monotonic() - t0
        return requests
