"""Serving engines: paged continuous batching (default) + legacy per-slot.

``ServeEngine`` is the paged engine: all active slots decode in ONE
``jax.jit`` step against a shared paged KV arena (``serve/paged_kv.py``),
with FIFO admission, power-of-2 prefill bucketing and recompute-style
preemption (``serve/scheduler.py``). Weights may be dense fp or the QMC
serving format (ShardedQTensor / QTensor stacks) — matmul dispatch handles
either, so the paper's eMEM-resident weights and the LPDDR5-resident paged
KV stream meet in the same step function.

``LegacyServeEngine`` keeps the original loop — N sequential batch-1 decode
calls over per-slot contiguous caches — as the parity/throughput baseline
for ``benchmarks/serving.py``.

Under greedy decoding both engines are token-identical: the paged gather
reads the same K/V values the contiguous slab holds (int8 caches share one
quantizer, ``models.kvcache.quantize_kv``), and masked pages contribute
exp(-1e30) = 0 to the softmax.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.model import decode_step, prefill
from repro.serve.paged_kv import (PagedKVPool, PoolExhausted, make_adopt,
                                  make_bucketed_prefill, pages_for)
from repro.serve.scheduler import (FifoScheduler, SchedulerConfig,
                                   bucket_len)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # [S] int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0
    decode_steps: int = 0            # jit decode calls (batched = 1/step)
    tokens_out: int = 0
    wall_s: float = 0.0
    preemptions: int = 0
    pages_peak: int = 0
    tokens_discarded: int = 0        # emitted then erased by preemption
    # per decode call: wall seconds and tokens emitted by that call (the
    # emitted count includes tokens a later preemption discards — the jit
    # work was really done; tokens_discarded records how many)
    step_seconds: List[float] = dataclasses.field(default_factory=list)
    step_tokens: List[int] = dataclasses.field(default_factory=list)

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / self.wall_s if self.wall_s else 0.0

    def per_token_latencies(self) -> List[float]:
        return [s / t for s, t in zip(self.step_seconds, self.step_tokens)
                if t]


@functools.lru_cache(maxsize=None)
def _decode_jit(cfg: ModelConfig):
    """One jitted decode per ModelConfig (hashable frozen dataclass):

    engines sharing a config reuse XLA executables instead of re-tracing."""
    return jax.jit(lambda p, t, c, pos: decode_step(cfg, p, t, c, pos))


def _finished(req: Request, pos: int, max_len: int) -> bool:
    """Termination test shared by both engines (applied after each emit):

    budget spent, EOS emitted (including at prefill), or the next decode
    would write past the cache capacity (positions 0..max_len-1 are
    writable, so the cache is full once pos == max_len)."""
    return (len(req.out_tokens) >= req.max_new_tokens
            or (req.eos_id is not None and req.out_tokens
                and req.out_tokens[-1] == req.eos_id)
            or pos >= max_len)


# ==========================================================================
# paged continuous-batching engine (default)
# ==========================================================================
class ServeEngine:
    """Continuous batching over a paged KV pool.

    ``slots`` bounds concurrent sequences; ``max_len`` is each sequence's
    logical capacity (prompt + generated). ``n_pages`` sizes the shared
    pool — the default fits every slot at full length, so preemption only
    occurs when the caller shrinks it (memory-pressure experiments).
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 256, cache_dtype=jnp.float32,
                 page_size: int = 16, n_pages: Optional[int] = None,
                 max_prefill_tokens: Optional[int] = None):
        if cfg.is_encdec or cfg.n_vis_tokens:
            raise NotImplementedError(
                "paged engine covers decoder-only models; use "
                "LegacyServeEngine for encdec/vlm")
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        self.page = page_size
        self.max_pages_per_seq = pages_for(max_len, page_size)
        self.n_pages = n_pages or slots * self.max_pages_per_seq
        self.max_prefill_tokens = (max_prefill_tokens
                                   or max(512, bucket_len(max_len,
                                                          page_size)))
        self.stats = EngineStats()
        self._decode = _decode_jit(cfg)
        self._prefill = make_bucketed_prefill(cfg, cache_dtype)
        self._adopt = make_adopt(cfg, page_size)

    def run(self, requests: List[Request],
            greedy: bool = True) -> List[Request]:
        """Process all requests to completion; returns them with outputs.

        Stats describe this run only (a fresh EngineStats per call)."""
        if not greedy:
            raise NotImplementedError("only greedy decoding is implemented")
        self.stats = EngineStats()
        t0 = time.monotonic()
        for r in requests:
            if len(r.prompt) > self.max_len:
                raise ValueError(f"request {r.uid}: prompt length "
                                 f"{len(r.prompt)} > max_len={self.max_len}")
        pool = PagedKVPool(self.cfg, n_pages=self.n_pages, page=self.page,
                           max_slots=self.slots,
                           max_pages_per_seq=self.max_pages_per_seq,
                           cache_dtype=self.cache_dtype)
        sched = FifoScheduler(SchedulerConfig(
            page=self.page, max_prefill_tokens=self.max_prefill_tokens,
            max_len=self.max_len))
        for r in requests:
            sched.enqueue(r)

        arena = pool.init_arena()
        active: List[Optional[Request]] = [None] * self.slots
        pos = np.zeros(self.slots, np.int64)
        next_tok = np.zeros(self.slots, np.int64)

        def finish(s: int) -> None:
            active[s].done = True
            active[s] = None
            pool.free_slot(s)
            sched.on_finish(s)

        def preempt(victim: int) -> None:
            req = active[victim]
            # recompute-style eviction: drop generated state, requeue; the
            # emitted tokens are regenerated, so back them out of the stats
            self.stats.tokens_out -= len(req.out_tokens)
            self.stats.tokens_discarded += len(req.out_tokens)
            req.out_tokens = []
            active[victim] = None
            pool.free_slot(victim)
            sched.on_preempt(victim)
            sched.requeue_front(req)

        def admit() -> None:
            nonlocal arena
            sched.start_round()
            free_slots = [s for s in range(self.slots)
                          if active[s] is None]
            while free_slots:
                req = sched.next_admission(pool.free_count)
                if req is None:
                    break
                L = len(req.prompt)
                bucket = bucket_len(L, self.page)
                toks = np.zeros((1, bucket), np.int32)
                toks[0, :L] = req.prompt
                logits, contig = self._prefill(
                    self.params, jnp.asarray(toks),
                    jnp.asarray([L], jnp.int32))
                self.stats.prefills += 1
                tok = int(jnp.argmax(logits[0, L - 1]))
                req.out_tokens.append(tok)
                self.stats.tokens_out += 1
                if _finished(req, L, self.max_len):
                    req.done = True     # e.g. prefill emitted EOS: no slot
                    continue
                s = free_slots.pop(0)
                pool.ensure(s, L)       # cannot fail: admission checked
                ids = list(pool.slot_pages[s])
                ids += [0] * (bucket // self.page - len(ids))
                arena = self._adopt(arena, contig,
                                    jnp.asarray(ids, jnp.int32), s)
                active[s] = req
                pos[s] = L
                next_tok[s] = tok
                sched.on_admit(s)

        admit()
        while any(a is not None for a in active) or sched.pending:
            if not any(a is not None for a in active):
                if sched.pending:
                    raise PoolExhausted(
                        f"queue head needs more than the whole pool "
                        f"({self.n_pages} pages)")
                break
            # every active slot must own the page its next token writes to;
            # on exhaustion evict the youngest younger slot — or self, if
            # none is younger (oldest-first order makes progress certain)
            order = sorted((s for s in range(self.slots)
                            if active[s] is not None),
                           key=lambda s: sched.admitted_at[s])
            for s in order:
                while (active[s] is not None
                       and pool.ensure(s, int(pos[s]) + 1) is None):
                    victim = sched.choose_victim(s)
                    if victim is not None:
                        preempt(victim)
                        continue
                    if not any(active[t] is not None
                               for t in range(self.slots) if t != s):
                        raise PoolExhausted(
                            f"sequence in slot {s} needs "
                            f"{int(pos[s]) + 1} tokens of KV but the pool "
                            f"holds {self.n_pages} pages total")
                    preempt(s)      # yield to older slots; retry later

            ts = time.monotonic()
            cache_in = pool.install_tables(arena)
            toks = jnp.asarray(next_tok[:, None].astype(np.int32))
            posv = jnp.asarray(pos.astype(np.int32))
            logits, arena = self._decode(self.params, toks, cache_in, posv)
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            self.stats.decode_steps += 1

            emitted = 0
            for s in range(self.slots):
                req = active[s]
                if req is None:
                    continue
                pos[s] += 1
                tok = int(nxt[s])
                next_tok[s] = tok
                req.out_tokens.append(tok)
                self.stats.tokens_out += 1
                emitted += 1
                if _finished(req, int(pos[s]), self.max_len):
                    finish(s)
            self.stats.step_seconds.append(time.monotonic() - ts)
            self.stats.step_tokens.append(emitted)
            admit()

        self.stats.preemptions = sched.preemptions
        self.stats.pages_peak = max(self.stats.pages_peak, pool.pages_peak)
        self.stats.wall_s = time.monotonic() - t0
        return requests


# ==========================================================================
# legacy per-slot engine (baseline)
# ==========================================================================
class LegacyServeEngine:
    """Original continuous-batching-lite loop: per-slot batch-1 contiguous

    caches, one sequential jit decode call per active slot per token."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 256, cache_dtype=jnp.float32):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        self.stats = EngineStats()
        self._decode = _decode_jit(cfg)

    def _prefill_one(self, prompt: np.ndarray):
        tokens = jnp.asarray(prompt)[None, :]
        logits, cache = prefill(self.cfg, self.params, tokens,
                                max_len=self.max_len,
                                cache_dtype=self.cache_dtype)
        self.stats.prefills += 1
        return int(jnp.argmax(logits[0])), cache

    def run(self, requests: List[Request],
            greedy: bool = True) -> List[Request]:
        """Process all requests to completion; returns them with outputs.

        Stats describe this run only (a fresh EngineStats per call)."""
        if not greedy:
            raise NotImplementedError("only greedy decoding is implemented")
        self.stats = EngineStats()
        t0 = time.monotonic()
        queue = list(requests)
        # slot state: per-slot cache (batch dim 1) + active request
        active: List[Optional[Request]] = [None] * self.slots
        caches: List = [None] * self.slots
        positions = [0] * self.slots
        next_tok = [0] * self.slots

        def refill():
            for s in range(self.slots):
                while active[s] is None and queue:
                    req = queue.pop(0)
                    tok, cache = self._prefill_one(req.prompt)
                    req.out_tokens.append(tok)
                    self.stats.tokens_out += 1
                    if _finished(req, len(req.prompt), self.max_len):
                        req.done = True   # EOS at prefill: no decode slot
                        continue
                    active[s] = req
                    caches[s] = cache
                    positions[s] = len(req.prompt)
                    next_tok[s] = tok

        refill()
        while any(a is not None for a in active):
            for s in range(self.slots):
                req = active[s]
                if req is None:
                    continue
                ts = time.monotonic()
                tok = jnp.asarray([[next_tok[s]]], jnp.int32)
                logits, caches[s] = self._decode(
                    self.params, tok, caches[s],
                    jnp.asarray(positions[s], jnp.int32))
                positions[s] += 1
                nxt = int(jnp.argmax(logits[0]))
                next_tok[s] = nxt
                req.out_tokens.append(nxt)
                self.stats.decode_steps += 1
                self.stats.tokens_out += 1
                self.stats.step_seconds.append(time.monotonic() - ts)
                self.stats.step_tokens.append(1)
                if _finished(req, positions[s], self.max_len):
                    req.done = True
                    active[s] = None
                    caches[s] = None
            refill()
        self.stats.wall_s = time.monotonic() - t0
        return requests
