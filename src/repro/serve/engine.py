"""Serving engines: paged continuous batching (default) + legacy per-slot.

``ServeEngine`` is the paged engine: every round, all active slots run in
ONE ``jax.jit`` step against a shared paged KV arena (``serve/paged_kv.py``)
— decode lanes carry one token each, prefilling lanes carry a chunk of
their prompt, and both co-schedule in the same ragged step
(``serve/steps.py``), with FIFO admission, a per-round chunk budget and
recompute-style preemption (``serve/scheduler.py``). Weights may be dense
fp or the QMC serving format (ShardedQTensor / QTensor stacks) — matmul
dispatch handles either, so the paper's eMEM-resident weights and the
LPDDR5-resident paged KV stream meet in the same step function.

``LegacyServeEngine`` keeps the original loop — N sequential batch-1 decode
calls over per-slot contiguous caches — as the parity/throughput baseline
for ``benchmarks/serving.py``.

Under greedy decoding both engines are token-identical: chunked prefill
scatters the same K/V values a one-shot contiguous prefill computes (int8
caches share one quantizer, ``models.kvcache.quantize_kv``), causal
attention makes each query's output independent of how the prompt was
chunked, and masked pages contribute exp(-1e30) = 0 to the softmax.

Token selection is FUSED into the jitted step (``serve/sampling.py``):
the paged engine's rounds move ``[B, C]`` selected token ids (+ per-token
logprobs) across the jit boundary, never the raw ``[B, C, V]`` logits.
Per-request :class:`~repro.serve.sampling.SamplingParams` ride on
``Request.sampling`` (engine-wide default via the ``sampling=`` ctor
arg); greedy — the default — takes the bitwise argmax oracle path.
``speculative_k > 0`` adds self-speculative greedy decode: a prompt-
lookup draft proposes up to k tokens per decode lane and ONE verify call
on an already-compiled ``width_ladder`` rung accepts a prefix
(``serve/speculative.py``); rejected positions roll back through
``PagedKVPool.trim`` and the fused page-op queue.

The pipelined round loop (``pipelined=True``)
---------------------------------------------
Steady-state decode runs as a device-resident loop: a pure-decode round
with an idle admission queue dispatches its step WITHOUT blocking and is
retired one round later, overlapped with the next round's host planning
and dispatch. The contract:

  * **What overlaps.** Only pure-decode rounds (no prefill chunks, no
    speculative verify, no pending admissions). The next round's input
    tokens are the previous step's on-device output, fed straight back
    in (``steps.carry_decode_tokens``) — decode tokens never round-trip
    through host. Readback starts asynchronously at dispatch
    (``copy_to_host_async`` where available); emission, EOS checks and
    scheduling run at retire, one round behind the in-flight dispatch.
  * **What barriers.** Admission, preemption, prefill grants, and any
    allocation that needs eviction-by-preemption drain the pipeline:
    retire the in-flight round, then run the next round synchronously
    (its fused ``apply_page_ops`` flush therefore dispatches only after
    the drained round's state is final — refcount/COW invariants and
    the one-dispatch-per-round cost-attribution contract survive).
    Plain decode page growth and prefix-cache evictions are NOT
    barriers: their table flush is device-ordered behind the in-flight
    step by the arena data dependency.
  * **EOS lag.** Budget and capacity finishes are predicted at dispatch
    (``FifoScheduler.grant_decode``), so only an EOS landing during the
    one-round lag overruns — by exactly the one in-flight token, which
    is never emitted: its lane's pages are rolled back via the same
    ``PagedKVPool.trim`` used for rejected speculative drafts
    (``EngineStats.lag_trimmed_tokens``), and the lane's slot is freed
    only once the overrun round retires.
  * **Parity.** Sampling keys fold from absolute positions only and
    greedy is a bitwise argmax, so pipelined decode is token-identical
    to the synchronous loop for greedy, sampled and speculative lanes
    (speculative rounds need retired host history to draft, so they
    simply never overlap — and speculative greedy equals plain greedy).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.memsys.workload import chunk_pages_streamed
from repro.obs import costs as obs_costs
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.models.config import ModelConfig
from repro.models.model import prefill
from repro.serve import sampling as samplib
from repro.serve import speculative
from repro.serve import steps as serve_steps
from repro.serve.paged_kv import PagedKVPool, PoolExhausted, pages_for
from repro.serve.prefix_cache import PrefixCache
from repro.serve.sampling import SamplingParams
from repro.serve.scheduler import (FifoScheduler, SchedulerConfig,
                                   bucket_len)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # [S] int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # token-selection policy; None -> the engine default (greedy unless
    # the engine was built with sampling=...)
    sampling: Optional[SamplingParams] = None
    # selected-token model logprobs, parallel to out_tokens — filled
    # only when the effective SamplingParams has logprobs=True (cleared
    # with out_tokens on preemption recompute)
    out_logprobs: List[float] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0                # prompts fully prefilled
    prefill_chunks: int = 0          # chunk executions (>= prefills)
    decode_steps: int = 0            # rounds that advanced a decode lane
    tokens_out: int = 0
    wall_s: float = 0.0
    preemptions: int = 0
    pages_peak: int = 0
    tokens_discarded: int = 0        # emitted then erased by preemption
    # per-step K/V gather work (page counts): `live` is what the ragged
    # page-table kernel streams (the bytes the DSE charges); `full` is the
    # block-table width the XLA reference gather reads. Decode lanes land
    # in kv_pages_*; prefill chunks in prefill_kv_pages_* (their stream is
    # per q block — memsys.workload.chunk_pages_streamed — and their
    # writes are page-rounded, the kv_traffic_chunked account)
    kv_pages_live: int = 0
    kv_pages_full: int = 0
    prefill_kv_pages_live: int = 0
    prefill_kv_pages_written: int = 0
    # prefix cache (all zero when caching is off)
    prompt_tokens: int = 0           # prompt tokens across admissions
    prefill_tokens: int = 0          # tokens actually prefilled (suffixes)
    prefill_tokens_padded: int = 0   # same, after chunk-width padding
    cache_hits: int = 0              # admissions served partly from cache
    cache_hit_tokens: int = 0        # prompt tokens adopted (cache+dedup)
    dedup_hits: int = 0              # admissions aliasing an in-flight
    #                                  identical prompt's live slot pages
    cow_copies: int = 0              # shared pages privatized on write
    cache_evictions: int = 0         # cached pages evicted under pressure
    # per jit round: wall seconds and tokens emitted by that round (the
    # emitted count includes tokens a later preemption discards — the jit
    # work was really done; tokens_discarded records how many). First
    # tokens land in the round their prompt's last chunk runs.
    step_seconds: List[float] = dataclasses.field(default_factory=list)
    step_tokens: List[int] = dataclasses.field(default_factory=list)
    # per request (first emission only — a preempted request's recompute
    # does not reset its clock): seconds from run() start to first token
    ttft_s: List[float] = dataclasses.field(default_factory=list)
    # --- observability (obs/) ------------------------------------------
    rounds: int = 0                  # engine rounds that ran a jit step
    # host↔device page-op round trips (the host overhead that once made
    # cached prefill slower than uncached, now fused — see below):
    # adopt_calls/device_tables_rebuilds are fed by PagedKVPool counters
    # (serve/paged_kv.py), page_copy_calls counts the engine's COW
    # page-copy dispatches (the device half of pool.cow)
    adopt_calls: int = 0
    page_copy_calls: int = 0
    device_tables_rebuilds: int = 0
    # batched page-ops (serve/steps.py apply_page_ops): flushes counts
    # fused dispatches, batched counts the individual ops they absorbed
    # (COW copies + state resets + the round's table rebuild) — the
    # difference is host↔device round trips the fusion saved vs the
    # one-dispatch-per-op admit path
    page_op_flushes: int = 0
    page_ops_batched: int = 0
    # rounds run through the B=1 solo-lane step (exactly one live lane)
    solo_rounds: int = 0
    # pipelined round loop (engine built with pipelined=True): rounds
    # whose retire was deferred behind the next dispatch (async
    # readback, device-token carry), drain events (admission /
    # preemption / prefill / alloc-pressure barriers, incl. the final
    # drain), and tokens computed past an EOS that landed during the
    # one-round lag — trimmed via PagedKVPool.trim, never emitted
    pipelined_rounds: int = 0
    pipeline_barriers: int = 0
    lag_trimmed_tokens: int = 0
    # self-speculative decode: rounds that carried a verify lane, draft
    # tokens proposed, and draft tokens the model accepted (the bonus
    # emissions beyond what plain decode would have produced)
    spec_rounds: int = 0
    spec_draft_tokens: int = 0
    spec_accepted_tokens: int = 0
    # serving-jit compiles observed during this run (TracedJit deltas
    # over the step set — nonzero on a warm engine means an unexpected
    # retrace) and the wall seconds those compiling calls took
    jit_compiles: int = 0
    jit_compile_s: float = 0.0
    # cumulative wall seconds per round phase (span names per the
    # obs/trace.py contract: round/admit .. round/emit) — recorded even
    # with tracing disabled, so benchmarks can attribute host vs device
    # vs compile share without parsing a trace file
    phase_seconds: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    # per-request emission timestamps (time.monotonic), keyed by uid —
    # the source of truth for inter-token latency; a preempted request's
    # discarded emissions are dropped with its tokens
    emit_times: Dict[int, List[float]] = dataclasses.field(
        default_factory=dict)

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / self.wall_s if self.wall_s else 0.0

    @property
    def spec_acceptance_rate(self) -> float:
        """Fraction of proposed draft tokens the model accepted."""
        return (self.spec_accepted_tokens / self.spec_draft_tokens
                if self.spec_draft_tokens else 0.0)

    @property
    def pipeline_overlap(self) -> float:
        """Fraction of rounds retired through the async pipeline."""
        return self.pipelined_rounds / self.rounds if self.rounds else 0.0

    @property
    def page_op_round_trips_saved(self) -> int:
        """Device dispatches the fused page-op path avoided."""
        return max(0, self.page_ops_batched - self.page_op_flushes)

    @property
    def hit_rate(self) -> float:
        """Fraction of admitted prompt tokens served from cached pages."""
        return (self.cache_hit_tokens / self.prompt_tokens
                if self.prompt_tokens else 0.0)

    @property
    def prefill_token_reduction(self) -> float:
        """1 - (tokens prefilled / tokens a cache-less engine prefills)."""
        return (1.0 - self.prefill_tokens / self.prompt_tokens
                if self.prompt_tokens else 0.0)

    def per_token_latencies(self) -> List[float]:
        return [s / t for s, t in zip(self.step_seconds, self.step_tokens)
                if t]

    def itl_s(self) -> List[float]:
        """Per-request inter-token latencies from emission timestamps.

        Gaps between consecutive emissions of the same request — the
        decode-lane experience — unlike ``per_token_latencies`` which
        averages a whole round over every token it emitted and so lets
        co-scheduled prefill chunks inflate decode ITL."""
        gaps: List[float] = []
        for times in self.emit_times.values():
            gaps.extend(b - a for a, b in zip(times, times[1:]))
        return gaps

    _DEVICE_PHASES = ("round/device_step", "round/dispatch",
                      "round/retire")

    def host_seconds(self) -> float:
        """Wall seconds in host-side round phases — admission, grants,
        host array prep, emission bookkeeping: the planning work only
        the host can do. Excludes every device-coupled span: the
        synchronous step, the pipelined retire (readback wait, with the
        emission bookkeeping riding inside it charged to the device
        side as a documented approximation), and the pipelined dispatch
        — nominally a pure async enqueue, but backends that bound their
        in-flight queue (CPU XLA) block the enqueue on the previous
        round's compute, so its wall is device wait too."""
        return sum(v for k, v in self.phase_seconds.items()
                   if k not in self._DEVICE_PHASES)

    def device_seconds(self) -> float:
        """Wall seconds blocked on or waiting for the device: the
        synchronous step phase plus the pipelined dispatch and retire
        spans (enqueue backpressure + readback wait). Includes jit
        compile time on cold geometries — ``jit_compile_s`` bounds that
        part."""
        return sum(self.phase_seconds.get(k, 0.0)
                   for k in self._DEVICE_PHASES)


def _finished(req: Request, pos: int, max_len: int) -> bool:
    """Termination test shared by both engines (applied after each emit):

    budget spent, EOS emitted (including at prefill), or the next decode
    would write past the cache capacity (positions 0..max_len-1 are
    writable, so the cache is full once pos == max_len)."""
    return (len(req.out_tokens) >= req.max_new_tokens
            or (req.eos_id is not None and req.out_tokens
                and req.out_tokens[-1] == req.eos_id)
            or pos >= max_len)


class _PhaseSpan:
    """Times one round phase: accumulates into ``EngineStats.
    phase_seconds``, observes the ``serve_phase_seconds{phase}``
    histogram, and (when tracing is on) records the span on the tracer.
    A plain class CM so the round loop pays two ``perf_counter`` calls
    per phase, nothing more, with tracing disabled."""

    __slots__ = ("name", "tracer", "hist", "stats", "t0")

    def __init__(self, name, tracer, hist, stats):
        self.name, self.tracer, self.hist = name, tracer, hist
        self.stats = stats

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dt = time.perf_counter() - self.t0
        ph = self.stats.phase_seconds
        ph[self.name] = ph.get(self.name, 0.0) + dt
        self.hist.observe(dt, phase=self.name)
        self.tracer.complete(self.name, self.t0, dt)
        return False


# ==========================================================================
# paged continuous-batching engine (default)
# ==========================================================================
class ServeEngine:
    """Continuous batching over a paged KV pool.

    ``slots`` bounds concurrent sequences; ``max_len`` is each sequence's
    logical capacity (prompt + generated). ``n_pages`` sizes the shared
    pool — the default fits every slot at full length, so preemption only
    occurs when the caller shrinks it (memory-pressure experiments).

    ``chunk_tokens`` is the prefill chunk width: prompts are consumed in
    fixed-size chunks that scatter straight into the arena and co-schedule
    with decode lanes in the same jit step (attention-only stacks; hybrid
    stacks interleave chunk rounds and decode rounds because the SSM
    recurrence cannot mix a 1-token update into a multi-token scan
    bitwise). The default — one chunk covers the longest admissible
    prompt — is "monolithic" prefill through the very same ragged path.
    Each round runs at the smallest width on the compiled ladder that
    covers its widest grant: C = 1 for pure decode, else a pow2 rung
    from ``serve_steps.width_ladder`` — so a cached-prefix suffix or a
    short tail chunk is not padded out to the full chunk. The ladder is
    log2(chunk/4) + 2 shapes, lru-shared across engines.

    ``prefix_cache=True`` keeps finished prompts' full KV pages in a radix
    index (``serve/prefix_cache.py``): admissions whose prompt shares a
    cached page-aligned prefix adopt those pages copy-on-write and prefill
    only the uncached suffix (in chunks, straight against the arena). The
    pool and arena then persist across ``run()`` calls so a shared system
    prompt is paid for once per server, not once per batch. Requires an
    attention-only stack — KV pages cannot snapshot SSM/conv state.

    On attention-only stacks the scheduler also runs **in-flight dedup**
    (a pending-prefill table): identical prompts admitted while an earlier
    copy still occupies a slot alias that slot's full prompt pages instead
    of prefilling them again — no radix index required.

    ``paged_attention=True`` runs every step's attention through the
    ragged Pallas page-table kernel (``kernels/paged_attention.py``):
    each lane streams only its causally-live pages instead of the full
    block-table width — token-identical to the reference gather under
    greedy decoding; ``EngineStats`` records the gather-work gap either
    way.

    ``weight_plan=True`` (default) lowers QMC stream-format weights once
    at engine setup into the backend's execution form
    (``core.serving_quant.build_exec_weights``) so the per-call step
    graph is as lean as the dense engine's; ``self.params`` keeps the
    stream tree for cost attribution. Dense weights are unaffected;
    mesh engines keep TP-local stream compute regardless.

    Per round, all page maintenance (COW copies, admission state resets,
    the device block-table rebuild) is queued host-side and flushed in
    ONE fused ``apply_page_ops`` jit call before the step — pure decode
    rounds with clean tables skip the dispatch entirely — and rounds
    with exactly one live lane run the B=1 ``solo_step`` instead of the
    full-width batch (``EngineStats.solo_rounds``), which is what keeps
    a cache-miss leader prefill from paying ``slots``-wide dead compute.

    ``sampling`` sets the engine-default
    :class:`~repro.serve.sampling.SamplingParams` (greedy when omitted;
    per-request ``Request.sampling`` overrides). ``speculative_k > 0``
    turns on self-speculative greedy decode: each greedy decode lane may
    propose up to k prompt-lookup draft tokens per round
    (``serve/speculative.py``) and verify them in ONE step call on the
    smallest ``width_ladder`` rung covering ``1 + k`` — zero new
    compiled shapes; draft tokens draw on the round's prefill budget
    (``FifoScheduler.grant_verify``); rejected positions return their
    tail pages via ``PagedKVPool.trim``. Attention-only stacks only
    (SSM state cannot roll back), and sampled (``temperature > 0``)
    lanes always decode one token at a time.

    ``pipelined=True`` overlaps host and device work on steady-state
    decode per the module-docstring pipeline contract: pure-decode
    rounds dispatch without blocking, carry the previous step's
    on-device tokens as input, and retire via async readback one round
    later; mutation rounds drain the pipeline first. Token-identical to
    the default synchronous loop on every lane type; the new
    ``round/dispatch``/``round/retire`` spans and ``serve_pipeline_*``
    metrics record the overlap.

    ``mesh`` (a jax Mesh with ``data``/``model`` axes) runs every step
    sharded: the arena's page axis over ``data``, attention heads / TP
    weight dims (including ShardedQTensor stream stacks) over ``model``.
    All step functions come from ``serve/steps.py`` — the same builder
    layer ``launch/serve.py`` uses — either built here or passed in
    prebuilt via ``step_set``.

    ``tracer`` / ``metrics`` plug the engine into the obs subsystem
    (``repro.obs``): every round records phase spans (``round/admit`` /
    ``round/grant`` / ``round/host_prep`` / ``round/device_step`` /
    ``round/emit``) and request lifecycle instants per the
    ``obs/trace.py`` naming contract, plus counters/histograms per the
    ``obs/metrics.py`` contract. Both default to the process-wide
    instances (``obs.trace.get_tracer()`` is disabled until e.g.
    ``launch/serve.py --trace-out`` turns it on, so the default engine
    pays one branch per span site).
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 256, cache_dtype=jnp.float32,
                 page_size: int = 16, n_pages: Optional[int] = None,
                 max_prefill_tokens: Optional[int] = None,
                 chunk_tokens: Optional[int] = None,
                 prefix_cache: bool = False, mesh=None,
                 step_set: Optional[serve_steps.PagedServeSteps] = None,
                 inflight_dedup: Optional[bool] = None,
                 paged_attention: bool = False,
                 weight_plan: bool = True,
                 sampling: Optional[SamplingParams] = None,
                 speculative_k: int = 0,
                 pipelined: bool = False,
                 tracer: Optional[obs_trace.Tracer] = None,
                 metrics: Optional[obs_metrics.Registry] = None):
        if cfg.is_encdec or cfg.n_vis_tokens:
            raise NotImplementedError(
                "paged engine covers decoder-only models; use "
                "LegacyServeEngine for encdec/vlm")
        attn_only = all(k.startswith("attn") for k in cfg.pattern)
        if (prefix_cache or inflight_dedup) and not attn_only:
            raise NotImplementedError(
                "prefix caching / in-flight dedup share attention KV "
                "pages; SSM/conv state is not page-addressable — disable "
                f"them for hybrid/mamba stacks (pattern={cfg.pattern})")
        if speculative_k > 0 and not attn_only:
            raise NotImplementedError(
                "self-speculative decode rolls rejected positions back "
                "via valid_len masking + page trim; SSM/conv state has "
                "no per-position rollback — attention-only stacks only "
                f"(pattern={cfg.pattern})")
        self.cfg = cfg
        self.mesh = mesh
        if mesh is not None:
            from repro.launch import sharding as shd
            params = jax.device_put(params,
                                    shd.shard_params_tree(params, mesh))
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        self.page = page_size
        self.max_pages_per_seq = pages_for(max_len, page_size)
        self.n_pages = n_pages or serve_steps.default_n_pages(
            slots, self.max_pages_per_seq, mesh)
        self.max_prefill_tokens = (max_prefill_tokens
                                   or max(512, bucket_len(max_len,
                                                          page_size)))
        self.chunk = chunk_tokens or serve_steps.default_chunk(
            self.max_pages_per_seq, page_size)
        self._widths = serve_steps.width_ladder(self.chunk)
        self.stats = EngineStats()
        self.paged_attention = paged_attention
        self._tracer = tracer          # None -> process default at run()
        self._metrics = metrics
        # token selection: the engine-wide default policy (per-request
        # Request.sampling overrides), host-side per-lane param tables
        # the step's traced sampling pytree is built from, and the max
        # draft length per verify round (0 = speculative decode off)
        self._default_sp = sampling or samplib.GREEDY
        self._samp = samplib.lane_inputs(slots)
        self._slot_sp: List[SamplingParams] = [samplib.GREEDY] * slots
        self._spec_k = int(speculative_k)
        self._pipelined = bool(pipelined)
        self._dedup = attn_only if inflight_dedup is None \
            else inflight_dedup
        # co-scheduling a 1-token decode into a C-wide step is bitwise
        # for attention (per-query independence) but not for the SSM
        # scan (s==1 takes the O(1) recurrence, s>1 the chunked SSD
        # path) — hybrid stacks run chunk rounds and decode rounds
        # separately instead
        self._co_schedule = attn_only
        if step_set is not None:
            if step_set.cfg != cfg or step_set.mesh != mesh or \
                    not step_set.compatible_with(
                        page=self.page, n_pages=self.n_pages,
                        max_slots=slots,
                        max_pages_per_seq=self.max_pages_per_seq,
                        cache_dtype=cache_dtype, chunk=self.chunk,
                        paged_attention=paged_attention):
                raise ValueError(
                    "step_set was built for a different engine geometry "
                    "(cfg/mesh/page/n_pages/slots/cache_dtype/chunk must "
                    "match)")
        self._steps = step_set
        # serving weight plan (core/serving_quant.build_exec_weights):
        # the stream-format tree stays the source of truth (self.params,
        # cost attribution); the step consumes the one-time execution
        # lowering. Single-device only — mesh engines run TP-local
        # through qmm_shard_map on the streams themselves.
        self._weight_plan = weight_plan and mesh is None
        self._exec_params = None
        if self._weight_plan:
            # build at engine setup, like the jit warm-up: run() walls
            # must measure serving, not the one-time lowering
            from repro.core.serving_quant import build_exec_weights
            self._exec_params = jax.block_until_ready(
                build_exec_weights(self.params))
        # page ops queued by seat() and flushed once per round through
        # the fused apply_page_ops jit (steps without it — a prebuilt
        # legacy step_set — keep the one-dispatch-per-op path)
        self._pending_copies: List = []
        self._pending_resets: List[int] = []
        # pool + arena (+ prefix index) persist across run() calls so
        # cached pages survive between batches, server-style
        self._use_prefix = prefix_cache
        self._pool: Optional[PagedKVPool] = None
        self._arena = None
        self.prefix_cache: Optional[PrefixCache] = None
        # filled by run() when obs.costs capture is enabled: per-step-fn
        # roofline attribution + modeled memsys cost of the last run
        self.last_cost_report: Optional[obs_costs.CostReport] = None

    def _build_steps(self) -> serve_steps.PagedServeSteps:
        p_struct = None
        if self.mesh is not None:
            p_struct = jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                self.params)
        return serve_steps.build_paged_steps(
            self.cfg, self.mesh, p_struct, page=self.page,
            n_pages=self.n_pages, max_slots=self.slots,
            max_pages_per_seq=self.max_pages_per_seq,
            cache_dtype=self.cache_dtype, chunk=self.chunk,
            paged_attention=self.paged_attention)

    def _ensure_pool(self) -> PagedKVPool:
        if self._pool is None:
            self._pool = PagedKVPool(
                self.cfg, n_pages=self.n_pages, page=self.page,
                max_slots=self.slots,
                max_pages_per_seq=self.max_pages_per_seq,
                cache_dtype=self.cache_dtype)
            self._arena = self._pool.init_arena()
            if self._steps is None:
                self._steps = self._build_steps()
            if self.mesh is not None:
                from repro.launch import sharding as shd
                self._arena = jax.device_put(
                    self._arena,
                    shd.shard_paged_cache_tree(self._arena, self.mesh))
            if self._use_prefix:
                self.prefix_cache = PrefixCache(self._pool,
                                                tracer=self._tracer)
        return self._pool

    def _step_params(self):
        """Params tree the jitted step consumes: the lazily built weight
        execution plan, or the raw tree when the plan is off / mesh."""
        if not self._weight_plan:
            return self.params
        if self._exec_params is None:
            from repro.core.serving_quant import build_exec_weights
            self._exec_params = build_exec_weights(self.params)
        return self._exec_params

    def _flush_page_ops(self, pool: PagedKVPool):
        """Apply the round's queued page copies / state resets and the
        block-table rebuild in ONE fused jit call; a round with nothing
        queued and clean tables skips the dispatch entirely. Returns the
        arena the step should consume."""
        copies, resets = self._pending_copies, self._pending_resets
        if self._steps.apply_page_ops is None:   # legacy step set
            for cw in copies:
                self._arena = self._steps.page_copy(self._arena, *cw)
            for s in resets:
                self._arena = self._steps.reset_state(self._arena, s)
            copies.clear()
            resets.clear()
            return pool.install_tables(self._arena)
        if not (copies or resets or pool.tables_dirty):
            return self._arena
        pool.check_tables()
        tables = jnp.asarray(pool.block_tables)
        reset_mask = np.zeros(self.slots, bool)
        for s in resets:
            reset_mask[s] = True
        n_ops = len(copies) + len(resets) + 1
        first = True
        while first or copies:      # > slots copies drain in extra calls
            src = np.zeros(self.slots, np.int32)
            dst = np.zeros(self.slots, np.int32)
            batch, copies[:] = copies[:self.slots], copies[self.slots:]
            for i, (a, b) in enumerate(batch):
                src[i], dst[i] = a, b
            self._arena = self._steps.apply_page_ops(
                self._arena, jnp.asarray(src), jnp.asarray(dst),
                tables, jnp.asarray(reset_mask))
            self.stats.page_op_flushes += 1
            reset_mask[:] = False
            first = False
        pool.tables_rebuilds += 1
        pool.tables_dirty = False
        self.stats.page_ops_batched += n_ops
        resets.clear()
        return self._arena

    def _alloc(self, slot: int, n_tokens: int) -> Optional[List[int]]:
        """pool.ensure with LRU eviction of unpinned cached pages as the
        fallback; None only when eviction cannot help either."""
        while True:
            fresh = self._pool.ensure(slot, n_tokens)
            if fresh is not None:
                return fresh
            if self.prefix_cache is None or not self.prefix_cache.evict(1):
                return None
            self.stats.cache_evictions += 1

    def run(self, requests: List[Request], greedy: bool = True,
            on_token=None) -> List[Request]:
        """Process all requests to completion; returns them with outputs.

        Token selection follows each request's
        :class:`~repro.serve.sampling.SamplingParams` (``Request.
        sampling``; the engine's ``sampling=`` default otherwise, greedy
        out of the box) — the ``greedy`` flag is kept for API
        compatibility and no longer gates anything. With
        ``logprobs=True`` the selected token's model logprob lands in
        ``request.out_logprobs``, parallel to ``out_tokens`` (read
        ``request.out_logprobs[-1]`` inside ``on_token`` to stream it).

        **EOS contract** (greedy, sampled and speculative paths agree):
        a generated ``eos_id`` IS emitted — appended to ``out_tokens``,
        streamed through ``on_token``, counted in ``tokens_out`` — and
        generation stops immediately after; speculative acceptance
        truncates at the first EOS, so no tokens ever follow it.

        ``on_token(slot, token, request)`` — when given — streams every
        emitted token: once in the round a request's last prefill chunk
        produces its first token (slot is -1 if the request finished at
        prefill without ever decoding) and once per accepted token per
        active decode lane after each jitted round (a verify round can
        emit several). A preempted request re-streams from its
        first token when recomputed; consumers that must not see
        duplicates should key on ``request.uid`` and truncate.

        Stats describe this run only (a fresh EngineStats per call); the
        prefix cache and its pages persist across calls."""
        del greedy                     # per-request SamplingParams rule
        self.stats = EngineStats()
        t0 = time.monotonic()
        for r in requests:
            if len(r.prompt) > self.max_len:
                raise ValueError(f"request {r.uid}: prompt length "
                                 f"{len(r.prompt)} > max_len={self.max_len}")
        pool = self._ensure_pool()
        # observability plumbing: explicit tracer/registry or the process
        # defaults (the default tracer is disabled — every span site is
        # then a single branch)
        trc = obs_trace.active(self._tracer)
        reg = self._metrics if self._metrics is not None \
            else obs_metrics.get_registry()
        phase_hist = reg.histogram(
            "serve_phase_seconds", "per-round phase wall time",
            labels=("phase",))

        def phase(name: str) -> _PhaseSpan:
            return _PhaseSpan(name, trc, phase_hist, self.stats)

        # the pool persists across runs: release slot pages a previously
        # aborted run may have left mapped (cached pages survive), and
        # re-base cumulative counters so stats cover this run only
        for s in range(self.slots):
            if pool.slot_pages[s]:
                pool.free_slot(s)
        pool.pages_peak = pool.used_count
        self._pending_copies.clear()   # an aborted run's stale queue
        self._pending_resets.clear()
        cow0 = pool.cow_copies
        adopt0 = pool.adopt_calls
        tbl0 = pool.tables_rebuilds
        _, jitc0, jits0 = self._steps.jit_counters()
        cost0 = obs_costs.snapshot(self._steps) \
            if obs_costs.capture_enabled() else None
        admissions = {"miss": 0, "hit": 0, "dedup": 0}
        cache = self.prefix_cache
        sched = FifoScheduler(SchedulerConfig(
            page=self.page, max_prefill_tokens=self.max_prefill_tokens,
            max_len=self.max_len, chunk=self.chunk), prefix_cache=cache,
            pool=pool if self._dedup else None, tracer=trc)
        for r in requests:
            sched.enqueue(r)

        active: List[Optional[Request]] = [None] * self.slots
        pos = np.zeros(self.slots, np.int64)   # next write position
        next_tok = np.zeros(self.slots, np.int64)
        seen_first: set = set()

        def prefilling(s: int) -> bool:
            return (active[s] is not None
                    and pos[s] < len(active[s].prompt))

        def emit(s: int, tok: int, req: Request,
                 now: Optional[float] = None) -> None:
            # pipelined retires pass the readback-complete timestamp so
            # the one-round lag never skews ttft/ITL; the sync path's
            # per-token clock reads are bit-identical to before
            if now is None:
                now = time.monotonic()
            self.stats.emit_times.setdefault(req.uid, []).append(now)
            if req.uid not in seen_first:
                seen_first.add(req.uid)
                self.stats.ttft_s.append(now - t0)
                trc.instant("req/first_token", uid=req.uid, slot=s)
            if on_token is not None:
                on_token(s, tok, req)

        def publish(req: Request, s: int) -> None:
            """Index the slot's full prompt pages (prefill KV reuse)."""
            if cache is not None:
                n_full = len(req.prompt) // self.page
                if n_full:
                    cache.insert(req.prompt, pool.slot_pages[s][:n_full])

        def finish(s: int, defer_rec=None) -> None:
            req = active[s]
            req.done = True
            active[s] = None
            if defer_rec is not None and s in defer_rec["act_dec"]:
                # EOS during the pipeline lag: the in-flight round
                # already computed (and allocated for) one more token on
                # this lane — keep the slot's pages mapped until that
                # round retires, then trim the overrun and free
                defer_rec["lag_free"].add(s)
            else:
                pool.free_slot(s)
            sched.on_finish(s)
            trc.instant("req/finished", uid=req.uid, slot=s,
                        tokens=len(req.out_tokens))

        def preempt(victim: int) -> None:
            req = active[victim]
            # recompute-style eviction: drop generated state, requeue; a
            # lane preempted mid-prompt has emitted nothing and releases
            # exactly the pages its chunks wrote (plus adopted refs)
            trc.instant("req/preempted", uid=req.uid, slot=victim,
                        discarded=len(req.out_tokens))
            self.stats.tokens_out -= len(req.out_tokens)
            self.stats.tokens_discarded += len(req.out_tokens)
            # discarded emissions must not contribute inter-token gaps
            self.stats.emit_times.pop(req.uid, None)
            req.out_tokens = []
            req.out_logprobs = []
            active[victim] = None
            pool.free_slot(victim)
            sched.on_preempt(victim)
            sched.requeue_front(req)

        def seat(adm, s: int) -> bool:
            """Seat an admission: adopt cached pages, allocate the first
            chunk's pages, COW the shared page a mid-page restart writes
            into, zero recurrent state. No model step runs here — chunks
            are scheduled round by round. False when pages ran out."""
            req = adm.req
            L = len(req.prompt)
            start = adm.suffix_start
            if adm.cached_pages:
                pool.adopt(s, adm.cached_pages)
            if self._alloc(s, min(L, start + self.chunk)) is None:
                pool.free_slot(s)
                return False
            cow = pool.cow(s, start) if adm.cached_pages else None
            while cow is False:
                if cache is None or not cache.evict(1):
                    pool.free_slot(s)
                    return False
                self.stats.cache_evictions += 1
                cow = pool.cow(s, start)
            if cow is not None:
                # queued, not dispatched: the whole round's copies /
                # resets / table rebuild fuse into one apply_page_ops
                # call right before the step (_flush_page_ops)
                self._pending_copies.append(cow)
                self.stats.page_copy_calls += 1
            if self._steps.reset_state is not None:
                self._pending_resets.append(s)
            active[s] = req
            pos[s] = start
            sp = req.sampling if req.sampling is not None \
                else self._default_sp
            self._slot_sp[s] = sp
            samplib.set_lane(self._samp, s, sp, req.uid)
            sched.on_admit(s)
            sched.note_progress(s, start)
            if adm.cached_pages:
                if adm.dedup:
                    self.stats.dedup_hits += 1
                    admissions["dedup"] += 1
                else:
                    self.stats.cache_hits += 1
                    admissions["hit"] += 1
                self.stats.cache_hit_tokens += start
            else:
                admissions["miss"] += 1
                sched.note_prefill(req, s)
                if cache is not None:
                    sched.miss_open(s)
            self.stats.prompt_tokens += L
            trc.instant("req/admitted", uid=req.uid, slot=s,
                        cached_tokens=start, dedup=adm.dedup)
            return True

        def admit() -> None:
            free_slots = [s for s in range(self.slots)
                          if active[s] is None]
            while free_slots:
                capacity = pool.free_count + (cache.evictable_pages()
                                              if cache else 0)
                adm = sched.next_admission(capacity)
                if adm is None:
                    break
                s = free_slots[0]
                ok = seat(adm, s)
                if not ok and adm.cached_pages:
                    # the hit pinned its matched pages, which may be the
                    # very pages the capacity check promised as evictable;
                    # degrade to an uncached admission that can evict them
                    adm.cached_pages, adm.cached_len = [], 0
                    adm.dedup = False
                    ok = seat(adm, s)
                if not ok:          # promised pages vanished; retry later
                    sched.requeue_front(adm.req)
                    break
                free_slots.pop(0)

        # ---- dispatch/retire machinery -----------------------------
        # Every round is dispatched exactly once through dispatch() and
        # emitted exactly once through process_round(); the synchronous
        # path gathers inline, the pipelined path (pipelined=True) keeps
        # one round in flight and retires it overlapped with the next
        # round's host work (module docstring: pipeline contract).

        def process_round(rec, nxt, logp_h, now=None, defer_rec=None):
            """Emission / EOS / scheduling for one completed round (the
            emit half of the round loop). Returns the tokens emitted.
            ``now`` stamps every emission (retires pass the readback-
            complete time); ``defer_rec`` is the round still in flight,
            whose lanes defer their page frees to its own retire."""
            plan, verify = rec["plan"], rec["verify"]
            act_dec, n_new = rec["act_dec"], rec["n_new"]
            c_len = rec["c_len"]
            emitted = 0
            for s in rec["order"]:
                req = active[s]
                if req is None:
                    continue
                if s in plan:
                    n = plan[s]
                    pos[s] += n
                    sched.note_progress(s, int(pos[s]))
                    self.stats.prefill_chunks += 1
                    self.stats.prefill_tokens += n
                    self.stats.prefill_tokens_padded += c_len
                    trc.instant("req/chunk_done", uid=req.uid,
                                slot=s, pos=int(pos[s]))
                    if int(pos[s]) < len(req.prompt):
                        continue        # mid-prompt: more chunks due
                    # last chunk: the logit at the prompt's final
                    # token is the request's first generated token
                    self.stats.prefills += 1
                    publish(req, s)
                    sched.miss_closed(s)
                    tok = int(nxt[s, n - 1])
                    assert tok != samplib.DEAD_TOKEN, \
                        f"emit read a dead lane (slot {s})"
                    req.out_tokens.append(tok)
                    if self._slot_sp[s].logprobs:
                        req.out_logprobs.append(
                            float(logp_h[s, n - 1]))
                    self.stats.tokens_out += 1
                    emitted += 1
                    if _finished(req, len(req.prompt), self.max_len):
                        req.done = True  # e.g. EOS at prefill: never
                        active[s] = None  # enters a decode round
                        pool.free_slot(s)
                        sched.on_finish(s)
                        emit(-1, tok, req, now)
                        trc.instant("req/finished", uid=req.uid,
                                    slot=-1,
                                    tokens=len(req.out_tokens))
                    else:
                        next_tok[s] = tok
                        emit(s, tok, req, now)
                elif s in act_dec:
                    # plain decode is a verify round with an empty
                    # draft: accept_greedy keeps the verified draft
                    # prefix + the model's correction token, and a
                    # draft-less lane accepts exactly its one token
                    n = int(n_new[s])
                    draft = verify.get(s)
                    if draft is not None:
                        n_acc = speculative.accept_greedy(
                            draft, nxt[s, :n])
                        self.stats.spec_draft_tokens += len(draft)
                        self.stats.spec_accepted_tokens += n_acc - 1
                    else:
                        n_acc = 1
                    fin = False
                    for j in range(n_acc):
                        tok = int(nxt[s, j])
                        assert tok != samplib.DEAD_TOKEN, \
                            f"emit read a dead lane (slot {s})"
                        pos[s] += 1
                        next_tok[s] = tok
                        req.out_tokens.append(tok)
                        if self._slot_sp[s].logprobs:
                            req.out_logprobs.append(
                                float(logp_h[s, j]))
                        self.stats.tokens_out += 1
                        emitted += 1
                        emit(s, tok, req, now)
                        if _finished(req, int(pos[s]), self.max_len):
                            # accepted tokens past EOS (or past the
                            # budget) are discarded, per the EOS
                            # contract on run()
                            finish(s, defer_rec)
                            fin = True
                            break
                    if draft is not None and not fin \
                            and n_acc < n:
                        # speculative rollback: tail pages allocated
                        # for rejected draft positions go back to
                        # the pool; their garbage K/V stays masked
                        # by valid_len until real tokens overwrite
                        # those positions
                        pool.trim(s, int(pos[s]))
            self.stats.step_seconds.append(
                (time.monotonic() if now is None else now) - rec["ts"])
            self.stats.step_tokens.append(emitted)
            return emitted

        def gather(rec):
            """Materialize a round's device outputs as full-width host
            arrays (dead lanes carry DEAD_TOKEN); blocks until the
            device — and any async readback — is done."""
            s0 = rec["solo_slot"]
            if s0 is not None:
                nxt = np.full((self.slots, rec["c_len"]),
                              samplib.DEAD_TOKEN, np.int64)
                logp_h = np.zeros((self.slots, rec["c_len"]),
                                  np.float32)
                nxt[s0] = np.asarray(rec["tok_dev"])[0]
                logp_h[s0] = np.asarray(rec["logp_dev"])[0]
            else:
                nxt = np.asarray(rec["tok_dev"])
                logp_h = np.asarray(rec["logp_dev"])
            return nxt, logp_h

        def readback_async(rec):
            # start the D2H copy at dispatch time so the retire's
            # gather finds it complete (or at least in flight); arrays
            # without the API just block in gather instead
            for arr in (rec["tok_dev"], rec["logp_dev"]):
                try:
                    arr.copy_to_host_async()
                except AttributeError:
                    pass

        def dispatch(toks_in, cache_in, start, n_new, solo_slot=None):
            """The round's ONE async step dispatch — never blocks; the
            sync path gathers inline, the pipelined path one round
            later."""
            if solo_slot is not None:
                tok_dev, logp_dev, self._arena = self._steps.solo_step(
                    self._step_params(), toks_in, cache_in,
                    np.int32(solo_slot),
                    jnp.asarray(start[solo_slot:solo_slot + 1]),
                    jnp.asarray(n_new[solo_slot:solo_slot + 1]),
                    {k: jnp.asarray(v[solo_slot:solo_slot + 1])
                     for k, v in self._samp.items()})
                self.stats.solo_rounds += 1
            else:
                tok_dev, logp_dev, self._arena = self._steps.step(
                    self._step_params(), toks_in, cache_in,
                    jnp.asarray(start), jnp.asarray(n_new),
                    {k: jnp.asarray(v) for k, v in self._samp.items()})
            return tok_dev, logp_dev

        def retire(rec, defer_rec=None):
            """Readback-complete + emission for a pipelined round. The
            lag-freed lanes (EOS during the lag) trim their overrun
            token's pages and release their slot HERE — only after the
            round that computed past the EOS has fully retired."""
            with phase("round/retire"):
                nxt, logp_h = gather(rec)
                now = time.monotonic()
                emitted = process_round(rec, nxt, logp_h, now=now,
                                        defer_rec=defer_rec)
                for s in sorted(rec["lag_free"]):
                    self.stats.lag_trimmed_tokens += int(rec["n_new"][s])
                    pool.trim(s, int(pos[s]))
                    pool.free_slot(s)
            return emitted

        inflight = None            # the dispatched-but-unretired round

        while any(a is not None for a in active) or sched.pending:
            r_t0 = time.perf_counter()
            if inflight is not None:
                # ---- pipelined fast path: grant pure-decode lanes
                # against the in-flight round's predicted state, carry
                # its on-device tokens into the next dispatch, THEN
                # retire it (emission overlaps the device step) ------
                dec: List[int] = []
                order = []
                barrier = sched.pending and any(a is None
                                                for a in active)
                if not barrier:
                    with phase("round/grant"):
                        order = sorted(
                            (s for s in range(self.slots)
                             if active[s] is not None),
                            key=lambda s: sched.admitted_at[s])
                        for s in order:
                            req = active[s]
                            if not sched.grant_decode(
                                    len(req.out_tokens),
                                    req.max_new_tokens, int(pos[s]),
                                    self.max_len, lead=1):
                                continue    # its last token retires in
                                #             a moment; nothing to grant
                            if s not in inflight["act_dec"]:
                                # no carried token for this lane: drain
                                # and let the sync path re-dispatch it
                                barrier = True
                                break
                            if self._alloc(s, int(pos[s]) + 2) is None:
                                barrier = True   # needs preemption
                                break
                            dec.append(s)
                if barrier or not dec:
                    # pipeline barrier: drain, then run the next round
                    # synchronously — admission/preemption/prefill see
                    # only retired state, and the sync round's page-op
                    # flush dispatches after this retire
                    self.stats.pipeline_barriers += 1
                    prev, inflight = inflight, None
                    retire(prev)
                    continue
                with phase("round/host_prep"):
                    start = np.zeros(self.slots, np.int32)
                    n_new = np.zeros(self.slots, np.int32)
                    for s in dec:
                        start[s] = int(pos[s]) + 1  # the in-flight
                        n_new[s] = 1                # token's position
                    ts = time.monotonic()
                    self.stats.kv_pages_live += sum(
                        pages_for(int(start[s]) + 1, self.page)
                        for s in dec)
                    self.stats.kv_pages_full += (
                        len(dec) * self.max_pages_per_seq)
                    cache_in = self._flush_page_ops(pool)
                    solo = (self._steps.solo_step is not None
                            and len(dec) == 1)
                with phase("round/dispatch"):
                    s0 = dec[0] if solo else None
                    tok_in = serve_steps.carry_decode_tokens(
                        inflight["tok_dev"], s0)
                    tok_dev, logp_dev = dispatch(
                        tok_in, cache_in, start, n_new, solo_slot=s0)
                    rec = {"order": dec, "plan": {}, "verify": {},
                           "act_dec": dec, "n_new": n_new, "c_len": 1,
                           "ts": ts, "tok_dev": tok_dev,
                           "logp_dev": logp_dev, "solo_slot": s0,
                           "lag_free": set()}
                    readback_async(rec)
                self.stats.decode_steps += 1
                self.stats.rounds += 1
                self.stats.pipelined_rounds += 1
                prev, inflight = inflight, rec
                emitted = retire(prev, defer_rec=rec)
                trc.counter("pool/pages", live=pool.used_count,
                            free=pool.free_count)
                trc.counter("sched/queue",
                            prefill_pending=sched.pending)
                trc.complete("round", r_t0,
                             time.perf_counter() - r_t0,
                             lanes=len(order), prefill_lanes=0,
                             decode_lanes=len(dec), emitted=emitted)
                continue
            with phase("round/admit"):
                sched.start_round()
                admit()
            if not any(a is not None for a in active):
                if sched.pending:
                    raise PoolExhausted(
                        f"queue head needs more than the whole pool "
                        f"({self.n_pages} pages)")
                break
            # --- plan the round: chunk grants for prefilling lanes, one
            # token per decode lane; every planned lane must own the
            # pages it writes — on exhaustion first evict unpinned cached
            # pages, then the youngest younger slot — or self, if none is
            # younger (oldest-first order makes progress certain)
            plan = {}                       # slot -> chunk tokens
            with phase("round/grant"):
                order = sorted((s for s in range(self.slots)
                                if active[s] is not None),
                               key=lambda s: sched.admitted_at[s])
                for s in order:
                    while active[s] is not None:
                        if prefilling(s):
                            n = plan.get(s)
                            if n is None:
                                n = sched.grant_chunk(
                                    len(active[s].prompt) - int(pos[s]))
                                if n == 0:
                                    break   # budget spent: idle a round
                                plan[s] = n
                            need = int(pos[s]) + n
                        else:
                            need = int(pos[s]) + 1
                        if self._alloc(s, need) is not None:
                            break
                        victim = sched.choose_victim(s)
                        if victim is not None:
                            plan.pop(victim, None)
                            preempt(victim)
                            continue
                        if not any(active[t] is not None
                                   for t in range(self.slots) if t != s):
                            raise PoolExhausted(
                                f"sequence in slot {s} needs "
                                f"{need} tokens of KV but the pool "
                                f"holds {self.n_pages} pages total")
                        plan.pop(s, None)
                        preempt(s)  # yield to older slots; retry later

                decode_lanes = [s for s in order if active[s] is not None
                                and not prefilling(s)]
                run_decode = bool(decode_lanes) and (self._co_schedule
                                                     or not plan)
                # self-speculative decode: draft up to k tokens per
                # greedy decode lane (prompt-lookup over its own
                # history) for a single verify step on an existing
                # ladder rung. Drafts are optional work: they draw on
                # the round budget after prefill grants and take extra
                # pages WITHOUT preemption — any shortfall just means
                # the lane decodes one token as usual
                verify: Dict[int, np.ndarray] = {}
                if run_decode and self._spec_k > 0:
                    for s in decode_lanes:
                        req = active[s]
                        if self._slot_sp[s].temperature > 0:
                            continue   # greedy acceptance only
                        want = min(self._spec_k, self.chunk - 1,
                                   self.max_len - int(pos[s]) - 1,
                                   req.max_new_tokens
                                   - len(req.out_tokens) - 1)
                        if want <= 0:
                            continue
                        hist = np.concatenate(
                            [np.asarray(req.prompt, np.int64),
                             np.asarray(req.out_tokens, np.int64)])
                        draft = speculative.propose(hist, want)
                        if draft.size == 0:
                            continue
                        granted = sched.grant_verify(len(draft))
                        if granted == 0:
                            continue
                        draft = draft[:granted]
                        if self._alloc(
                                s, int(pos[s]) + 1 + len(draft)) is None:
                            continue
                        verify[s] = draft
            if not plan and not run_decode:
                continue            # everything preempted/idled; re-admit

            with phase("round/host_prep"):
                max_n = max([max(plan.values(), default=0)]
                            + [1 + len(d) for d in verify.values()])
                # smallest compiled width covering the widest grant —
                # prefill chunk or speculative verify (pow2 ladder, see
                # the class docstring); pure-decode rounds stay at the
                # dedicated C = 1 shape
                c_len = 1 if max_n <= 1 else min(
                    [w for w in self._widths if w >= max_n]
                    or [self.chunk])
                toks = np.zeros((self.slots, c_len), np.int32)
                start = np.zeros(self.slots, np.int32)
                n_new = np.zeros(self.slots, np.int32)
                for s in range(self.slots):
                    if active[s] is None:
                        continue
                    start[s] = pos[s]
                    if s in plan:
                        n = plan[s]
                        n_new[s] = n
                        p0 = int(pos[s])
                        toks[s, :n] = active[s].prompt[p0:p0 + n]
                    elif not prefilling(s) and run_decode:
                        d = verify.get(s)
                        toks[s, 0] = next_tok[s]
                        if d is None:
                            n_new[s] = 1
                        else:
                            n_new[s] = 1 + len(d)
                            toks[s, 1:1 + len(d)] = d

                ts = time.monotonic()
                # gather-work accounting: decode lanes attend seq =
                # pos+n_new (the tokens being written included — n_new
                # is 1, or 1+k on a verify round); chunk lanes stream
                # per q block, page-for-page what kv_traffic_chunked
                # charges
                act_dec = decode_lanes if run_decode else []
                if verify:
                    self.stats.spec_rounds += 1
                self.stats.kv_pages_live += sum(
                    pages_for(int(pos[s]) + int(n_new[s]), self.page)
                    for s in act_dec)
                self.stats.kv_pages_full += (len(act_dec)
                                             * self.max_pages_per_seq)
                for s in plan:
                    self.stats.prefill_kv_pages_live += \
                        chunk_pages_streamed(int(pos[s]), plan[s],
                                             page=self.page)
                    self.stats.prefill_kv_pages_written += (
                        pages_for(int(pos[s]) + plan[s], self.page)
                        - int(pos[s]) // self.page)
                cache_in = self._flush_page_ops(pool)
                live = np.flatnonzero(n_new > 0)
                solo = (self._steps.solo_step is not None
                        and len(live) == 1)
            # a pure-decode round with an idle admission queue may enter
            # the pipeline: dispatch without blocking, retire one round
            # later (speculative lanes never overlap — drafting needs
            # retired host history)
            overlap = (self._pipelined and run_decode and not plan
                       and not verify and self._spec_k == 0
                       and not sched.pending)
            with phase("round/dispatch" if overlap
                       else "round/device_step"):
                # token selection runs INSIDE the jit (the sampling-head
                # epilogue): only [B, C] selected ids + logprobs cross
                # the boundary, and dead lanes come back as the
                # DEAD_TOKEN sentinel — never a forgeable vocab id
                if solo:
                    s0 = int(live[0])
                    tok_dev, logp_dev = dispatch(
                        jnp.asarray(toks[s0:s0 + 1]), cache_in,
                        start, n_new, solo_slot=s0)
                else:
                    s0 = None
                    tok_dev, logp_dev = dispatch(
                        jnp.asarray(toks), cache_in, start, n_new)
                rec = {"order": order, "plan": plan, "verify": verify,
                       "act_dec": act_dec, "n_new": n_new,
                       "c_len": c_len, "ts": ts, "tok_dev": tok_dev,
                       "logp_dev": logp_dev, "solo_slot": s0,
                       "lag_free": set()}
                if overlap:
                    readback_async(rec)
                else:
                    nxt, logp_h = gather(rec)
            if act_dec:
                self.stats.decode_steps += 1
            self.stats.rounds += 1
            if overlap:
                self.stats.pipelined_rounds += 1
                inflight = rec
                emitted = 0         # emissions land at this round's
                #                     retire, one round from now
            else:
                with phase("round/emit"):
                    emitted = process_round(rec, nxt, logp_h)
            # pool-pressure counter tracks, one sample per round — these
            # render as Perfetto counter lanes next to the phase spans
            trc.counter("pool/pages", live=pool.used_count,
                        free=pool.free_count)
            trc.counter("sched/queue", prefill_pending=sched.pending)
            trc.complete("round", r_t0, time.perf_counter() - r_t0,
                         lanes=len(order), prefill_lanes=len(plan),
                         decode_lanes=len(act_dec), emitted=emitted)

        if inflight is not None:
            # every lane finished (or deferred its free) during the lag
            # and the loop fell through: retire the last in-flight round
            retire(inflight)
            inflight = None

        self.stats.preemptions = sched.preemptions
        self.stats.pages_peak = max(self.stats.pages_peak, pool.pages_peak)
        self.stats.cow_copies = pool.cow_copies - cow0
        self.stats.adopt_calls = pool.adopt_calls - adopt0
        self.stats.device_tables_rebuilds = pool.tables_rebuilds - tbl0
        _, jitc1, jits1 = self._steps.jit_counters()
        self.stats.jit_compiles = jitc1 - jitc0
        self.stats.jit_compile_s = jits1 - jits0
        self.stats.wall_s = time.monotonic() - t0
        self._flush_metrics(reg, admissions)
        if cost0 is not None:
            report = obs_costs.attribute(
                self._steps, self.stats, cfg=self.cfg,
                params=self.params, page=self.page,
                kv_dtype_bits=jnp.dtype(self.cache_dtype).itemsize * 8,
                baseline=cost0)
            self.last_cost_report = report
            obs_costs.flush_metrics(reg, report)
        return requests

    def _flush_metrics(self, reg: obs_metrics.Registry,
                       admissions: Dict[str, int]) -> None:
        """Fold the finished run's EngineStats deltas into the registry
        (names per the ``obs/metrics.py`` contract)."""
        s = self.stats
        reg.counter("serve_rounds_total",
                    "engine rounds that ran a jit step").inc(s.rounds)
        tok = reg.counter("serve_tokens_total", "tokens emitted/discarded",
                          labels=("kind",))
        tok.inc(s.tokens_out, kind="emitted")
        tok.inc(s.tokens_discarded, kind="discarded")
        adm = reg.counter("serve_admissions_total",
                          "request admissions by prefix-cache outcome",
                          labels=("kind",))
        for kind, n in admissions.items():
            adm.inc(n, kind=kind)
        reg.counter("serve_preemptions_total",
                    "recompute-style slot evictions").inc(s.preemptions)
        ops = reg.counter("serve_page_ops_total",
                          "host<->device page-op round trips",
                          labels=("op",))
        ops.inc(s.adopt_calls, op="adopt")
        ops.inc(s.page_copy_calls, op="page_copy")
        ops.inc(s.device_tables_rebuilds, op="tables_rebuild")
        ops.inc(s.cow_copies, op="cow")
        ops.inc(s.cache_evictions, op="cache_evict")
        ops.inc(s.page_op_flushes, op="fused_flush")
        reg.counter(
            "serve_page_op_round_trips_saved_total",
            "device dispatches avoided by fused page-op batching"
        ).inc(s.page_op_round_trips_saved)
        reg.counter("serve_solo_rounds_total",
                    "rounds run through the B=1 solo-lane step"
                    ).inc(s.solo_rounds)
        pipe = reg.counter("serve_pipeline_rounds_total",
                           "pipelined-loop events by kind",
                           labels=("kind",))
        pipe.inc(s.pipelined_rounds, kind="overlapped")
        pipe.inc(s.pipeline_barriers, kind="barrier")
        reg.counter("serve_pipeline_trimmed_tokens_total",
                    "tokens computed past an EOS during the pipeline "
                    "lag, trimmed and never emitted"
                    ).inc(s.lag_trimmed_tokens)
        reg.gauge("serve_pipeline_overlap_fraction",
                  "fraction of this run's rounds retired through the "
                  "async pipeline").set(s.pipeline_overlap)
        reg.counter("serve_speculative_rounds_total",
                    "rounds that carried a speculative verify lane"
                    ).inc(s.spec_rounds)
        spec = reg.counter("serve_speculative_tokens_total",
                           "speculative draft tokens by outcome",
                           labels=("kind",))
        spec.inc(s.spec_draft_tokens, kind="drafted")
        spec.inc(s.spec_accepted_tokens, kind="accepted")
        pool = self._pool
        if pool is not None:
            reg.gauge("serve_pages_used",
                      "arena pages allocated").set(pool.used_count)
            reg.gauge("serve_pages_peak",
                      "peak arena pages this run").set(s.pages_peak)


# ==========================================================================
# legacy per-slot engine (baseline)
# ==========================================================================
class LegacyServeEngine:
    """Original continuous-batching-lite loop: per-slot batch-1 contiguous

    caches, one sequential jit decode call per active slot per token."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 256, cache_dtype=jnp.float32):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        self.stats = EngineStats()
        self._decode = serve_steps.contiguous_decode(cfg)

    def _prefill_one(self, prompt: np.ndarray):
        tokens = jnp.asarray(prompt)[None, :]
        logits, cache = prefill(self.cfg, self.params, tokens,
                                max_len=self.max_len,
                                cache_dtype=self.cache_dtype)
        self.stats.prefills += 1
        return int(jnp.argmax(logits[0])), cache

    def run(self, requests: List[Request],
            greedy: bool = True) -> List[Request]:
        """Process all requests to completion; returns them with outputs.

        Stats describe this run only (a fresh EngineStats per call)."""
        if not greedy:
            raise NotImplementedError("only greedy decoding is implemented")
        self.stats = EngineStats()
        t0 = time.monotonic()
        queue = list(requests)
        # slot state: per-slot cache (batch dim 1) + active request
        active: List[Optional[Request]] = [None] * self.slots
        caches: List = [None] * self.slots
        positions = [0] * self.slots
        next_tok = [0] * self.slots

        def refill():
            for s in range(self.slots):
                while active[s] is None and queue:
                    req = queue.pop(0)
                    tok, cache = self._prefill_one(req.prompt)
                    req.out_tokens.append(tok)
                    self.stats.tokens_out += 1
                    if _finished(req, len(req.prompt), self.max_len):
                        req.done = True   # EOS at prefill: no decode slot
                        continue
                    active[s] = req
                    caches[s] = cache
                    positions[s] = len(req.prompt)
                    next_tok[s] = tok

        refill()
        while any(a is not None for a in active):
            for s in range(self.slots):
                req = active[s]
                if req is None:
                    continue
                ts = time.monotonic()
                tok = jnp.asarray([[next_tok[s]]], jnp.int32)
                logits, caches[s] = self._decode(
                    self.params, tok, caches[s],
                    jnp.asarray(positions[s], jnp.int32))
                positions[s] += 1
                nxt = int(jnp.argmax(logits[0]))
                next_tok[s] = nxt
                req.out_tokens.append(nxt)
                self.stats.decode_steps += 1
                self.stats.tokens_out += 1
                self.stats.step_seconds.append(time.monotonic() - ts)
                self.stats.step_tokens.append(1)
                if _finished(req, positions[s], self.max_len):
                    req.done = True
                    active[s] = None
                    caches[s] = None
            refill()
        self.stats.wall_s = time.monotonic() - t0
        return requests
