"""Self-speculative decode: draft proposals and greedy acceptance.

The engine's ragged step already compiles C-wide rungs for chunked
prefill (``width_ladder``), so verifying k draft tokens costs one step
call at the smallest rung covering ``1 + k`` — no new compiled shapes,
no second model. The draft here is the cheapest one that works on a
single model: **prompt lookup** (n-gram continuation), the
self-speculative scheme of arXiv:2304.04487 / vLLM's ``[ngram]``
speculator. :func:`propose` finds the most recent earlier occurrence of
the sequence's longest matching suffix n-gram and proposes the tokens
that followed it; :func:`accept_greedy` keeps the verified prefix plus
the model's correction token, which makes speculative greedy decode
token-identical to plain greedy decode at any k (the classic
speculative-decoding guarantee specialized to argmax).

Draft and acceptance are pure host/numpy — only the verify step runs on
device. Rejected draft positions leave garbage K/V behind; that is
masked by ``valid_len`` until real tokens overwrite it, and the pages
allocated for rejected positions are returned via
``PagedKVPool.trim`` (see ``serve/engine.py``).
"""
from __future__ import annotations

import numpy as np


def propose(history: np.ndarray, k: int, max_ngram: int = 3) -> np.ndarray:
    """Prompt-lookup draft: up to ``k`` tokens predicted to follow
    ``history`` (prompt + generated so far, most recent last).

    Tries suffix n-grams from ``max_ngram`` down to 1; on the first n
    with an earlier occurrence, returns the (up to k) tokens that
    followed its most recent earlier occurrence. Empty array when
    nothing matches — the round falls back to plain one-token decode.
    """
    h = np.asarray(history, np.int64).ravel()
    size = int(h.size)
    if size < 2 or k <= 0:
        return np.empty(0, np.int32)
    for n in range(min(max_ngram, size - 1), 0, -1):
        pat = h[size - n:]
        windows = np.lib.stride_tricks.sliding_window_view(h, n)
        starts = np.flatnonzero((windows == pat).all(axis=1))
        starts = starts[starts < size - n]   # exclude the suffix itself
        if starts.size:
            i = int(starts[-1])              # most recent recurrence
            cont = h[i + n: i + n + k]
            if cont.size:
                return cont.astype(np.int32)
    return np.empty(0, np.int32)


def accept_greedy(draft: np.ndarray, selected: np.ndarray) -> int:
    """Tokens to emit from a greedy verify step: the longest draft
    prefix the model agrees with, plus the model's own next token.

    ``selected`` is the step's argmax output for the verify columns
    (``selected[c]`` = the model's token after consuming column c, where
    column 0 carried the last real token and columns 1..k the draft).
    Always >= 1 — even a fully rejected draft yields the token plain
    decode would have produced, so a verify round never loses ground.
    Capped by ``len(selected)``: a caller that truncated the selection
    (e.g. at a budget edge) can never be told to emit past it.
    """
    n = 1
    while (n <= min(len(draft), len(selected))
           and int(draft[n - 1]) == int(selected[n - 1])):
        n += 1
    return min(n, len(selected))
