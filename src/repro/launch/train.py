"""Training driver CLI.

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
      --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt --resume

Uses the real distributed step builder when a mesh is requested (--dp/--tp)
and the single-device fallback otherwise. On restart after a crash/kill it
resumes from the newest checkpoint (fault-tolerance path).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, reduced_config
from repro.launch import mesh as meshlib
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced smoke config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--dp", type=int, default=0)
    ap.add_argument("--tp", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--deadline", type=float, default=600.0)
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(
        args.arch)
    mesh = None
    if args.dp or args.tp:
        dp = args.dp or 1
        tp = args.tp or 1
        mesh = meshlib.make_mesh((dp, tp), ("data", "model"))

    tc = TrainConfig(steps=args.steps, global_batch=args.batch,
                     seq_len=args.seq, microbatches=args.microbatches,
                     ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                     resume=args.resume, seed=args.seed,
                     step_deadline_s=args.deadline)
    oc = AdamWConfig(lr=args.lr)

    def extra(batch, seq, c):
        out = {}
        if c.n_vis_tokens:
            out["vis_embeds"] = jax.numpy.zeros(
                (batch, c.n_vis_tokens, c.d_model), jax.numpy.float32)
        if c.is_encdec:
            out["frames"] = jax.numpy.zeros(
                (batch, c.enc_seq, c.d_model), jax.numpy.float32)
        return out

    result = train(cfg, tc, oc, mesh=mesh,
                   extra_batch_fn=extra if (cfg.n_vis_tokens
                                            or cfg.is_encdec) else None)
    final = result["history"][-1]["loss"] if result["history"] else None
    print(f"[train] done. final loss={final}")


if __name__ == "__main__":
    main()
