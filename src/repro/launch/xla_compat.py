"""jax-version compatibility over AOT compilation artifacts.

``Compiled.cost_analysis()`` changed shape across jax releases: newer
versions return a flat ``{counter: value}`` dict, older ones a
one-element list of such dicts, and some backends return ``None`` (or
raise) when the compiler exposes no cost model at all. Every consumer in
this repo — the dry-run roofline (``launch/dryrun.py``) and the live
serving cost-attribution layer (``obs/costs.py``) — parses through THIS
module so the normalization logic exists exactly once.
"""
from __future__ import annotations

from typing import Dict, Tuple


def cost_analysis_dict(compiled) -> Dict:
    """Normalized ``compiled.cost_analysis()``: always a flat dict.

    Newer jax returns a flat dict, older a one-element list of dicts;
    ``None``, an empty list, or a raising backend all collapse to ``{}``
    — callers degrade to zero-cost attribution, never crash.
    """
    try:
        ca = compiled.cost_analysis() or {}
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def flops_bytes(compiled) -> Tuple[float, float]:
    """(FLOPs, bytes accessed) per invocation, per device; zeros when the
    backend reports no cost model (the CPU-interpret degradation path)."""
    d = cost_analysis_dict(compiled)

    def num(key: str) -> float:
        v = d.get(key, 0.0)
        return float(v) if isinstance(v, (int, float)) else 0.0

    return num("flops"), num("bytes accessed")
