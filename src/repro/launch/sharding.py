"""Logical sharding rules: parameter/activation/cache PartitionSpecs.

Scheme (GSPMD; MaxText-style):
  * TP ("model" axis): attention heads / FFN hidden / vocab.
  * FSDP/ZeRO-3 ("data" axis): the non-TP dim of every large parameter is
    additionally sharded over data; GSPMD all-gathers per layer on use and
    reduce-scatters gradients. Optimizer state inherits the param spec, so
    Adam moments are fully sharded.
  * "pod" axis: pure data parallelism (batch), gradients all-reduce across
    pods once per step.

Rules are name-based over the flattened param path; stacked leaves
(blocks/encoder/xattn pytrees carry a leading n_groups dim) get a leading
None.
"""
from __future__ import annotations

import re
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.qtensor import QTensor
from repro.launch import mesh as meshlib

_STACKED_PREFIXES = ("blocks/", "encoder/blocks/", "xattn/")

# (regex on path, spec for the trailing (non-stacked) dims)
_RULES = [
    (r"embed/tok$",            ("model", "data")),
    (r"lm_head$",              ("data", "model")),
    (r"pos_emb$",              (None, None)),
    # attention
    (r"attn/w[qkv]$",          ("data", "model")),
    (r"attn/wo$",              ("model", "data")),
    (r"attn/b[qkvo]$",         (None,)),
    # dense FFN
    (r"ffn/w_(gate|up)$",      ("data", "model")),
    (r"ffn/w_down$",           ("model", "data")),
    # MoE FFN (leaf ndim 3 without stacking: [E, d, ff])
    (r"ffn/router$",           ("data", None)),
    # mamba
    (r"mamba/in_proj$",        ("data", "model")),
    (r"mamba/out_proj$",       ("model", "data")),
    (r"mamba/conv_w$",         ("model", None)),
    (r"mamba/(a_log|dt_bias|d_skip)$", (None,)),
    (r"mamba/norm_scale$",     (None,)),
    (r"norm",                  (None,)),
]
_MOE_EXPERT_RULES = {
    "w_gate": ("model", "data", None),
    "w_up": ("model", "data", None),
    "w_down": ("model", None, "data"),
}


def _strip_axes(spec: Tuple, mesh) -> Tuple:
    """Drop axes the mesh doesn't have (e.g. no fsdp on a 1-D mesh)."""
    names = mesh.axis_names
    return tuple((a if (a in names) else None) for a in spec)


def param_spec(path: str, leaf, mesh) -> P:
    """PartitionSpec for one parameter leaf."""
    stacked = any(path.startswith(p) or ("/" + p) in path
                  for p in _STACKED_PREFIXES)
    ndim = getattr(leaf, "ndim", 0)
    shape = tuple(getattr(leaf, "shape", ()))
    base_ndim = ndim - (1 if stacked else 0)

    # MoE expert tensors: [.., E, d, ff]
    m = re.search(r"ffn/(w_gate|w_up|w_down)$", path)
    if m is not None and base_ndim == 3:
        e = shape[1] if stacked else shape[0]
        if e % meshlib.axis_size(mesh, "model") == 0:
            spec = _MOE_EXPERT_RULES[m.group(1)]
        else:
            # too few experts for EP: megatron-shard the FFN dims instead
            spec = {"w_gate": (None, "data", "model"),
                    "w_up": (None, "data", "model"),
                    "w_down": (None, "model", "data")}[m.group(1)]
        return _finalize(spec, stacked, ndim, shape, mesh)

    for pat, spec in _RULES:
        if re.search(pat, path):
            if len(spec) != base_ndim:
                spec = tuple(spec[:base_ndim]) + (None,) * max(
                    0, base_ndim - len(spec))
            return _finalize(spec, stacked, ndim, shape, mesh)
    return _finalize((None,) * base_ndim, stacked, ndim, shape, mesh)


def _finalize(spec, stacked, ndim, shape, mesh) -> P:
    spec = tuple(spec)
    if stacked:
        spec = (None,) + spec
    spec = spec + (None,) * (ndim - len(spec))
    spec = _strip_axes(spec, mesh)
    # drop shardings that don't divide the dim (pjit in_shardings reject
    # padding; odd dims — 92553 vocab, 25 heads — replicate instead)
    out = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            out.append(None)
            continue
        n = meshlib.axis_size(mesh, ax) if isinstance(ax, str) else \
            int(np.prod([meshlib.axis_size(mesh, a) for a in ax]))
        out.append(ax if dim % n == 0 else None)
    return P(*out)


def shard_params_tree(params, mesh):
    """Tree of NamedShardings matching `params` (QTensor-aware)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        p = _path_str(path)
        out.append(NamedSharding(mesh, _qtensor_field_spec(p, leaf, mesh))
                   if _in_qtensor(p) else
                   NamedSharding(mesh, param_spec(p, leaf, mesh)))
    return jax.tree_util.tree_unflatten(treedef, out)


_QT_FIELDS = ("in_codes", "out_codes", "stream_pos", "is_out", "scale_in",
              "scale_out")


def _in_qtensor(path: str) -> bool:
    return path.split("/")[-1] in _QT_FIELDS


def _qtensor_field_spec(path: str, leaf, mesh) -> P:
    """QTensor stream fields.

    Layouts: base fields (in/out codes [n,8,128]; pos/tags/scales 2-D) may
    carry lead dims — (G,) layer stack, (S,) TP shards, (G,S) or (G,E).
    The innermost lead dim is the distribution dim (TP shard or expert):
    shard it on `model` when divisible; everything else replicated.
    """
    field = path.split("/")[-1]
    base = {"in_codes": 3, "out_codes": 3, "stream_pos": 2, "is_out": 2,
            "scale_in": 2, "scale_out": 2}[field]
    lead = leaf.ndim - base
    if lead <= 0:
        return P()
    tp_n = meshlib.axis_size(mesh, "model")
    shard_dim = lead - 1
    ax = "model" if ("model" in mesh.axis_names
                     and leaf.shape[shard_dim] % tp_n == 0) else None
    spec = [None] * leaf.ndim
    spec[shard_dim] = ax
    return P(*spec)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


# ---------------------------------------------------------------------------
# activations / batches / caches
# ---------------------------------------------------------------------------
def _dp_entry(mesh):
    """dp axes as a PartitionSpec entry: scalar for a single axis (the

    canonical spelling), tuple only for a genuine multi-axis dp submesh."""
    dp = meshlib.dp_axes(mesh)
    return dp[0] if len(dp) == 1 else dp


def batch_spec(mesh, global_batch: int) -> P:
    dp = _dp_entry(mesh)
    if global_batch % meshlib.dp_size(mesh) == 0 and dp:
        return P(dp)
    return P()


def batch_sharding(mesh, global_batch: int) -> NamedSharding:
    return NamedSharding(mesh, batch_spec(mesh, global_batch))


def cache_spec(path: str, leaf, mesh, global_batch: int) -> P:
    """KV/SSM cache specs. Leaves are stacked: leading n_groups dim.

    attn k/v [G,B,T,KV,hd]: batch on dp when divisible, else sequence on
    data (sequence-parallel cache for long-context batch=1); kv heads on
    model when divisible.
    """
    dp = _dp_entry(mesh)
    dp_n = meshlib.dp_size(mesh)
    tp_n = meshlib.axis_size(mesh, "model")
    batch_ok = dp and global_batch % dp_n == 0

    if path.endswith("/k") or path.endswith("/v"):
        # flat cache layout [G, B, T, KV*hd]: the fused dim shards 16-way
        # even when n_kv_heads < TP (GSPMD reshapes it to the nested
        # (KV x hd) sharding the attention einsums want — §Perf cell B)
        g, b, t, kvd = leaf.shape
        kv_ax = "model" if kvd % tp_n == 0 else None
        if batch_ok:
            return P(None, dp, None, kv_ax)
        data_n = meshlib.axis_size(mesh, "data")
        seq_ax = "data" if t % data_n == 0 else None
        return P(None, None, seq_ax, kv_ax)
    if path.endswith("k_scale") or path.endswith("v_scale"):
        g, b, t, kv = leaf.shape
        kv_ax = "model" if kv % tp_n == 0 else None
        if batch_ok:
            return P(None, dp, None, kv_ax)
        data_n = meshlib.axis_size(mesh, "data")
        seq_ax = "data" if t % data_n == 0 else None
        return P(None, None, seq_ax, kv_ax)
    if path.endswith("xk") or path.endswith("xv"):
        return P(None, dp if batch_ok else None, None, None, None)
    if path.endswith("/ssm"):
        g, b, h, p_, n = leaf.shape
        h_ax = "model" if h % tp_n == 0 else None
        return P(None, dp if batch_ok else None, h_ax, None, None)
    if path.endswith("/conv"):
        g, b, k, c = leaf.shape
        c_ax = "model" if c % tp_n == 0 else None
        return P(None, dp if batch_ok else None, None, c_ax)
    return P()


def paged_cache_spec(path: str, leaf, mesh) -> P:
    """Paged KV arena specs (``serve/paged_kv.py``). Leaves are stacked
    with a leading n_groups dim.

    Sharding contract (the serve step builders' "sharded arena"):

      * ``k_pages``/``v_pages`` ``[G, n_pages, page, kv_dim]`` — the page
        axis shards over **data** (each data shard owns a horizontal slice
        of the pool; block tables address pages globally and GSPMD routes
        the gather/scatter), the fused kv_dim over **model** (same flat
        16-way trick as the contiguous cache — reshapeable into the
        (KV x hd) sharding the attention einsums want).
      * ``k_scale_pages``/``v_scale_pages`` ``[G, n_pages, page, KV]`` —
        page axis on data; the per-head scale dim on model when the KV
        head count divides.
      * ``block_tbl`` ``[G, B, max_pages]`` — **replicated**: every shard
        must resolve any logical position to a (possibly remote) page.
      * mamba ``ssm``/``conv`` — dense per-slot; batch on dp when
        divisible (matches ``cache_spec``).

    Non-divisible dims replicate, as everywhere else in this module.
    """
    tp_n = meshlib.axis_size(mesh, "model")
    data_n = meshlib.axis_size(mesh, "data")
    shape = tuple(getattr(leaf, "shape", ()))

    def div(dim, ax, n):
        return ax if (n > 1 and dim % n == 0) else None

    if path.endswith("_pages"):
        g, n_pages, page, last = shape
        return P(None, div(n_pages, "data", data_n), None,
                 div(last, "model", tp_n))
    if path.endswith("block_tbl"):
        return P()
    if path.endswith("/ssm") or path.endswith("/conv"):
        dp = _dp_entry(mesh)
        dp_n = meshlib.dp_size(mesh)
        b = shape[1]
        spec = [None] * len(shape)
        spec[1] = div(b, dp, dp_n) if isinstance(dp, str) else None
        return P(*spec)
    return P()


def shard_paged_cache_tree(arena, mesh):
    """Tree of NamedShardings for a paged arena pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(arena)
    out = [NamedSharding(mesh, paged_cache_spec(_path_str(p), l, mesh))
           for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def shard_cache_tree(cache, mesh, global_batch: int):
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = [NamedSharding(mesh, cache_spec(_path_str(p), l, mesh,
                                          global_batch))
           for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def logits_spec(mesh, global_batch: int) -> P:
    dp = _dp_entry(mesh)
    if dp and global_batch % meshlib.dp_size(mesh) == 0:
        return P(dp, None, "model" if "model" in mesh.axis_names else None)
    return P(None, None, "model" if "model" in mesh.axis_names else None)
