"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (v5e hardware constants):

  compute    = HLO_FLOPs   / (chips * 197e12 FLOP/s bf16)
  memory     = HLO_bytes   / (chips * 819e9  B/s HBM)
  collective = coll_bytes  / (chips * 50e9   B/s per ICI link)

cost_analysis() provides FLOPs/bytes. Collective bytes are NOT in
cost_analysis: we parse the post-SPMD HLO text and sum operand sizes of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Note: jax lowers SPMD programs to a per-device module, so cost_analysis
numbers are per-device; we report both per-device and whole-mesh views.
MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) catches remat/redundancy
waste via the ratio MODEL_FLOPS / (HLO_FLOPs * chips).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (per chip, one direction)

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "bf16": 2, "f16": 2, "f32": 4,
    "f64": 8, "c64": 8, "c128": 16, "e4m3": 1, "e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# shape like bf16[16,4096,512]{2,1,0} or (tuple of those); capture
# dtype + dims of every tensor literal on an op line
_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> float:
    if dtype not in _DTYPE_BYTES:
        return 0.0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum *output* tensor sizes of every collective op, by kind.

    HLO line shape: `%name = TYPE op-name(...)` — the leading TYPE is the
    op's result shape, which for collectives equals the data landing on the
    wire per device (all-gather output, all-to-all shuffled tuple, ...).
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"^%?[\w\.\-]+\s*=\s*(.+)$", stripped)
        if not m:
            continue
        rhs = m.group(1)
        kind = None
        for k in _COLLECTIVES:
            if re.search(rf"\b{k}(-start|-done)?\(", rhs):
                kind = k
                break
        if kind is None:
            continue
        if re.search(rf"\b{kind}-done\(", rhs):
            continue  # size counted at -start
        # result type is everything before the op name
        idx = rhs.find(kind)
        result_t = rhs[:idx]
        size = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(
            result_t))
        out[kind] += size
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    model_flops: float           # 6*N_active*D for the step's token count
    useful_bytes: float = 0.0    # irreducible weight+cache traffic (global)
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0

    def finalize(self) -> "Roofline":
        self.t_compute = self.flops_per_dev / PEAK_FLOPS
        self.t_memory = self.bytes_per_dev / HBM_BW
        self.t_collective = self.coll_bytes_per_dev / ICI_BW
        return self

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_dev * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_time(self) -> float:
        """Ideal overlapped execution: max of the three streams."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Useful work time / bound time — the score we hillclimb.

        Compute-shaped steps (train/prefill): useful FLOP time vs the
        binding stream. Bandwidth-shaped steps (decode): the irreducible
        weight+cache byte time also counts as useful work — take the max
        of the two views so decode cells are scored against the memory
        roofline they actually live on.
        """
        if self.roofline_time <= 0:
            return 0.0
        t_useful_c = (self.model_flops / self.chips) / PEAK_FLOPS
        t_useful_b = (self.useful_bytes / self.chips) / HBM_BW
        return max(t_useful_c, t_useful_b) / self.roofline_time

    @property
    def bw_fraction(self) -> float:
        """Irreducible bytes / HLO bytes (decode: how lean is the traffic)."""
        total = self.bytes_per_dev * self.chips
        return self.useful_bytes / total if total else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(bottleneck=self.bottleneck,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_time=self.roofline_time,
                 roofline_fraction=self.roofline_fraction,
                 bw_fraction=self.bw_fraction)
        return d


def model_flops_for(cfg, suite) -> float:
    """6*N_active*D with D = tokens processed by the step."""
    n = cfg.active_param_count()
    if suite.kind == "train":
        tokens = suite.global_batch * suite.seq_len
        return 6.0 * n * tokens
    if suite.kind == "prefill":
        tokens = suite.global_batch * suite.seq_len
        return 2.0 * n * tokens          # forward only
    tokens = suite.global_batch           # one token per sequence
    return 2.0 * n * tokens


def useful_bytes_for(cfg, suite, serve_weights: str = "fp16") -> float:
    """Irreducible global bytes for the step: every active weight read once

    (packed bits when serving QMC) + the valid KV/SSM cache (decode) or
    activation residency floor (train/prefill: params + grads touched)."""
    from repro.memsys.workload import kv_bits_per_step
    n = cfg.active_param_count()
    w_bits = n * (5.2 if serve_weights == "qtensor"
                  and suite.kind == "decode" else 16.0)
    if suite.kind == "train":
        # fwd + bwd touch params twice, grads once, opt state twice
        return (3 * w_bits + 2 * cfg.param_count() * 32) / 8.0
    if suite.kind == "prefill":
        return w_bits / 8.0
    cache_bits = kv_bits_per_step(cfg, suite.seq_len) * suite.global_batch
    return (w_bits + cache_bits) / 8.0


def from_artifacts(arch: str, shape: str, mesh_name: str, chips: int,
                   cost: Dict, coll: Dict, model_flops: float,
                   useful_bytes: float = 0.0) -> Roofline:
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_dev=float(cost.get("flops", 0.0)),
        bytes_per_dev=float(cost.get("bytes accessed", 0.0)),
        coll_bytes_per_dev=float(coll.get("total", 0.0)),
        model_flops=model_flops,
        useful_bytes=useful_bytes).finalize()
