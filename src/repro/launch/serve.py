"""Serving driver CLI: train-free demo loads random-init weights, quantizes

them with QMC, and serves batched requests through the engine.

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
      --reduced --requests 8 --new-tokens 16 --weights qmc
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core.qconfig import QMCConfig
from repro.core.serving_quant import quantize_for_serving
from repro.models.model import init_params
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--weights", choices=["fp16", "qmc"], default="qmc")
    ap.add_argument("--rho", type=float, default=0.3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share prompt-prefix KV pages copy-on-write")
    ap.add_argument("--sys-prompt-len", type=int, default=0,
                    help="prepend a shared system prompt of this length "
                         "to every request (multi-tenant demo)")
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(
        args.arch)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    if args.weights == "qmc":
        t0 = time.monotonic()
        params = quantize_for_serving(
            params, QMCConfig(rho=args.rho, granularity="subtile"),
            tp_shards=1, min_dim=64)
        print(f"[serve] QMC PTQ in {time.monotonic()-t0:.1f}s")

    rng = np.random.default_rng(args.seed)
    sys_prompt = rng.integers(2, cfg.vocab, size=args.sys_prompt_len)
    reqs = [Request(uid=i,
                    prompt=np.concatenate(
                        [sys_prompt,
                         rng.integers(2, cfg.vocab, size=args.prompt_len)]
                    ).astype(np.int32),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    eng = ServeEngine(cfg, params, slots=args.slots,
                      max_len=(args.sys_prompt_len + args.prompt_len
                               + args.new_tokens + 4),
                      prefix_cache=args.prefix_cache)
    eng.run(reqs)
    s = eng.stats
    print(f"[serve] {s.prefills} prefills, {s.decode_steps} decode steps, "
          f"{s.tokens_out} tokens in {s.wall_s:.2f}s "
          f"({s.tokens_per_s:.1f} tok/s)")
    if args.prefix_cache:
        print(f"[serve] prefix cache: {s.cache_hits} hits, "
              f"hit_rate={s.hit_rate:.2f}, prefill-token reduction="
              f"{s.prefill_token_reduction:.2f}, {s.cow_copies} COW copies")
    for r in reqs[:3]:
        print(f"  req {r.uid}: {r.out_tokens[:10]}...")


if __name__ == "__main__":
    main()
