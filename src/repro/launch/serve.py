"""Serving driver CLI: train-free demo loads random-init weights, quantizes

them with QMC, and serves batched requests through the engine.

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
      --reduced --requests 8 --new-tokens 16 --weights qmc

Sharded serving drives the SAME step-builder layer (``serve/steps.py``)
the engine uses everywhere: ``--data-shards D --model-shards M`` builds a
(D, M) ``("data", "model")`` mesh, quantizes the weights per TP shard
(``tp_shards=M`` — the QMC quantize-after-shard deployment format), builds
the paged step set explicitly, and hands it to ``ServeEngine``. Run under
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to demo on a CPU
host.

Observability: ``--trace-out t.json`` writes a Perfetto-loadable Chrome
trace of the run (round phase spans + request lifecycle instants, see
``repro.obs.trace``), ``--metrics-out m.json`` snapshots the ``serve_*``
metrics registry (``repro.obs.metrics``), and ``--profile DIR`` wraps the
run in ``jax.profiler.trace`` for an XLA-level TensorBoard profile.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core.qconfig import QMCConfig
from repro.core.serving_quant import quantize_for_serving
from repro.launch import mesh as meshlib
from repro.models.model import init_params
from repro.obs import costs as obs_costs
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve import steps as serve_steps
from repro.serve.engine import Request, ServeEngine
from repro.serve.paged_kv import pages_for
from repro.serve.sampling import SamplingParams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--weights", choices=["fp16", "qmc"], default="qmc")
    ap.add_argument("--rho", type=float, default=0.3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy argmax, the "
                         "bitwise oracle path)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="sample from the k highest-logit tokens "
                         "(0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling: smallest token set with "
                         "cumulative mass >= p (1.0 = off)")
    ap.add_argument("--logprobs", action="store_true",
                    help="record each selected token's model logprob in "
                         "Request.out_logprobs")
    ap.add_argument("--speculative", type=int, default=0, metavar="K",
                    help="self-speculative decode: up to K prompt-lookup "
                         "draft tokens verified per greedy decode lane "
                         "per round (0 = off)")
    ap.add_argument("--pipelined", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="overlap host and device work on steady-state "
                         "decode: async step dispatch, on-device token "
                         "carry, retire via async readback one round "
                         "later (token-identical to the sync loop)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share prompt-prefix KV pages copy-on-write")
    ap.add_argument("--paged-attention", action="store_true",
                    help="attend through the ragged Pallas page-table "
                         "kernel (streams live pages only, for decode "
                         "tokens and prefill chunks alike; "
                         "interpret-mode off TPU)")
    ap.add_argument("--chunked-prefill", action="store_true",
                    help="split prompts into fixed-size chunks that "
                         "co-schedule with decode lanes in the same jit "
                         "step (default: one chunk covers the whole "
                         "prompt)")
    ap.add_argument("--chunk-tokens", type=int, default=32,
                    help="prefill chunk width with --chunked-prefill")
    ap.add_argument("--sys-prompt-len", type=int, default=0,
                    help="prepend a shared system prompt of this length "
                         "to every request (multi-tenant demo)")
    ap.add_argument("--data-shards", type=int, default=1,
                    help="mesh 'data' axis: shards the paged arena's "
                         "page pool")
    ap.add_argument("--model-shards", type=int, default=1,
                    help="mesh 'model' axis: TP over heads / FFN / "
                         "quantized weight shards")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--trace-out", metavar="PATH",
                    help="write a Chrome trace-event JSON of the run "
                         "(load in Perfetto / chrome://tracing): per-round "
                         "phase spans plus request-lifecycle and "
                         "scheduler/cache instants")
    ap.add_argument("--metrics-out", metavar="PATH",
                    help="write a JSON snapshot of the serve_* metrics "
                         "registry after the run")
    ap.add_argument("--profile", metavar="DIR",
                    help="wrap the run in jax.profiler.trace(DIR) "
                         "(TensorBoard-loadable XLA profile)")
    ap.add_argument("--cost-report", action="store_true",
                    help="capture XLA cost_analysis() per step shape and "
                         "print the per-step roofline attribution table "
                         "+ modeled memory-system cost after the run "
                         "(obs/costs.py; makes step calls synchronous)")
    args = ap.parse_args()

    if args.cost_report:
        # before any step set is built, so every wrapper captures
        obs_costs.enable_capture()

    cfg = reduced_config(args.arch) if args.reduced else get_config(
        args.arch)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    if args.weights == "qmc":
        t0 = time.monotonic()
        params = quantize_for_serving(
            params, QMCConfig(rho=args.rho, granularity="subtile"),
            tp_shards=args.model_shards, min_dim=64)
        print(f"[serve] QMC PTQ in {time.monotonic()-t0:.1f}s "
              f"(tp_shards={args.model_shards})")

    mesh = None
    if args.data_shards * args.model_shards > 1:
        mesh = meshlib.make_mesh((args.data_shards, args.model_shards),
                                 ("data", "model"))
        print(f"[serve] mesh data={args.data_shards} "
              f"model={args.model_shards} over "
              f"{args.data_shards * args.model_shards} devices")

    rng = np.random.default_rng(args.seed)
    sys_prompt = rng.integers(2, cfg.vocab, size=args.sys_prompt_len)
    reqs = [Request(uid=i,
                    prompt=np.concatenate(
                        [sys_prompt,
                         rng.integers(2, cfg.vocab, size=args.prompt_len)]
                    ).astype(np.int32),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]

    # build the step set through the shared builder layer (exactly what
    # the engine would build itself — passing it in pins the contract)
    max_len = (args.sys_prompt_len + args.prompt_len
               + args.new_tokens + 4)
    mpps = pages_for(max_len, args.page_size)
    n_pages = serve_steps.default_n_pages(args.slots, mpps, mesh)
    p_struct = None
    if mesh is not None:
        p_struct = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    chunk = (args.chunk_tokens if args.chunked_prefill
             else serve_steps.default_chunk(mpps, args.page_size))
    step_set = serve_steps.build_paged_steps(
        cfg, mesh, p_struct, page=args.page_size,
        n_pages=n_pages, max_slots=args.slots,
        max_pages_per_seq=mpps, chunk=chunk,
        paged_attention=args.paged_attention)
    tracer = None
    if args.trace_out:
        tracer = obs_trace.Tracer(enabled=True)
        # install as the process default so deep call sites (scheduler,
        # prefix cache, jit wrappers) emit into the same trace
        obs_trace.set_tracer(tracer)
    sp = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                        top_p=args.top_p, seed=args.seed,
                        logprobs=args.logprobs)
    eng = ServeEngine(cfg, params, slots=args.slots, max_len=max_len,
                      page_size=args.page_size, mesh=mesh,
                      step_set=step_set, chunk_tokens=chunk,
                      prefix_cache=args.prefix_cache,
                      paged_attention=args.paged_attention,
                      sampling=sp, speculative_k=args.speculative,
                      pipelined=args.pipelined, tracer=tracer)
    if args.profile:
        with jax.profiler.trace(args.profile):
            eng.run(reqs)
        print(f"[serve] XLA profile written under {args.profile}")
    else:
        eng.run(reqs)
    s = eng.stats
    if tracer is not None:
        n_ev = tracer.export(args.trace_out)
        print(f"[serve] trace: {n_ev} events -> {args.trace_out}")
    if args.metrics_out:
        obs_metrics.get_registry().write_json(args.metrics_out)
        print(f"[serve] metrics snapshot -> {args.metrics_out}")
    print(f"[serve] {s.prefills} prefills ({s.prefill_chunks} chunks of "
          f"<= {chunk} tokens), {s.decode_steps} decode steps, "
          f"{s.tokens_out} tokens in {s.wall_s:.2f}s "
          f"({s.tokens_per_s:.1f} tok/s)")
    if s.phase_seconds:
        print(f"[serve] phases: host={s.host_seconds():.2f}s "
              f"device={s.device_seconds():.2f}s over {s.rounds} rounds "
              f"({s.jit_compiles} jit compiles, ~{s.jit_compile_s:.2f}s)")
    if args.pipelined:
        print(f"[serve] pipelined: {s.pipelined_rounds}/{s.rounds} "
              f"rounds overlapped ({s.pipeline_overlap:.0%}), "
              f"{s.pipeline_barriers} drains, "
              f"{s.lag_trimmed_tokens} lag-trimmed tokens")
    if args.chunked_prefill and s.ttft_s:
        import numpy as _np
        print(f"[serve] chunked prefill: TTFT p50="
              f"{_np.percentile(s.ttft_s, 50) * 1e3:.1f}ms p95="
              f"{_np.percentile(s.ttft_s, 95) * 1e3:.1f}ms, "
              f"{s.prefill_kv_pages_live} live pages streamed / "
              f"{s.prefill_kv_pages_written} written by chunks")
    if args.paged_attention and s.kv_pages_full:
        print(f"[serve] paged-attention kernel: {s.kv_pages_live} live "
              f"pages streamed vs {s.kv_pages_full} full-width "
              f"({1 - s.kv_pages_live / s.kv_pages_full:.0%} gather work "
              f"saved)")
    if args.prefix_cache:
        print(f"[serve] prefix cache: {s.cache_hits} hits, "
              f"hit_rate={s.hit_rate:.2f}, prefill-token reduction="
              f"{s.prefill_token_reduction:.2f}, {s.cow_copies} COW copies")
    if s.dedup_hits:
        print(f"[serve] in-flight dedup: {s.dedup_hits} admissions "
              f"aliased a live identical prompt")
    if args.temperature > 0:
        print(f"[serve] sampling: temperature={args.temperature} "
              f"top_k={args.top_k} top_p={args.top_p} seed={args.seed}")
    if args.speculative > 0:
        print(f"[serve] speculative k={args.speculative}: "
              f"{s.spec_rounds} verify rounds, "
              f"{s.spec_accepted_tokens}/{s.spec_draft_tokens} drafts "
              f"accepted (rate={s.spec_acceptance_rate:.2f})")
    if args.logprobs and reqs and reqs[0].out_logprobs:
        lp = reqs[0].out_logprobs[:5]
        print(f"[serve] req 0 logprobs: "
              f"{[round(x, 3) for x in lp]}...")
    if args.cost_report and eng.last_cost_report is not None:
        print("[serve] cost attribution (measured vs roofline, "
              "obs/costs.py):")
        print(eng.last_cost_report.table())
    for r in reqs[:3]:
        print(f"  req {r.uid}: {r.out_tokens[:10]}...")


if __name__ == "__main__":
    main()
