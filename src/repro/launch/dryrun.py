import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("DRYRUN_DEVICES", "512"))

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the

production meshes (16x16 single-pod, 2x16x16 multi-pod) and record
memory_analysis / cost_analysis / collective traffic for the roofline.

The two lines above MUST stay first: jax locks the device count on first
init, and only this entry point should see 512 host devices.

Usage:
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out artifacts/dryrun
"""
import argparse  # noqa: E402
import functools  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Dict, Optional, Tuple  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import runtime_context as ctx  # noqa: E402
from repro.configs import (applicable_shapes, get_config, get_shape,  # noqa
                           ASSIGNED_ARCHS)
from repro.core.qconfig import QMCConfig  # noqa: E402
from repro.core.serving_quant import serving_params_struct  # noqa: E402
from repro.launch import mesh as meshlib  # noqa: E402
from repro.launch import roofline as rl  # noqa: E402
from repro.launch import xla_compat  # noqa: E402
from repro.launch import sharding as shd  # noqa: E402
from repro.models.model import init_params  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.optim.adamw import AdamWConfig  # noqa: E402
from repro.serve import steps as serve_steps  # noqa: E402
from repro.train.step import build_train_step  # noqa: E402


def params_struct(cfg, dtype=jnp.bfloat16):
    return jax.eval_shape(
        functools.partial(init_params, cfg, dtype=dtype),
        jax.random.PRNGKey(0))


def input_specs(cfg, suite, *, batch: Optional[int] = None) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b = batch or suite.global_batch
    s = suite.seq_len
    sds = jax.ShapeDtypeStruct
    tok = sds((b, s), jnp.int32)
    if suite.kind == "train":
        spec = {"tokens": tok, "labels": sds((b, s), jnp.int32)}
    elif suite.kind == "prefill":
        spec = {"tokens": tok}
    else:  # decode
        spec = {"tokens": sds((b, 1), jnp.int32)}
    if cfg.n_vis_tokens and suite.kind in ("train", "prefill"):
        spec["vis_embeds"] = sds((b, cfg.n_vis_tokens, cfg.d_model),
                                 jnp.bfloat16)
    if cfg.is_encdec and suite.kind in ("train", "prefill"):
        spec["frames"] = sds((b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    return spec


def _moment_dtype(cfg) -> str:
    # giant models: bf16 moments so optimizer state fits 256 x 16 GB HBM
    return "bfloat16" if cfg.param_count() > 3e10 else "float32"


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               serve_weights: str = "qtensor",
               microbatches: int = 1, mesh=None, cfg=None,
               suite=None, scan_layers: bool = True
               ) -> Tuple[object, object, Dict]:
    """Lower + compile one cell; returns (lowered, compiled, extras)."""
    cfg = cfg or get_config(arch)
    suite = suite or get_shape(shape_name)
    if mesh is None:
        mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    p_struct = params_struct(cfg)
    spec = input_specs(cfg, suite)

    with ctx.use_mesh(mesh, meshlib.dp_axes(mesh)):
        if suite.kind == "train":
            opt_cfg = AdamWConfig(moment_dtype=_moment_dtype(cfg))
            o_struct = jax.eval_shape(
                functools.partial(adamw.init, cfg=opt_cfg), p_struct)
            _, jit_builder, _ = build_train_step(
                cfg, opt_cfg, mesh, microbatches=microbatches,
                scan_layers=scan_layers)
            jitted = jit_builder(p_struct, o_struct, spec)
            lowered = jitted.lower(p_struct, o_struct, spec)
        elif suite.kind == "prefill":
            fn, make_jit = serve_steps.build_prefill(
                cfg, mesh, batch=suite.global_batch, seq=suite.seq_len,
                scan_layers=scan_layers)
            extras = {k: v for k, v in spec.items() if k != "tokens"}
            jitted = make_jit(p_struct, extras)
            lowered = jitted.lower(p_struct, spec["tokens"], extras)
        else:  # decode
            q_struct = p_struct
            if serve_weights == "qtensor":
                q_struct = serving_params_struct(
                    p_struct, QMCConfig(rho=0.3, granularity="subtile"),
                    tp_shards=meshlib.axis_size(mesh, "model"))
            fn, make_jit = serve_steps.build_decode(
                cfg, mesh, batch=suite.global_batch,
                cache_len=suite.seq_len, scan_layers=scan_layers)
            c_struct = serve_steps.cache_struct(
                cfg, suite.global_batch, suite.seq_len)
            jitted = make_jit(q_struct)
            lowered = jitted.lower(q_struct, spec["tokens"], c_struct,
                                   jax.ShapeDtypeStruct((), jnp.int32))
    t0 = time.monotonic()
    compiled = lowered.compile()
    return lowered, compiled, {"cfg": cfg, "suite": suite, "mesh": mesh,
                               "compile_s": time.monotonic() - t0}


def cost_dict(compiled) -> Dict:
    """compiled.cost_analysis() across jax versions — the shared shim in
    ``launch/xla_compat.py`` (also used by the live serving cost layer,
    ``obs/costs.py``)."""
    return xla_compat.cost_analysis_dict(compiled)


def _cost_and_coll(compiled) -> Dict:
    cost = cost_dict(compiled)
    coll = rl.collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": coll}


def calibrated_cost(arch: str, shape_name: str, *, multi_pod: bool,
                    serve_weights: str, mesh=None, cfg=None) -> Dict:
    """Exact per-device cost reconstruction.

    XLA's cost analysis counts a while-loop body ONCE regardless of trip
    count, so scan-over-layers dry-runs underreport. We lower unrolled
    1-group and 2-group versions of the model (small, fast compiles),
    take body = u2 - u1 and outside = 2*u1 - u2, and reconstruct
    total = outside + n_groups * body for flops, bytes, and per-kind
    collective traffic.
    """
    import dataclasses as dc
    cfg = cfg or get_config(arch)
    plen = len(cfg.pattern)
    g_full = cfg.n_groups

    def shrunk(groups: int):
        repl = {"n_layers": plen * groups}
        if cfg.is_encdec:
            repl["n_enc_layers"] = groups
        return dc.replace(cfg, **repl)

    out = {}
    for tag, groups in (("u1", 1), ("u2", 2)):
        c = shrunk(groups)
        _, compiled, _ = lower_cell(
            arch, shape_name, multi_pod=multi_pod,
            serve_weights=serve_weights, mesh=mesh, cfg=c,
            scan_layers=False)
        out[tag] = _cost_and_coll(compiled)

    def combine(f1, f2):
        body = max(f2 - f1, 0.0)
        outside = max(2 * f1 - f2, 0.0)
        return outside + g_full * body

    corrected = {
        "flops": combine(out["u1"]["flops"], out["u2"]["flops"]),
        "bytes accessed": combine(out["u1"]["bytes"], out["u2"]["bytes"]),
    }
    coll = {}
    keys = set(out["u1"]["coll"]) | set(out["u2"]["coll"])
    for k in keys:
        coll[k] = combine(float(out["u1"]["coll"].get(k, 0.0)),
                          float(out["u2"]["coll"].get(k, 0.0)))
    return {"cost": corrected, "collectives": coll,
            "u1": out["u1"], "u2": out["u2"], "n_groups": g_full}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             serve_weights: str = "qtensor", out_dir: Optional[str] = None,
             collect_hlo: bool = True, calibrate: bool = True) -> Dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: Dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "serve_weights": serve_weights, "ok": False}
    t0 = time.monotonic()
    try:
        lowered, compiled, extra = lower_cell(
            arch, shape_name, multi_pod=multi_pod,
            serve_weights=serve_weights)
        rec["compile_s"] = extra["compile_s"]
        rec["lower_s"] = time.monotonic() - t0 - extra["compile_s"]
        cost = cost_dict(compiled)
        rec["cost"] = {k: float(v) for k, v in cost.items()
                       if isinstance(v, (int, float)) and k in
                       ("flops", "bytes accessed", "utilization",
                        "transcendentals")}
        ma = compiled.memory_analysis()
        if ma is not None:
            rec["memory"] = {
                "argument_bytes": int(getattr(
                    ma, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
                "alias_bytes": int(getattr(
                    ma, "alias_size_in_bytes", 0)),
            }
        if collect_hlo:
            txt = compiled.as_text()
            rec["collectives"] = rl.collective_bytes(txt)
            rec["hlo_lines"] = txt.count("\n")
        del lowered, compiled
        chips = 512 if multi_pod else 256
        cfg, suite = extra["cfg"], extra["suite"]
        cost_used, coll_used = rec.get("cost", {}), rec.get(
            "collectives", {})
        if calibrate:
            # reconstruct exact totals (scan bodies count once in XLA's
            # cost analysis — see calibrated_cost)
            cal = calibrated_cost(arch, shape_name, multi_pod=multi_pod,
                                  serve_weights=serve_weights)
            rec["cost_corrected"] = cal["cost"]
            rec["collectives_corrected"] = cal["collectives"]
            cost_used, coll_used = cal["cost"], cal["collectives"]
        roof = rl.from_artifacts(
            arch, shape_name, mesh_name, chips, cost_used, coll_used,
            rl.model_flops_for(cfg, suite),
            rl.useful_bytes_for(cfg, suite, serve_weights))
        rec["roofline"] = roof.to_dict()
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 - record and continue
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = time.monotonic() - t0
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        sw = f"_{serve_weights}" if get_shape(shape_name).kind == "decode" \
            else ""
        path = os.path.join(out_dir,
                            f"{arch}_{shape_name}_{mesh_name}{sw}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--serve-weights", default="qtensor",
                    choices=["qtensor", "fp16"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-calibrate", action="store_true",
                    help="skip the unrolled cost calibration (faster; "
                         "roofline terms underreport scan bodies)")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ASSIGNED_ARCHS:
            cfg = get_config(arch)
            for suite in applicable_shapes(cfg):
                cells.append((arch, suite.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    n_ok = n_fail = 0
    for arch, shape in cells:
        for mp in meshes:
            mesh_name = "pod2x16x16" if mp else "pod16x16"
            sw = f"_{args.serve_weights}" \
                if get_shape(shape).kind == "decode" else ""
            path = os.path.join(
                args.out, f"{arch}_{shape}_{mesh_name}{sw}.json")
            if args.skip_existing and os.path.exists(path):
                with open(path) as f:
                    if json.load(f).get("ok"):
                        print(f"[skip] {arch} {shape} {mesh_name}")
                        n_ok += 1
                        continue
            rec = run_cell(arch, shape, multi_pod=mp,
                           serve_weights=args.serve_weights,
                           out_dir=args.out,
                           calibrate=not args.no_calibrate and not mp)
            status = "OK " if rec["ok"] else "FAIL"
            n_ok += rec["ok"]
            n_fail += not rec["ok"]
            roof = rec.get("roofline", {})
            print(f"[{status}] {arch:22s} {shape:12s} {mesh_name:10s} "
                  f"compile={rec.get('compile_s', 0):6.1f}s "
                  f"bottleneck={roof.get('bottleneck', '-'):10s} "
                  f"frac={roof.get('roofline_fraction', 0):.3f} "
                  f"{rec.get('error', '')}")
    print(f"\ndry-run: {n_ok} ok, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
