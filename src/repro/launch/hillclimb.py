import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("DRYRUN_DEVICES", "512"))

"""Perf hillclimbing harness (§Perf): measure the calibrated roofline terms

of a cell under named optimization variants and log
hypothesis -> change -> before -> after records to artifacts/hillclimb/.

  python -m repro.launch.hillclimb --cell gemma2_train
  python -m repro.launch.hillclimb --all
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
from typing import Dict, Optional  # noqa: E402

from repro.configs import get_config, get_shape  # noqa: E402
from repro.launch import roofline as rl  # noqa: E402
from repro.launch.dryrun import calibrated_cost  # noqa: E402

OUT = "artifacts/hillclimb"

# variant -> ModelConfig field overrides (cumulative per cell plan)
OPTS = {
    "baseline": {},
    "chunked_ce": {"chunked_ce": True},
    "chunked_attn": {"chunked_attn": True},
    "both": {"chunked_ce": True, "chunked_attn": True},
    "both_dots": {"chunked_ce": True, "chunked_attn": True,
                  "remat_policy": "dots"},
    "kv8": {"kv_cache_quant": True},
    "both_kv8": {"chunked_ce": True, "chunked_attn": True,
                 "kv_cache_quant": True},
}

# The three hillclimb cells (chosen per EXPERIMENTS.md §Perf):
#   gemma2_train  — worst train roofline fraction (256k-vocab CE dominates)
#   dbrx_decode   — most collective-bound decode (MoE + QMC serving)
#   stablelm_dec  — paper-representative SLM edge decode (memory-bound);
#                   also measures FP16-weights vs QMC-weights serving.
CELLS = {
    "gemma2_train": dict(arch="gemma2-2b", shape="train_4k",
                         serve_weights="fp16",
                         variants=["baseline", "chunked_ce",
                                   "chunked_attn", "both", "both_dots"]),
    "dbrx_decode": dict(arch="dbrx-132b", shape="decode_32k",
                        serve_weights="qtensor",
                        variants=["baseline", "kv8"]),
    "stablelm_decode": dict(arch="stablelm-1.6b", shape="decode_32k",
                            serve_weights="qtensor",
                            variants=["baseline", "kv8"]),
    "stablelm_decode_fp16": dict(arch="stablelm-1.6b", shape="decode_32k",
                                 serve_weights="fp16",
                                 variants=["baseline", "kv8"]),
}


def measure(arch: str, shape: str, serve_weights: str,
            overrides: Dict) -> Dict:
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    suite = get_shape(shape)
    t0 = time.monotonic()
    cal = calibrated_cost(arch, shape, multi_pod=False,
                          serve_weights=serve_weights, cfg=cfg)
    roof = rl.from_artifacts(
        arch, shape, "pod16x16", 256, cal["cost"], cal["collectives"],
        rl.model_flops_for(cfg, suite),
        rl.useful_bytes_for(cfg, suite, serve_weights))
    return {"roofline": roof.to_dict(),
            "collectives": cal["collectives"],
            "wall_s": time.monotonic() - t0}


def run_cell(name: str) -> Dict:
    plan = CELLS[name]
    results = {}
    for variant in plan["variants"]:
        try:
            r = measure(plan["arch"], plan["shape"], plan["serve_weights"],
                        OPTS[variant])
        except Exception as e:  # noqa: BLE001
            r = {"error": f"{type(e).__name__}: {e}"}
        results[variant] = r
        roof = r.get("roofline", {})
        print(f"[{name}/{variant}] "
              f"t_comp={roof.get('t_compute', 0):.3e} "
              f"t_mem={roof.get('t_memory', 0):.3e} "
              f"t_coll={roof.get('t_collective', 0):.3e} "
              f"frac={roof.get('roofline_fraction', 0):.4f} "
              f"{r.get('error', '')}", flush=True)
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, f"{name}.json"), "w") as f:
        json.dump({"cell": name, "plan": {k: v for k, v in plan.items()},
                   "results": results}, f, indent=1, default=str)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None, choices=list(CELLS))
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()
    names = list(CELLS) if args.all or not args.cell else [args.cell]
    for n in names:
        run_cell(n)


if __name__ == "__main__":
    main()
