"""Mesh construction. Importing this module never touches jax device state;

meshes are built inside functions only (the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The production target: one v5e pod slice (16x16 = 256 chips) or two

    pods (2x16x16 = 512 chips) with a leading pure-DP "pod" axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Mesh over the first prod(shape) devices (the dry-run host exposes

    512 placeholder devices; the single-pod mesh uses the first 256)."""
    import numpy as np
    from jax.sharding import Mesh
    n = 1
    for s in shape:
        n *= s
    devs = jax.devices()
    if len(devs) < n:
        raise ValueError(f"need {n} devices, have {len(devs)}")
    return Mesh(np.asarray(devs[:n]).reshape(shape), axes)


def dp_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel submesh axes (pod + data when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def tp_axis(mesh) -> Optional[str]:
    return "model" if "model" in mesh.axis_names else None


def axis_size(mesh, name) -> int:
    if name is None:
        return 1
    names = list(mesh.axis_names)
    if name not in names:
        return 1
    return mesh.devices.shape[names.index(name)]


def dp_size(mesh) -> int:
    n = 1
    for a in dp_axes(mesh):
        n *= axis_size(mesh, a)
    return n
