"""launch subsystem."""
