"""Host-sharded batching with background prefetch (straggler mitigation).

Each host materializes only its slice of the global batch; a daemon thread
keeps a small queue of ready batches so a slow data step never stalls the
accelerator (the trainer's watchdog flags it instead).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np


class PrefetchLoader:
    def __init__(self, sample_fn: Callable[[int], Dict[str, np.ndarray]],
                 *, depth: int = 2, start_step: int = 0):
        """sample_fn(step) -> host-local batch dict."""
        self.sample_fn = sample_fn
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            try:
                batch = self.sample_fn(step)
            except Exception:           # pragma: no cover - defensive
                self._stop.set()
                raise
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        step, batch = self.q.get()
        return step, batch

    def __iter__(self) -> Iterator:
        while True:
            yield next(self)

    def close(self):
        self._stop.set()
        while not self.q.empty():
            try:
                self.q.get_nowait()
            except queue.Empty:
                break


def host_slice(global_batch: int, n_hosts: int, host_id: int) -> slice:
    per = global_batch // n_hosts
    return slice(host_id * per, (host_id + 1) * per)
