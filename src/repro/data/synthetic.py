"""Deterministic synthetic language corpus (no internet in this container).

A zipf-mixture Markov language with enough structure to be learnable:
  * K latent "topics", each a sparse bigram table over the vocab;
  * documents pick a topic, tokens follow the topic's bigram chain;
  * a cloze "reasoning" task (benchmarks): the model must recall the
    document's topic-defining token at a distance.

Everything is keyed by (seed, host, step) so multi-host training is
deterministic and restart-safe without any data files.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class CorpusConfig:
    vocab: int = 512
    n_topics: int = 8
    branch: int = 24            # out-degree of each bigram node
    seed: int = 1234


class SyntheticCorpus:
    def __init__(self, cfg: CorpusConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v, k, b = cfg.vocab, cfg.n_topics, cfg.branch
        # per-topic bigram structure: successor sets + zipf weights
        self.succ = rng.integers(2, v, size=(k, v, b))
        w = 1.0 / np.arange(1, b + 1) ** 1.2
        self.w = w / w.sum()
        self.topic_marker = rng.permutation(v - 2)[:k] + 2  # topic id tokens

    def sample_batch(self, batch: int, seq: int, *, step: int,
                     host: int = 0) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.cfg.seed * 1_000_003 + step) * 4099 + host)
        v, k = self.cfg.vocab, self.cfg.n_topics
        topics = rng.integers(0, k, size=batch)
        toks = np.zeros((batch, seq + 1), np.int32)
        toks[:, 0] = self.topic_marker[topics]
        choice = rng.choice(self.cfg.branch, size=(batch, seq),
                            p=self.w)
        for t in range(seq):
            toks[:, t + 1] = self.succ[topics, toks[:, t], choice[:, t]]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def iterate(self, batch: int, seq: int, *, start_step: int = 0,
                host: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.sample_batch(batch, seq, step=step, host=host)
            step += 1

    def heldout_ppl_batches(self, n: int, batch: int, seq: int):
        """Fixed evaluation batches (steps offset far from training)."""
        return [self.sample_batch(batch, seq, step=10_000_000 + i)
                for i in range(n)]

    def cloze_batch(self, n: int, seq: int = 64, *, seed: int = 0):
        """Reasoning probe: predict the topic marker repeated at the end.

        Returns tokens with the final position's correct answer; accuracy =
        P(argmax logits at last position == marker).
        """
        rng = np.random.default_rng(seed + 777)
        b = self.sample_batch(n, seq, step=20_000_000 + seed)
        toks = b["tokens"].copy()
        answers = toks[:, 0].copy()          # the topic marker
        toks[:, -1] = 1                      # cloze query token
        return {"tokens": toks, "answers": answers}
