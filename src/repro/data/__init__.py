"""data subsystem."""
