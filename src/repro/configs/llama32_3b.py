"""LLaMA-3.2-3B (paper model) [arXiv:2302.13971 lineage]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-3b", family="dense",
        n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8,
        d_ff=8192, vocab=128256,
        rope_theta=500_000.0, tie_embeddings=True,
    )
