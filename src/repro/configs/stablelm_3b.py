"""StableLM-3B — dense, MHA (kv=32) [hf:stabilityai/stablelm-2-1_6b family]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b", family="dense",
        n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=6912, vocab=50304,
        rotary_pct=0.25, qkv_bias=True,
    )
