"""Mamba2-370M — attention-free SSD (state-space duality) [arXiv:2405.21060]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m", family="ssm",
        n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0, head_dim=1,
        d_ff=0, vocab=50280,
        pattern=("mamba",),
        d_state=128, ssm_headdim=64, expand=2,
        tie_embeddings=True,
    )
