"""Whisper-medium — encoder-decoder, conv frontend STUB [arXiv:2212.04356].

Assignment lists 24L: interpreted as 24 encoder + 24 decoder layers (the
published medium config). input_specs() provides 1500 precomputed frame
embeddings (the conv1d+mel frontend is a stub per the assignment).
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium", family="encdec",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=4096, vocab=51865,
        is_encdec=True, n_enc_layers=24, enc_seq=1500,
        act="gelu", gated_mlp=False, qkv_bias=True,
    )
