"""The four assigned input-shape suites (applied per architecture).

train_4k    -> train_step      (seq 4096,   global batch 256)
prefill_32k -> prefill_step    (seq 32768,  global batch 32)
decode_32k  -> decode_step     (KV cache 32768, global batch 128, 1 new tok)
long_500k   -> decode_step     (KV cache 524288, global batch 1) — only for
               sub-quadratic architectures (SSM / hybrid), per assignment.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSuite:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeSuite("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeSuite("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeSuite("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeSuite("long_500k", "decode", 524288, 1)

ALL_SHAPES: Tuple[ShapeSuite, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K,
                                      LONG_500K)


def get_shape(name: str) -> ShapeSuite:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def applicable_shapes(cfg) -> Tuple[ShapeSuite, ...]:
    """Shape suites that apply to an architecture.

    long_500k runs for SSM/hybrid families (decode cost is linear: bounded
    SSM state + single-token KV reads); pure full-attention archs skip it
    per the assignment (noted in DESIGN.md §5).
    """
    shapes = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.family in ("ssm", "hybrid"):
        shapes.append(LONG_500K)
    return tuple(shapes)
