"""Jamba-1.5-Large (398B) — Mamba+attention 1:7 interleave, MoE 16e top-2

[arXiv:2403.19887]. Groups of 8 layers: 1 attention + 7 mamba; MoE FFN on
every other layer in the group.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=24576, vocab=65536,
        pattern=("attn",) + ("mamba",) * 7,
        moe_pattern=(False, True, False, True, False, True, False, True),
        n_experts=16, topk=2,
        d_state=128, ssm_headdim=128, expand=2,
    )
