"""Grok-1 314B — MoE, 8 experts top-2 [hf:xai-org/grok-1]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b", family="moe",
        n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=32768, vocab=131072,
        attn_softcap=30.0, logit_softcap=30.0,
        n_experts=8, topk=2, moe_pattern=(True,),
    )
