"""Architecture config registry: 10 assigned archs + the paper's 4 SLMs.

`get_config(arch)` returns the full published configuration;
`reduced_config(arch)` returns a small same-family config for CPU smoke
tests (few layers, narrow width, tiny vocab — structure preserved).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.models.config import ModelConfig
from repro.configs import shapes  # re-export
from repro.configs.shapes import ShapeSuite, applicable_shapes, get_shape

from repro.configs.internvl2_2b import config as _internvl2
from repro.configs.dbrx_132b import config as _dbrx
from repro.configs.grok1_314b import config as _grok
from repro.configs.stablelm_1_6b import config as _stablelm16
from repro.configs.gemma2_2b import config as _gemma2
from repro.configs.stablelm_3b import config as _stablelm3
from repro.configs.granite_8b import config as _granite
from repro.configs.whisper_medium import config as _whisper
from repro.configs.mamba2_370m import config as _mamba2
from repro.configs.jamba_1_5_large import config as _jamba
from repro.configs.hymba_1_5b import config as _hymba
from repro.configs.llama32_3b import config as _llama
from repro.configs.phi_1_5b import config as _phi
from repro.configs.qwen25_1_5b import config as _qwen

_REGISTRY = {
    # --- the 10 assigned architectures ---
    "internvl2-2b": _internvl2,
    "dbrx-132b": _dbrx,
    "grok-1-314b": _grok,
    "stablelm-1.6b": _stablelm16,
    "gemma2-2b": _gemma2,
    "stablelm-3b": _stablelm3,
    "granite-8b": _granite,
    "whisper-medium": _whisper,
    "mamba2-370m": _mamba2,
    "jamba-1.5-large-398b": _jamba,
    # --- the paper's own evaluation models ---
    "hymba-1.5b": _hymba,
    "llama-3.2-3b": _llama,
    "phi-1.5b": _phi,
    "qwen2.5-1.5b": _qwen,
}

ASSIGNED_ARCHS = ["internvl2-2b", "dbrx-132b", "grok-1-314b",
                  "stablelm-1.6b", "gemma2-2b", "stablelm-3b", "granite-8b",
                  "whisper-medium", "mamba2-370m", "jamba-1.5-large-398b"]
PAPER_ARCHS = ["hymba-1.5b", "llama-3.2-3b", "phi-1.5b", "qwen2.5-1.5b"]


def list_archs() -> List[str]:
    return list(_REGISTRY)


def get_config(arch: str) -> ModelConfig:
    try:
        return _REGISTRY[arch]()
    except KeyError:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_REGISTRY)}")


def reduced_config(arch: str) -> ModelConfig:
    """Shrink every dimension while preserving family structure."""
    cfg = get_config(arch)
    plen = len(cfg.pattern)
    n_layers = plen * 2                       # two scan groups
    heads = min(cfg.n_heads, 4) or 1
    kv = min(cfg.n_kv_heads, max(1, heads // 2)) or 1
    if cfg.n_heads and cfg.n_kv_heads:
        # preserve GQA divisibility
        while heads % kv:
            kv -= 1
    d_model = 128
    repl: Dict = dict(
        name=cfg.name + "-reduced",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=heads if cfg.n_heads else 0,
        n_kv_heads=kv if cfg.n_kv_heads else 0,
        head_dim=(d_model // heads) if cfg.n_heads else 1,
        d_ff=256 if cfg.d_ff else 0,
        vocab=512,
        window=min(cfg.window, 16),
        n_experts=min(cfg.n_experts, 4),
        topk=min(cfg.topk, 2),
        d_state=16 if cfg.d_state else 0,
        ssm_headdim=32 if cfg.d_state else 64,
        n_enc_layers=2 if cfg.is_encdec else 0,
        enc_seq=16 if cfg.is_encdec else cfg.enc_seq,
        n_vis_tokens=8 if cfg.n_vis_tokens else 0,
    )
    return dataclasses.replace(cfg, **repl)


__all__ = ["ASSIGNED_ARCHS", "PAPER_ARCHS", "ShapeSuite",
           "applicable_shapes", "get_config", "get_shape", "list_archs",
           "reduced_config", "shapes"]
