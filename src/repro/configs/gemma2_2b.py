"""Gemma2-2B — local/global alternating attention, logit softcap

[arXiv:2408.00118]. Pattern = (sliding-window local, global) per group.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b", family="dense",
        n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4,
        d_ff=9216, vocab=256000, head_dim=256,
        pattern=("attn_local", "attn"), window=4096,
        attn_softcap=50.0, logit_softcap=30.0,
        scale_embed=True, tie_embeddings=True,
        act="gelu",
    )
