"""DBRX-132B — fine-grained MoE, 16 experts top-4 [hf:databricks/dbrx-base]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="moe",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=10752, vocab=100352,
        rope_theta=500_000.0,
        n_experts=16, topk=4, moe_pattern=(True,),
    )
