"""Qwen2.5-1.5B-Instruct (paper model) [arXiv:2407.10671]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-1.5b", family="dense",
        n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
        d_ff=8960, vocab=151936,
        rope_theta=1_000_000.0, qkv_bias=True, tie_embeddings=True,
    )
