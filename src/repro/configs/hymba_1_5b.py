"""Hymba-1.5B (paper model) — parallel attention+SSM hybrid heads

[arXiv:2411.13676]. Parallel-head fusion approximated as mean of the two
mixer outputs; mostly sliding-window with periodic global layers.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", family="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
        d_ff=5504, vocab=32001,
        pattern=("hybrid", "hybrid_local", "hybrid_local", "hybrid_local"),
        window=1024,
        d_state=128, ssm_headdim=64, expand=2,
    )
