"""InternVL2-2B — InternViT frontend (stub) + InternLM2-1.8B backbone.

[arXiv:2404.16821; hf]. The vision tower is a STUB per the assignment:
input_specs() provides 256 precomputed patch embeddings at d_model.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b", family="vlm",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
        d_ff=8192, vocab=92553,
        rope_theta=1_000_000.0,
        n_vis_tokens=256,
    )
