"""Phi-1.5 (paper model) [Microsoft]. Partial rotary, gelu MLP."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi-1.5b", family="dense",
        n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab=51200,
        rotary_pct=0.5, act="gelu", gated_mlp=False, qkv_bias=True,
    )
