"""Straggler/hang watchdog for the training loop.

Every step arms a deadline; if the step (or the data queue) exceeds it, the
incident is logged and counted. Policies:
  * "log"    — record and continue (default; stragglers are transient),
  * "raise"  — abort so the job-level restarter (launch/train.py --resume)
               relaunches from the last checkpoint.

On a real cluster the deadline maps to the collective timeout; here it also
exercises the restart path in tests.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, List, Optional


@dataclasses.dataclass
class Incident:
    step: int
    elapsed_s: float
    kind: str


class StepWatchdog:
    def __init__(self, deadline_s: float = 60.0, policy: str = "log",
                 on_incident: Optional[Callable[[Incident], None]] = None):
        self.deadline_s = deadline_s
        self.policy = policy
        self.on_incident = on_incident
        self.incidents: List[Incident] = []
        self._timer: Optional[threading.Timer] = None
        self._armed_step = -1
        self._t0 = 0.0
        self._fired = threading.Event()

    def arm(self, step: int):
        self.disarm()
        self._armed_step = step
        self._t0 = time.monotonic()
        self._fired.clear()
        self._timer = threading.Timer(self.deadline_s, self._fire)
        self._timer.daemon = True
        self._timer.start()

    def _fire(self):
        inc = Incident(self._armed_step,
                       time.monotonic() - self._t0, "step_deadline")
        self.incidents.append(inc)
        self._fired.set()
        if self.on_incident:
            self.on_incident(inc)

    def disarm(self):
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None

    def check(self):
        """Call after each step: enforce the policy for fired deadlines."""
        if self._fired.is_set() and self.policy == "raise":
            raise TimeoutError(
                f"step {self._armed_step} exceeded "
                f"{self.deadline_s}s deadline (straggler/hang)")

    def close(self):
        self.disarm()
