"""ft subsystem."""
