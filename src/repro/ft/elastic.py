"""Elastic mesh selection + checkpoint resharding on restart.

After a node failure the job restarts with whatever device count survives.
`choose_mesh_shape(n)` picks the largest usable (data, model) grid — model
parallelism capped so TP stays intra-pod-sized — and checkpoint.restore
device_puts the (unsharded-on-disk) leaves with the new mesh's shardings.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.launch import mesh as meshlib

PREFERRED_TP = (16, 8, 4, 2, 1)


def choose_mesh_shape(n_devices: int, *, want_tp: int = 16,
                      pods: int = 1) -> Tuple[Tuple[int, ...],
                                              Tuple[str, ...]]:
    """Largest (pod, data, model) grid for n_devices (drops stragglers)."""
    per_pod = n_devices // pods
    for tp in PREFERRED_TP:
        if tp > want_tp:
            continue
        if per_pod % tp == 0 and per_pod // tp >= 1:
            dp = per_pod // tp
            if pods > 1:
                return (pods, dp, tp), ("pod", "data", "model")
            return (dp, tp), ("data", "model")
    return (n_devices,), ("data",)


def make_elastic_mesh(*, want_tp: int = 16, pods: int = 1,
                      devices=None):
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    shape, axes = choose_mesh_shape(n, want_tp=want_tp, pods=pods)
    used = 1
    for s in shape:
        used *= s
    return jax.make_mesh(shape, axes, devices=devices[:used])


def reshard_restore(tree_like, directory: str, mesh, spec_fn,
                    step: Optional[int] = None):
    """Restore a checkpoint written on any mesh onto `mesh`.

    spec_fn(tree_like, mesh) -> matching tree of NamedShardings.
    """
    from repro.checkpoint import ckpt
    shardings = spec_fn(tree_like, mesh)
    return ckpt.restore(tree_like, directory, step=step,
                        shardings=shardings)
