"""Span tracer exporting Chrome trace-event JSON (Perfetto-loadable).

One :class:`Tracer` records the serving stack's phase structure as
complete ("X") duration events plus instant ("i") point events, in the
Chrome ``traceEvents`` format — load the exported file in Perfetto
(https://ui.perfetto.dev) or ``chrome://tracing``. Timestamps are
microseconds from the tracer's construction (``time.perf_counter``
based), one track per Python thread. Pure host-side: tracing a round
costs a handful of ``perf_counter`` calls and dict appends; a *disabled*
tracer costs one branch per call site (the engine's acceptance bar is
< 2 % tokens/s overhead with tracing off).

Span / event naming contract (what later PRs must follow)
---------------------------------------------------------
Engine round phases — complete events, one per round, non-overlapping
and strictly inside their round's wall window, emitted by
``serve/engine.py``:

  * ``round/admit``        — scheduler round start + admissions: prefix
    /dedup matching, page adopts, COW ``page_copy`` dispatches and SSM
    ``reset_state`` dispatches for newly seated requests.
  * ``round/grant``        — chunk-budget grants + page allocation for
    every planned lane, including eviction/preemption fallout.
  * ``round/host_prep``    — building the step's host arrays (tokens /
    start / n_new), gather-work accounting and ``install_tables``
    (block-table validation + host→device upload).
  * ``round/device_step``  — the jitted unified step + argmax,
    ``block_until_ready`` + device→host logits transfer included; on a
    cold geometry this span absorbs the jit compile (see ``jit/compile``
    instants).
  * ``round/emit``         — token emission, stats, streaming callbacks,
    publish/finish/requeue bookkeeping.

Pipelined engines (``ServeEngine(pipelined=True)``) add two phases and
relax the window rule for one of them:

  * ``round/dispatch``     — the async step dispatch of an overlapped
    round: device-token carry + enqueue + ``copy_to_host_async``, NO
    ``block_until_ready`` (that is the point). Backends that bound
    their in-flight queue (CPU XLA) can still block the enqueue on the
    previous round's compute, so ``EngineStats`` charges this span as
    device wait, not host work.
  * ``round/retire``       — readback-complete + emission of the
    PREVIOUS round. A pipelined retire necessarily lands inside the
    NEXT round's wall window — the one sanctioned exception to the
    "strictly inside their round" rule above; synchronous engines never
    emit these two spans and keep the original contract bit-for-bit.

Request lifecycle — instant events with ``uid`` (and ``slot``) args,
emitted by ``serve/engine.py``:

  * ``req/admitted``    — seated into a slot (args: cached prompt tokens
    adopted, dedup flag).
  * ``req/chunk_done``  — one prefill chunk scattered (args: pos after).
  * ``req/first_token`` — first emission; exactly ONCE per request even
    across preemption/recompute (TTFT's clock rule).
  * ``req/preempted``   — recompute-style eviction; emitted tokens were
    discarded.
  * ``req/finished``    — terminal emission (args: n tokens out).

Scheduler / cache / jit events:

  * ``sched/dedup_wait`` — admission head waiting for an in-flight
    identical prompt's prefill (``serve/scheduler.py``).
  * ``sched/miss_wait``  — admission head serialized behind the one
    open prefix-cache miss (``serve/scheduler.py``).
  * ``cache/published``  — prefill pages inserted into the radix index
    (``serve/prefix_cache.py``; args: n new pages).
  * ``cache/evicted``    — index pages LRU-evicted under pressure
    (``serve/prefix_cache.py``; args: n pages).
  * ``jit/compile``      — a serving jit traced a new shape
    (``serve/steps.py`` TracedJit; args: fn, cache size, seconds).
  * ``jit/unexpected_retrace`` — cache growth beyond the step's declared
    compile surface: the late-flag-flip bug class, surfaced instead of
    silently stalling a round 10x.

Counter tracks — "C" events rendering as value lanes on the timeline:

  * ``pool/pages``  — per round (``serve/engine.py``): arena pages
    ``live`` / ``free`` — pool pressure next to the phase spans.
  * ``sched/queue`` — per round (``serve/engine.py``):
    ``prefill_pending`` admission-queue depth.
  * ``cost/<fn>``   — per traced-jit call when ``obs.costs`` capture is
    on (``serve/steps.py``): cumulative captured ``flops`` / ``bytes``
    of that step function, e.g. ``cost/step``.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import List, Optional


class _NullSpan:
    """Shared no-op context manager for disabled tracers."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("tracer", "name", "args", "t0")

    def __init__(self, tracer: "Tracer", name: str, args: dict):
        self.tracer, self.name, self.args = tracer, name, args

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        self.tracer._complete(self.name, self.t0, t1 - self.t0, self.args)
        return False


class Tracer:
    """Chrome-trace span/instant recorder.

    ``enabled=False`` (and the module-default tracer until someone turns
    it on) makes every recording method a constant-time no-op returning
    shared objects — instrument call sites unconditionally and let the
    flag decide. All recording is in-memory (a list of small dicts);
    :meth:`export` writes the ``{"traceEvents": [...]}`` JSON object.
    Appends are guarded by a lock only on the shared event list; the
    timestamp math is per-call-site."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.events: List[dict] = []
        self._t0 = time.perf_counter()
        self._pid = os.getpid()
        self._lock = threading.Lock()

    # ---- recording -----------------------------------------------------
    def _ts(self, t: float) -> float:
        return (t - self._t0) * 1e6

    def span(self, name: str, **args):
        """Context manager timing a phase; records one "X" event."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, args)

    def _complete(self, name: str, t0: float, dur_s: float,
                  args: dict) -> None:
        ev = {"name": name, "ph": "X", "ts": self._ts(t0),
              "dur": dur_s * 1e6, "pid": self._pid,
              "tid": threading.get_ident()}
        if args:
            ev["args"] = args
        with self._lock:
            self.events.append(ev)

    def complete(self, name: str, t0: float, dur_s: float,
                 **args) -> None:
        """Record an already-measured span (``t0`` in perf_counter
        seconds) — for call sites that time phases themselves."""
        if self.enabled:
            self._complete(name, t0, dur_s, args)

    def instant(self, name: str, **args) -> None:
        """Record a point occurrence (thread-scoped "i" event)."""
        if not self.enabled:
            return
        ev = {"name": name, "ph": "i", "s": "t",
              "ts": self._ts(time.perf_counter()), "pid": self._pid,
              "tid": threading.get_ident()}
        if args:
            ev["args"] = args
        with self._lock:
            self.events.append(ev)

    def counter(self, name: str, **values) -> None:
        """Record a Chrome counter-track sample ("C" event)."""
        if not self.enabled:
            return
        ev = {"name": name, "ph": "C",
              "ts": self._ts(time.perf_counter()), "pid": self._pid,
              "args": values}
        with self._lock:
            self.events.append(ev)

    # ---- export --------------------------------------------------------
    def to_json(self) -> dict:
        return {"traceEvents": list(self.events),
                "displayTimeUnit": "ms"}

    def export(self, path: str) -> int:
        """Write the Chrome trace JSON; returns the event count."""
        with open(path, "w") as f:
            json.dump(self.to_json(), f)
        return len(self.events)

    def clear(self) -> None:
        with self._lock:
            self.events.clear()

    # ---- analysis helpers (bench / tests) ------------------------------
    def phase_totals(self) -> dict:
        """Summed "X"-event duration per span name, in seconds."""
        out: dict = {}
        for ev in self.events:
            if ev["ph"] == "X":
                out[ev["name"]] = out.get(ev["name"], 0.0) \
                    + ev["dur"] * 1e-6
        return out


# ---------------------------------------------------------------------------
# process-default tracer: instrumentation sites not handed an explicit
# tracer (scheduler events, steps.py jit wrappers, prefix-cache eviction)
# record here. Disabled until ``set_tracer`` installs an enabled one
# (``launch/serve.py --trace-out`` does), so by default every call site
# is a single-branch no-op.
# ---------------------------------------------------------------------------
_DEFAULT = Tracer(enabled=False)


def get_tracer() -> Tracer:
    return _DEFAULT


def set_tracer(tracer: Tracer) -> Tracer:
    """Swap the process-default tracer; returns the previous one."""
    global _DEFAULT
    prev, _DEFAULT = _DEFAULT, tracer
    return prev


def active(tracer: Optional[Tracer]) -> Tracer:
    """Resolve an instrumentation site's tracer: the explicit one it was
    handed, else the process default."""
    return tracer if tracer is not None else _DEFAULT
