"""Per-step cost attribution: XLA cost_analysis x engine counters x DSE.

The paper's headline numbers are deployment *costs* (external transfers,
energy, latency per token) but the serving stack only measures wall time.
This module closes that gap three ways, per engine run:

  1. **Capture** — with :func:`enable_capture` on, every serving jit
     wrapped in ``serve/steps.py:TracedJit`` AOT-lowers each new call
     shape once and records ``cost_analysis()`` FLOPs / bytes-accessed
     per call (the ``launch/xla_compat.py`` shim; backends without a
     cost model degrade to zeros, never raise).
  2. **Attribute** — :func:`attribute` diffs the step set's per-shape
     call/wall tables across a run and scores each (fn, shape) against
     its roofline bound (``launch/roofline.py``): measured wall seconds
     vs ``calls * max(flops/PEAK_FLOPS, bytes/HBM_BW)``, plus arithmetic
     intensity. The drift ratio (measured / roofline) is the
     model-vs-measured health signal — a QMC step 5x over its roofline
     is kernel overhead, not bandwidth.
  3. **Model** — the same run's ``EngineStats`` page/token counters feed
     the Eq. (3)/(4) DSE (``memsys/workload.py`` traffic +
     ``memsys/system.py`` evaluate_hetero / evaluate_conventional), so
     each run also reports *modeled* bytes / energy / latency per round
     and per token for the weight format it actually served.

Exports land on the existing obs surfaces via :func:`flush_metrics`
(``serve_cost_*`` instruments per the ``obs/metrics.py`` contract) and
the ``cost/<fn>`` Perfetto counter tracks TracedJit emits per call.
Wired end to end by ``launch/serve.py --cost-report`` and the
``cost_attribution`` section of ``benchmarks/serving.py``.

Sampling and speculative verification need no rows of their own: token
selection is fused INTO the step (``serve/sampling.py`` — its FLOPs land
in the step's per-width cost, and no out-of-jit argmax dispatch exists
to go unattributed any more), and a speculative verify call is just the
step at a ``width_ladder`` rung, so it lands in that rung's ``C<width>``
row. The invariant the regression tests pin: one engine round == exactly
one attributed ``step``/``solo_step`` dispatch.

Capture is OFF by default: the only cost any other path pays is one
module-bool branch per traced call. Turning it on makes each TracedJit
call synchronous (``block_until_ready`` inside the timed window) so the
per-shape wall tables measure device time, not async dispatch — a
measurement mode, not a serving mode.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.launch import roofline as rl
from repro.launch import xla_compat
from repro.memsys.system import (MemSystemConfig, evaluate_conventional,
                                 evaluate_hetero)
from repro.memsys.workload import (act_bits_per_step, kv_bits_per_step,
                                   make_traffic)

# ---------------------------------------------------------------------------
# capture switch
# ---------------------------------------------------------------------------
_CAPTURE = False


def enable_capture(on: bool = True) -> bool:
    """Turn per-call cost capture on/off; returns the previous state."""
    global _CAPTURE
    prev, _CAPTURE = _CAPTURE, bool(on)
    return prev


def capture_enabled() -> bool:
    return _CAPTURE


def capture_costs(fn, args, kw) -> Dict[str, float]:
    """AOT-lower one call shape and read its cost analysis.

    ``{"flops": f, "bytes": b}`` per invocation (per device); any
    failure — a non-jit callable, a backend without ``lower``, an empty
    cost model — degrades to zeros. Attribution then reports measured
    wall time with the roofline columns zeroed and the drift gauge
    suppressed; it never raises into the serving path.
    """
    try:
        compiled = fn.lower(*args, **kw).compile()
        flops, nbytes = xla_compat.flops_bytes(compiled)
        return {"flops": flops, "bytes": nbytes}
    except Exception:
        return {"flops": 0.0, "bytes": 0.0}


# ---------------------------------------------------------------------------
# per-(fn, shape) attribution rows
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class FnCost:
    """One (step function, call shape) row of the attribution table."""
    fn: str                          # TracedJit name: step / page_copy / ...
    key: str                         # call-shape key, e.g. "C1" / "C16"
    calls: int
    wall_s: float                    # measured (synchronous) wall seconds
    flops_per_call: float
    bytes_per_call: float

    @property
    def label(self) -> str:
        return f"{self.fn}/{self.key}"

    @property
    def captured(self) -> bool:
        return self.flops_per_call > 0 or self.bytes_per_call > 0

    def roofline(self) -> rl.Roofline:
        return rl.from_artifacts(
            self.fn, self.key, "-", 1,
            {"flops": self.flops_per_call,
             "bytes accessed": self.bytes_per_call},
            {}, model_flops=0.0)

    @property
    def roofline_s(self) -> float:
        """Bound time for all calls: max(compute, memory) per call."""
        return self.calls * self.roofline().roofline_time

    @property
    def drift(self) -> float:
        """Measured / roofline-bound wall time (>= 1 in practice; 0 when
        capture degraded to zeros)."""
        r = self.roofline_s
        return self.wall_s / r if r > 0 else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the roofline bound achieved (roofline / measured)."""
        return self.roofline_s / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def arithmetic_intensity(self) -> float:
        """FLOPs per byte accessed — where on the roofline this shape sits."""
        return (self.flops_per_call / self.bytes_per_call
                if self.bytes_per_call > 0 else 0.0)

    def to_dict(self) -> dict:
        return {"fn": self.fn, "key": self.key, "calls": self.calls,
                "wall_s": self.wall_s,
                "flops_per_call": self.flops_per_call,
                "bytes_per_call": self.bytes_per_call,
                "roofline_s": self.roofline_s, "drift": self.drift,
                "roofline_fraction": self.roofline_fraction,
                "arithmetic_intensity": self.arithmetic_intensity}


def _traced_members(step_set):
    """The step set's TracedJit-like members, duck-typed (no import of
    ``serve.steps`` — it imports this module)."""
    for name in ("step", "solo_step", "page_copy", "reset_state",
                 "apply_page_ops"):
        fn = getattr(step_set, name, None)
        if fn is not None and hasattr(fn, "cost_by_key"):
            yield fn


def snapshot(step_set) -> Dict[Tuple[str, str], Tuple[int, float]]:
    """Per-(fn, shape) (calls, wall seconds) tables right now — diff two
    of these around a run to attribute that run only."""
    out = {}
    for fn in _traced_members(step_set):
        for key, n in fn.calls_by_key.items():
            out[(fn.name, key)] = (n, fn.seconds_by_key.get(key, 0.0))
    return out


def collect(step_set, baseline=None) -> List[FnCost]:
    """Attribution rows for a step set, minus an optional prior
    :func:`snapshot` (so warm engines report only their own run)."""
    baseline = baseline or {}
    rows = []
    for fn in _traced_members(step_set):
        for key, n in fn.calls_by_key.items():
            n0, s0 = baseline.get((fn.name, key), (0, 0.0))
            calls = n - n0
            if calls <= 0:
                continue
            cost = fn.cost_by_key.get(key, {})
            rows.append(FnCost(
                fn=fn.name, key=key, calls=calls,
                wall_s=fn.seconds_by_key.get(key, 0.0) - s0,
                flops_per_call=float(cost.get("flops", 0.0)),
                bytes_per_call=float(cost.get("bytes", 0.0))))
    rows.sort(key=lambda r: -r.wall_s)
    return rows


# ---------------------------------------------------------------------------
# modeled memory-system cost from EngineStats counters
# ---------------------------------------------------------------------------
def detect_weights_method(params) -> str:
    """Map a serving params tree to a ``make_traffic`` method name.

    QTensor / ShardedQTensor leaves anywhere -> ``qmc``; else the widest
    float dtype decides ``fp32`` vs ``fp16`` (bf16 streams 16 bits too).
    """
    import jax
    import jax.numpy as jnp
    from repro.core.qtensor import QTensor
    from repro.core.qtensor_sharded import ShardedQTensor

    q = (QTensor, ShardedQTensor)
    leaves = jax.tree_util.tree_flatten(
        params, is_leaf=lambda x: isinstance(x, q))[0]
    if any(isinstance(x, q) for x in leaves):
        return "qmc"
    for x in leaves:
        if hasattr(x, "dtype") and x.dtype == jnp.float32:
            return "fp32"
    return "fp16"


def modeled_memsys(cfg, stats, *, method: str, page: int,
                   kv_dtype_bits: int = 16, qmc=None,
                   sys_cfg: Optional[MemSystemConfig] = None) -> dict:
    """Eq. (3)/(4) cost of the run the engine just measured.

    Rebinds a :func:`make_traffic` stream to THIS run's averages: per
    round (one jit step), weights stream once, the KV stream is the page
    count the engine actually gathered/wrote (``kv_pages_live`` decode
    reads + ``prefill_kv_pages_live`` chunk reads + page-rounded writes,
    the same accounts ``kv_traffic_paged/chunked`` charge), and
    activations scale with the lane-steps the round carried. Returns a
    JSON-able dict with per-round bits, bytes/token and the
    ``evaluate_hetero`` / ``evaluate_conventional`` results; degenerate
    runs (no rounds or no tokens) report zeros with ``degenerate=True``.
    """
    from repro.core.qconfig import QMCConfig
    sys_cfg = sys_cfg or MemSystemConfig()
    rounds = int(getattr(stats, "rounds", 0))
    tokens = int(getattr(stats, "tokens_out", 0))
    if rounds <= 0 or tokens <= 0:
        return {"method": method, "degenerate": True,
                "rounds": rounds, "tokens_out": tokens,
                "bytes_per_round": 0.0, "bytes_per_token": 0.0,
                "weight_bits_per_round": 0.0, "kv_bits_per_round": 0.0,
                "act_bits_per_round": 0.0}

    per_page_bits = (kv_bits_per_step(cfg, page, kv_dtype_bits)
                     - kv_bits_per_step(cfg, 0, kv_dtype_bits))
    ssm_bits = kv_bits_per_step(cfg, 0, kv_dtype_bits)
    lane_steps = tokens + int(getattr(stats, "prefill_chunks", 0))
    pages_read = (int(getattr(stats, "kv_pages_live", 0))
                  + int(getattr(stats, "prefill_kv_pages_live", 0)))
    kv_read = pages_read * per_page_bits + lane_steps * ssm_bits
    kv_write = (int(getattr(stats, "prefill_kv_pages_written", 0))
                * per_page_bits + tokens * per_page_bits / page)

    base = make_traffic(cfg, method, qmc=qmc or QMCConfig())
    traffic = dataclasses.replace(
        base, name=f"{base.name}+run",
        kv_bits=(kv_read + kv_write) / rounds,
        act_bits=act_bits_per_step(cfg) * lane_steps / rounds)
    het = evaluate_hetero(traffic, sys_cfg)
    conv = evaluate_conventional(traffic, sys_cfg, legacy_flash=False)

    bits_per_round = traffic.weight_bits + traffic.kv_bits \
        + traffic.act_bits

    def _res(r) -> dict:
        return {"latency_s": r.latency_s, "energy_j": r.energy_j,
                "external_bits": r.external_bits, "power_w": r.power_w,
                "feasible": r.feasible}

    return {
        "method": method, "degenerate": False,
        "rounds": rounds, "tokens_out": tokens,
        "weight_bits_per_round": traffic.weight_bits,
        "kv_bits_per_round": traffic.kv_bits,
        "act_bits_per_round": traffic.act_bits,
        "bytes_per_round": bits_per_round / 8.0,
        "bytes_per_token": bits_per_round * rounds / 8.0 / tokens,
        "hetero": _res(het),
        "conventional": _res(conv),
        "energy_j_per_token": het.energy_j * rounds / tokens,
        "latency_s_per_token": het.latency_s * rounds / tokens,
    }


# ---------------------------------------------------------------------------
# the full report
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CostReport:
    """One run's cost attribution: per-(fn, shape) roofline rows + the
    modeled memory-system cost of the same run."""
    fns: List[FnCost]
    modeled: dict
    measured_wall_s: float
    measured_device_s: float
    tokens_out: int

    def to_dict(self) -> dict:
        return {"fns": [r.to_dict() for r in self.fns],
                "modeled": self.modeled,
                "measured_wall_s": self.measured_wall_s,
                "measured_device_s": self.measured_device_s,
                "tokens_out": self.tokens_out}

    def table(self) -> str:
        lines = [f"{'fn/shape':14s} {'calls':>6s} {'wall_s':>9s} "
                 f"{'roofline_s':>10s} {'drift':>7s} {'ai':>7s}"]
        for r in self.fns:
            lines.append(
                f"{r.label:14s} {r.calls:6d} {r.wall_s:9.4f} "
                f"{r.roofline_s:10.6f} "
                f"{(f'{r.drift:7.1f}' if r.captured else '      -')} "
                f"{(f'{r.arithmetic_intensity:7.2f}' if r.captured else '      -')}")
        m = self.modeled
        if m and not m.get("degenerate"):
            lines.append(
                f"modeled[{m['method']}]: "
                f"{m['bytes_per_token'] / 1e6:.2f} MB/token, "
                f"hetero {m['energy_j_per_token'] * 1e3:.3f} mJ/token "
                f"{m['latency_s_per_token'] * 1e3:.3f} ms/token "
                f"(feasible={m['hetero']['feasible']})")
        return "\n".join(lines)


def attribute(step_set, stats, *, cfg, params=None,
              method: Optional[str] = None, page: int,
              kv_dtype_bits: int = 16, baseline=None, qmc=None,
              sys_cfg: Optional[MemSystemConfig] = None) -> CostReport:
    """Assemble a run's :class:`CostReport` from its step set + stats."""
    if method is None:
        method = detect_weights_method(params) if params is not None \
            else "fp16"
    return CostReport(
        fns=collect(step_set, baseline),
        modeled=modeled_memsys(cfg, stats, method=method, page=page,
                               kv_dtype_bits=kv_dtype_bits, qmc=qmc,
                               sys_cfg=sys_cfg),
        measured_wall_s=float(getattr(stats, "wall_s", 0.0)),
        measured_device_s=float(stats.device_seconds()
                                if hasattr(stats, "device_seconds")
                                else 0.0),
        tokens_out=int(getattr(stats, "tokens_out", 0)))


def flush_metrics(reg, report: CostReport) -> None:
    """Fold a report into a metrics registry per the ``serve_cost_*``
    contract (``obs/metrics.py``). The drift gauge is only set for rows
    whose capture succeeded — a backend without a cost model suppresses
    it rather than reporting drift=0 as if the step hit its roofline."""
    flops = reg.counter("serve_cost_flops_total",
                        "captured XLA FLOPs executed, per fn/shape",
                        labels=("fn",))
    nbytes = reg.counter("serve_cost_bytes_total",
                         "captured XLA bytes accessed, per fn/shape",
                         labels=("fn",))
    drift = reg.gauge("serve_cost_drift_ratio",
                      "measured wall / roofline bound, per fn/shape",
                      labels=("fn",))
    for r in report.fns:
        flops.inc(r.flops_per_call * r.calls, fn=r.label)
        nbytes.inc(r.bytes_per_call * r.calls, fn=r.label)
        if r.captured:
            drift.set(r.drift, fn=r.label)
    m = report.modeled
    if m and not m.get("degenerate"):
        reg.gauge("serve_cost_modeled_bytes_per_token",
                  "Eq.(3)/(4) modeled memory traffic per emitted token"
                  ).set(m["bytes_per_token"])
        e = reg.gauge("serve_cost_modeled_energy_j",
                      "modeled per-round memory energy", labels=("system",))
        lat = reg.gauge("serve_cost_modeled_latency_s",
                        "modeled per-round memory latency",
                        labels=("system",))
        for system in ("hetero", "conventional"):
            e.set(m[system]["energy_j"], system=system)
            lat.set(m[system]["latency_s"], system=system)
