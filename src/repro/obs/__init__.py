"""Serving observability: metrics, tracing, and cost attribution.

Three host-side modules the serving stack records itself through:

  * ``obs.metrics`` — counters / gauges / fixed-log-bucket histograms
    with labels, behind a get-or-create :class:`~repro.obs.metrics.
    Registry`; snapshot-to-JSON and Prometheus text exposition. The
    metric naming contract lives in its module docstring.
  * ``obs.trace`` — a span :class:`~repro.obs.trace.Tracer` (context-
    manager API, near-zero overhead when disabled, instant events for
    point occurrences) exporting Chrome trace-event JSON loadable in
    Perfetto. The span/event naming contract lives in its module
    docstring.
  * ``obs.costs`` — per-step cost attribution: opt-in capture of XLA
    ``cost_analysis()`` FLOPs/bytes per serving-jit call shape, roofline
    drift (measured wall vs bound), and the Eq. (3)/(4) modeled memory
    cost of the run's engine counters. Off by default (one bool branch
    per traced call); ``launch/serve.py --cost-report`` and the bench's
    ``cost_attribution`` section turn it on.

The first two keep a process-default instance (``get_registry`` /
``get_tracer``) so deep call sites — the steps.py jit-compile wrappers,
scheduler wait events — need no plumbing; engines and tests may pass
explicit instances instead. ``launch/serve.py
--trace-out/--metrics-out`` turns the defaults on and writes both files
after a run.
"""
from repro.obs.costs import (CostReport, FnCost,  # noqa: F401
                             attribute, capture_enabled, enable_capture,
                             modeled_memsys)
from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               Registry, get_registry, log_buckets,
                               set_registry)
from repro.obs.trace import (Tracer, active, get_tracer,  # noqa: F401
                             set_tracer)
