"""Serving observability: metrics registry + Chrome-trace span tracer.

Two host-side modules the serving stack records itself through:

  * ``obs.metrics`` — counters / gauges / fixed-log-bucket histograms
    with labels, behind a get-or-create :class:`~repro.obs.metrics.
    Registry`; snapshot-to-JSON and Prometheus text exposition. The
    metric naming contract lives in its module docstring.
  * ``obs.trace`` — a span :class:`~repro.obs.trace.Tracer` (context-
    manager API, near-zero overhead when disabled, instant events for
    point occurrences) exporting Chrome trace-event JSON loadable in
    Perfetto. The span/event naming contract lives in its module
    docstring.

Both keep a process-default instance (``get_registry`` / ``get_tracer``)
so deep call sites — the steps.py jit-compile wrappers, scheduler wait
events — need no plumbing; engines and tests may pass explicit instances
instead. ``launch/serve.py --trace-out/--metrics-out`` turns the
defaults on and writes both files after a run.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               Registry, get_registry, log_buckets,
                               set_registry)
from repro.obs.trace import (Tracer, active, get_tracer,  # noqa: F401
                             set_tracer)
