"""Lightweight metrics registry: counters, gauges, log-bucket histograms.

The serving stack records its health through ONE of these registries —
``serve/engine.py`` (round/phase accounting, admissions, page ops),
``serve/steps.py`` (jit compile / retrace counters) and ``launch/serve.py``
(``--metrics-out`` snapshot) all write here. Pure host-side Python: no jax
imports, no device work, safe to call from inside the engine's round loop
(a counter ``inc`` is one dict lookup + add).

Naming contract (what later PRs must follow)
--------------------------------------------
Metric names are ``serve_<noun>_<unit-or-total>`` with Prometheus
conventions: monotonic counts end in ``_total``, durations are base-unit
seconds. The instruments the serving stack registers today:

  * ``serve_rounds_total``                — engine rounds executed
  * ``serve_tokens_total{kind}``          — ``emitted`` | ``discarded``
  * ``serve_admissions_total{kind}``      — ``miss`` | ``hit`` | ``dedup``
  * ``serve_preemptions_total``           — recompute-style evictions
  * ``serve_page_ops_total{op}``          — host↔device page-op round
    trips: ``adopt`` | ``page_copy`` | ``tables_rebuild`` | ``cow`` |
    ``cache_evict``
  * ``serve_phase_seconds{phase}``        — histogram of per-round phase
    wall time, one label value per span name in ``obs/trace.py``'s
    contract (``round/admit`` ... ``round/emit``)
  * ``serve_jit_compiles_total{fn}``      — traced-jit cache growth per
    step function (``step`` / ``page_copy`` / ``reset_state``)
  * ``serve_jit_retraces_unexpected_total{fn}`` — compiles beyond a step
    function's declared compile surface (the late-flag-flip bug class)

The ``serve_cost_*`` family (written by ``obs/costs.py:flush_metrics``
only when cost capture ran — see that module). ``fn`` labels are
``<step-fn>/<shape-key>``, e.g. ``step/C1`` / ``step/C16``:

  * ``serve_cost_flops_total{fn}``        — captured XLA FLOPs executed
  * ``serve_cost_bytes_total{fn}``        — captured XLA bytes accessed
  * ``serve_cost_drift_ratio{fn}``        — gauge: measured wall /
    roofline bound per fn/shape; SUPPRESSED (not set) for rows whose
    ``cost_analysis()`` capture degraded to zeros, so a backend without
    a cost model never reports a fake drift of 0
  * ``serve_cost_modeled_bytes_per_token``     — gauge: Eq. (3)/(4)
    modeled memory traffic per emitted token
  * ``serve_cost_modeled_energy_j{system}``    — gauge: modeled
    per-round energy, ``system`` in ``hetero`` | ``conventional``
  * ``serve_cost_modeled_latency_s{system}``   — gauge: modeled
    per-round latency, same label values

Snapshots serialize two ways: :meth:`Registry.snapshot` (JSON-able dict,
written by ``--metrics-out``) and :meth:`Registry.to_prometheus` (text
exposition format, scrapeable once an HTTP front door exists).
"""
from __future__ import annotations

import json
import math
import threading
from typing import Dict, List, Optional, Sequence, Tuple


def log_buckets(lo: float = 1e-6, factor: float = 4.0,
                count: int = 12) -> Tuple[float, ...]:
    """Fixed log-spaced histogram bounds: ``lo * factor**k``.

    The default (1 µs · 4^k, 12 bounds) spans 1 µs .. ~4.2 s — wide
    enough for host phase slivers and cold jit compiles alike, at 12
    ints of storage per label set."""
    return tuple(lo * factor ** k for k in range(count))


def _label_values(label_names: Sequence[str], labels: dict,
                  metric: str) -> Tuple[str, ...]:
    if set(labels) != set(label_names):
        raise ValueError(
            f"{metric}: got labels {sorted(labels)}, declared "
            f"{sorted(label_names)}")
    return tuple(str(labels[k]) for k in label_names)


class Counter:
    """Monotonically increasing count, optionally per label set."""

    def __init__(self, name: str, help: str, label_names: Sequence[str]):
        self.name, self.help = name, help
        self.label_names = tuple(label_names)
        self._values: Dict[Tuple[str, ...], float] = {}

    def inc(self, n: float = 1, **labels) -> None:
        if n < 0:
            raise ValueError(f"{self.name}: counters only go up (n={n})")
        key = _label_values(self.label_names, labels, self.name)
        self._values[key] = self._values.get(key, 0) + n

    def value(self, **labels) -> float:
        return self._values.get(
            _label_values(self.label_names, labels, self.name), 0)


class Gauge:
    """Point-in-time value (set/add), optionally per label set."""

    def __init__(self, name: str, help: str, label_names: Sequence[str]):
        self.name, self.help = name, help
        self.label_names = tuple(label_names)
        self._values: Dict[Tuple[str, ...], float] = {}

    def set(self, v: float, **labels) -> None:
        self._values[_label_values(self.label_names, labels,
                                   self.name)] = v

    def add(self, n: float, **labels) -> None:
        key = _label_values(self.label_names, labels, self.name)
        self._values[key] = self._values.get(key, 0) + n

    def value(self, **labels) -> float:
        return self._values.get(
            _label_values(self.label_names, labels, self.name), 0)


class Histogram:
    """Fixed-bound histogram (cumulative buckets + sum + count).

    Bounds are upper-inclusive like Prometheus ``le``; one implicit
    ``+Inf`` bucket catches the tail. Use :func:`log_buckets` for the
    standard log-spaced seconds bounds."""

    def __init__(self, name: str, help: str, label_names: Sequence[str],
                 buckets: Sequence[float]):
        if list(buckets) != sorted(buckets) or len(set(buckets)) != \
                len(tuple(buckets)):
            raise ValueError(f"{name}: bucket bounds must be strictly "
                             f"increasing, got {tuple(buckets)}")
        self.name, self.help = name, help
        self.label_names = tuple(label_names)
        self.buckets = tuple(float(b) for b in buckets)
        # per label set: [counts per bound + inf, sum, n]
        self._series: Dict[Tuple[str, ...], List] = {}

    def _row(self, key):
        row = self._series.get(key)
        if row is None:
            row = [[0] * (len(self.buckets) + 1), 0.0, 0]
            self._series[key] = row
        return row

    def observe(self, v: float, **labels) -> None:
        row = self._row(_label_values(self.label_names, labels, self.name))
        i = len(self.buckets)
        for j, b in enumerate(self.buckets):
            if v <= b:
                i = j
                break
        row[0][i] += 1
        row[1] += v
        row[2] += 1

    def count(self, **labels) -> int:
        key = _label_values(self.label_names, labels, self.name)
        return self._series[key][2] if key in self._series else 0

    def sum(self, **labels) -> float:
        key = _label_values(self.label_names, labels, self.name)
        return self._series[key][1] if key in self._series else 0.0


class Registry:
    """Get-or-create home for named instruments.

    Re-registering a name returns the existing instrument — and raises if
    the type, labels or buckets disagree, so two instrumentation sites
    can never silently split one logical metric."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name, help, label_names, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, label_names, **kw)
                self._metrics[name] = m
                return m
        if not isinstance(m, cls) or m.label_names != tuple(label_names) \
                or kw.get("buckets") is not None \
                and m.buckets != tuple(kw["buckets"]):
            raise ValueError(
                f"metric {name!r} re-registered with a different "
                f"type/labels/buckets")
        return m

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        return self._get(Histogram, name, help, labels,
                         buckets=tuple(buckets or log_buckets()))

    def reset(self) -> None:
        """Drop every instrument (tests / fresh measurement windows)."""
        with self._lock:
            self._metrics.clear()

    # ---- export --------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-able view: every instrument with all its label series."""
        out = {}
        for name, m in sorted(self._metrics.items()):
            entry = {"type": type(m).__name__.lower(), "help": m.help,
                     "labels": list(m.label_names)}
            if isinstance(m, Histogram):
                entry["buckets"] = list(m.buckets)
                entry["series"] = [
                    {"labels": dict(zip(m.label_names, key)),
                     "counts": list(row[0]), "sum": row[1],
                     "count": row[2]}
                    for key, row in sorted(m._series.items())]
            else:
                entry["series"] = [
                    {"labels": dict(zip(m.label_names, key)), "value": v}
                    for key, v in sorted(m._values.items())]
            out[name] = entry
        return out

    def write_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2)

    def to_prometheus(self) -> str:
        """Text exposition format (one scrape body)."""
        lines = []
        for name, m in sorted(self._metrics.items()):
            kind = {"Counter": "counter", "Gauge": "gauge",
                    "Histogram": "histogram"}[type(m).__name__]
            if m.help:
                lines.append(f"# HELP {name} {_esc_help(m.help)}")
            lines.append(f"# TYPE {name} {kind}")
            if isinstance(m, Histogram):
                for key, row in sorted(m._series.items()):
                    cum = 0
                    for b, c in zip(m.buckets, row[0]):
                        cum += c
                        lines.append(
                            f"{name}_bucket"
                            f"{_labels(m.label_names, key, le=_fmt(b))}"
                            f" {cum}")
                    cum += row[0][-1]
                    lines.append(
                        f"{name}_bucket"
                        f"{_labels(m.label_names, key, le='+Inf')} {cum}")
                    lines.append(
                        f"{name}_sum{_labels(m.label_names, key)}"
                        f" {_fmt(row[1])}")
                    lines.append(
                        f"{name}_count{_labels(m.label_names, key)}"
                        f" {row[2]}")
            else:
                for key, v in sorted(m._values.items()):
                    lines.append(
                        f"{name}{_labels(m.label_names, key)} {_fmt(v)}")
        return "\n".join(lines) + "\n"


def _fmt(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if isinstance(v, float) and v.is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(v)


def _esc_help(s: str) -> str:
    return s.replace("\\", r"\\").replace("\n", r"\n")


def _esc_label(s: str) -> str:
    return s.replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")


def _labels(names: Sequence[str], values: Sequence[str], **extra) -> str:
    pairs = [(n, v) for n, v in zip(names, values)] + list(extra.items())
    if not pairs:
        return ""
    body = ",".join(f'{n}="{_esc_label(str(v))}"' for n, v in pairs)
    return "{" + body + "}"


# ---------------------------------------------------------------------------
# process-default registry: instrumentation sites that are not handed an
# explicit registry (deep call sites like the steps.py jit wrappers) write
# here; ``launch/serve.py --metrics-out`` snapshots it.
# ---------------------------------------------------------------------------
_DEFAULT = Registry()


def get_registry() -> Registry:
    return _DEFAULT


def set_registry(reg: Registry) -> Registry:
    """Swap the process-default registry; returns the previous one."""
    global _DEFAULT
    prev, _DEFAULT = _DEFAULT, reg
    return prev
