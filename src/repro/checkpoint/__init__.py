"""checkpoint subsystem."""
