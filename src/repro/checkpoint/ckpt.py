"""Sharded, atomic, async checkpointing (no external deps).

Layout:  <dir>/step_<N>/
           index.msgpack   — pytree structure, leaf shapes/dtypes, step
           shard_<i>.npz   — leaf arrays, chunked ~512MB per file
         <dir>/LATEST      — atomic pointer (written last)

Restores onto ANY mesh: leaves are saved unsharded (gathered via
jax.device_get on addressable shards) and resharded on load by the caller's
shardings — this is the elastic-restart path (checkpoint written on 512
chips restores on 256, 8, or 1).

Async: `save_async` snapshots to host memory synchronously (cheap) and
writes in a daemon thread so training continues during I/O.
"""
from __future__ import annotations

import os
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import msgpack
import numpy as np

_MAX_SHARD_BYTES = 512 * 1024 * 1024


def _flatten(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "name"):
                parts.append(str(p.name))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        out.append(("/".join(parts), leaf))
    return out, treedef


def save(tree, directory: str, step: int) -> str:
    flat, _ = _flatten(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat}
    return _write(host, directory, step)


def _write(host: Dict[str, np.ndarray], directory: str, step: int) -> str:
    stepdir = os.path.join(directory, f"step_{step}")
    tmpdir = stepdir + ".tmp"
    os.makedirs(tmpdir, exist_ok=True)

    index = {"step": step, "leaves": {}, "shards": 0}
    shard: Dict[str, np.ndarray] = {}
    size = 0
    shard_id = 0

    def flush():
        nonlocal shard, size, shard_id
        if not shard:
            return
        np.savez(os.path.join(tmpdir, f"shard_{shard_id}.npz"), **shard)
        shard, size = {}, 0
        shard_id += 1

    for key, arr in sorted(host.items()):
        if size + arr.nbytes > _MAX_SHARD_BYTES and shard:
            flush()
        safe = key.replace("/", "§")
        shard[safe] = arr
        index["leaves"][key] = {"shard": shard_id,
                                "dtype": str(arr.dtype),
                                "shape": list(arr.shape)}
        size += arr.nbytes
    flush()
    index["shards"] = shard_id
    with open(os.path.join(tmpdir, "index.msgpack"), "wb") as f:
        f.write(msgpack.packb(index))
    if os.path.exists(stepdir):
        import shutil
        shutil.rmtree(stepdir)
    os.rename(tmpdir, stepdir)                    # atomic publish
    latest_tmp = os.path.join(directory, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(str(step))
    os.replace(latest_tmp, os.path.join(directory, "LATEST"))
    return stepdir


class AsyncCheckpointer:
    """Snapshot-on-call, write-in-background. One outstanding write."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save(self, tree, directory: str, step: int):
        self.wait()
        flat, _ = _flatten(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat}

        def run():
            self.last_path = _write(host, directory, step)
        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(directory: str) -> Optional[int]:
    p = os.path.join(directory, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def restore(tree_like, directory: str, step: Optional[int] = None,
            shardings=None):
    """Load into the structure of `tree_like` (shapes must match).

    `shardings`: optional matching tree of NamedShardings — leaves are
    device_put with them (elastic re-shard onto the current mesh).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    stepdir = os.path.join(directory, f"step_{step}")
    with open(os.path.join(stepdir, "index.msgpack"), "rb") as f:
        index = msgpack.unpackb(f.read())

    cache: Dict[int, Any] = {}

    def get_arr(key: str) -> np.ndarray:
        meta = index["leaves"][key]
        sid = meta["shard"]
        if sid not in cache:
            cache[sid] = np.load(os.path.join(stepdir, f"shard_{sid}.npz"))
        return cache[sid][key.replace("/", "§")]

    flat, treedef = _flatten(tree_like)
    shard_flat = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(flat))
    out = []
    for (key, ref), shd in zip(flat, shard_flat):
        arr = get_arr(key)
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {ref.shape}")
        arr = arr.astype(ref.dtype)
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), step
