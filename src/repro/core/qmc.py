"""Algorithm 1 — Outlier-Aware Robust Quantization (the paper's core).

`qmc_quantize` is the paper-faithful scalar-granularity routine:

  Step 1  partition W into outliers (top-rho by |w|) and inliers,
  Step 2  inlier scale via noise-aware search (Eq. 5-7), quantize to 3 bits,
  Step 3  outlier scale via plain MSE search, quantize to 5 bits,
  Step 4  scatter/merge: W~ = scatter(W_in*, W_out*).

Returns the fake-quantized tensor (for accuracy evaluation) plus the pieces
needed by the memory simulator and by noise-injection studies.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import partition as part
from repro.core.noise import perturb_codes
from repro.core.qconfig import QMCConfig
from repro.core.quantizers import (dequantize, mse_scale_search,
                                   noise_aware_scale_search, quantize_codes)


@dataclasses.dataclass
class QMCResult:
    w_hat: jax.Array          # merged fake-quantized weights (Step 4)
    outlier_mask: jax.Array   # elementwise bool (True -> MRAM/outlier)
    scale_in: jax.Array       # per-channel inlier scale
    scale_out: jax.Array      # per-channel outlier scale
    codes_in: jax.Array       # inlier codes (zeros at outlier slots)
    codes_out: jax.Array      # outlier codes (zeros at inlier slots)


def _elementwise_mask(w: jax.Array, cfg: QMCConfig) -> jax.Array:
    if cfg.granularity == "subtile" and w.ndim == 2 \
            and w.shape[0] % cfg.subtile[0] == 0 \
            and w.shape[1] % cfg.subtile[1] == 0:
        sub = part.subtile_outlier_mask(w, cfg.rho, cfg.subtile)
        return part.expand_subtile_mask(sub, w.shape, cfg.subtile)
    if cfg.granularity in ("scalar", "subtile"):
        # subtile granularity degrades to scalar on non-tileable shapes
        return part.scalar_outlier_mask(w, cfg.rho)
    raise ValueError(cfg.granularity)


def qmc_quantize(w: jax.Array, cfg: QMCConfig,
                 noise_aware: bool = True) -> QMCResult:
    """Run Algorithm 1 on one weight tensor. Works on any >=1-D tensor;

    per-channel axis is cfg.channel_axis (last axis by default)."""
    w = w.astype(jnp.float32)
    mask = _elementwise_mask(w, cfg)

    noise = cfg.noise if noise_aware else None
    scale_in = noise_aware_scale_search(
        w, cfg.bits_in, noise, channel_axis=cfg.channel_axis,
        grid_lo=cfg.scale_grid_lo, grid_hi=cfg.scale_grid_hi,
        grid_n=cfg.scale_grid_n, mask=~mask)
    scale_out = mse_scale_search(
        w, cfg.bits_out, channel_axis=cfg.channel_axis,
        grid_lo=cfg.scale_grid_lo, grid_hi=cfg.scale_grid_hi,
        grid_n=cfg.scale_grid_n, mask=mask)

    codes_in = jnp.where(mask, 0.0, quantize_codes(w, scale_in, cfg.bits_in))
    codes_out = jnp.where(mask, quantize_codes(w, scale_out, cfg.bits_out),
                          0.0)
    w_hat = jnp.where(mask, dequantize(codes_out, scale_out),
                      dequantize(codes_in, scale_in))
    return QMCResult(w_hat=w_hat, outlier_mask=mask, scale_in=scale_in,
                     scale_out=scale_out, codes_in=codes_in,
                     codes_out=codes_out)


def apply_reram_noise(key: jax.Array, res: QMCResult, cfg: QMCConfig
                      ) -> jax.Array:
    """Simulate deployment: inlier codes sit in noisy MLC ReRAM; outliers sit

    in (noise-free) MRAM. Returns the noisy merged weights."""
    noisy_in = perturb_codes(key, res.codes_in, cfg.bits_in, cfg.noise)
    w_in = dequantize(noisy_in, res.scale_in)
    w_out = dequantize(res.codes_out, res.scale_out)
    return jnp.where(res.outlier_mask, w_out, w_in)


def qmc_fake_quant(w: jax.Array, cfg: QMCConfig,
                   noise_key: Optional[jax.Array] = None,
                   noise_aware: bool = True) -> jax.Array:
    """One-call fake-quant: Algorithm 1, optionally followed by simulated

    ReRAM read noise (noise_key != None)."""
    res = qmc_quantize(w, cfg, noise_aware=noise_aware)
    if noise_key is None:
        return res.w_hat.astype(w.dtype)
    return apply_reram_noise(noise_key, res, cfg).astype(w.dtype)


def quantization_mse(w: jax.Array, w_hat: jax.Array) -> jax.Array:
    return jnp.mean(jnp.square(w.astype(jnp.float32)
                               - w_hat.astype(jnp.float32)))
