"""Outlier/inlier weight partitioning (paper Eq. 1).

Two granularities:

* scalar  — paper-faithful: tau is the (1-rho) quantile of |W| per tensor;
            W_out = {w : |w| > tau}. Exactly Algorithm 1 Step 1.
* subtile — TPU-native restructuring (see DESIGN.md §2): the tensor is tiled
            into (8, 128) VREG granules; the rho fraction of subtiles with the
            largest max-|w| become the outlier stream. Selection remains
            magnitude-based and data-free, but streams stay dense and regular
            so a Pallas kernel can fetch/merge them like the paper's Model
            Weight Controller merges MRAM and ReRAM streams.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def scalar_outlier_mask(w: jax.Array, rho: float) -> jax.Array:
    """Boolean mask of the top-rho fraction of |w| (per tensor)."""
    if rho <= 0.0:
        return jnp.zeros(w.shape, dtype=bool)
    if rho >= 1.0:
        return jnp.ones(w.shape, dtype=bool)
    tau = jnp.quantile(jnp.abs(w).astype(jnp.float32), 1.0 - rho)
    return jnp.abs(w) > tau


def _subtile_grid(shape: Tuple[int, int], subtile: Tuple[int, int]
                  ) -> Tuple[int, int]:
    r, c = subtile
    if shape[0] % r or shape[1] % c:
        raise ValueError(f"shape {shape} not divisible by subtile {subtile}")
    return shape[0] // r, shape[1] // c


def subtile_scores(w: jax.Array, subtile: Tuple[int, int] = (8, 128)
                   ) -> jax.Array:
    """max |w| per (8,128) subtile -> [gr, gc]."""
    gr, gc = _subtile_grid(w.shape, subtile)
    r, c = subtile
    tiles = w.reshape(gr, r, gc, c)
    return jnp.max(jnp.abs(tiles), axis=(1, 3))


def subtile_outlier_mask(w: jax.Array, rho: float,
                         subtile: Tuple[int, int] = (8, 128)) -> jax.Array:
    """[gr, gc] bool mask with exactly round(rho * n_sub) outlier subtiles."""
    scores = subtile_scores(w, subtile)
    n_sub = scores.size
    k = int(round(rho * n_sub))
    if k <= 0:
        return jnp.zeros(scores.shape, dtype=bool)
    if k >= n_sub:
        return jnp.ones(scores.shape, dtype=bool)
    flat = scores.reshape(-1)
    thresh = jnp.sort(flat)[n_sub - k]  # k-th largest
    mask = flat >= thresh
    # Tie-break to exactly k: keep the first k True positions.
    cum = jnp.cumsum(mask.astype(jnp.int32))
    mask = mask & (cum <= k)
    return mask.reshape(scores.shape)


def expand_subtile_mask(mask: jax.Array, shape: Tuple[int, int],
                        subtile: Tuple[int, int] = (8, 128)) -> jax.Array:
    """Broadcast a [gr, gc] subtile mask to elementwise shape."""
    r, c = subtile
    gr, gc = mask.shape
    assert (gr * r, gc * c) == tuple(shape)
    return jnp.repeat(jnp.repeat(mask, r, axis=0), c, axis=1)


def partition(w: jax.Array, rho: float, granularity: str = "scalar",
              subtile: Tuple[int, int] = (8, 128)
              ) -> Tuple[jax.Array, jax.Array]:
    """Return (w_in, w_out) with zeros at the other set's positions.

    The pair satisfies w == w_in + w_out exactly, which is the scatter/merge
    identity used in Algorithm 1 Step 4.
    """
    if granularity == "scalar":
        m = scalar_outlier_mask(w, rho)
    elif granularity == "subtile":
        m = expand_subtile_mask(subtile_outlier_mask(w, rho, subtile),
                                w.shape, subtile)
    else:
        raise ValueError(f"unknown granularity: {granularity}")
    zero = jnp.zeros_like(w)
    return jnp.where(m, zero, w), jnp.where(m, w, zero)
