"""Uniform affine quantizers and scale optimization.

All quantizers here are symmetric, per-channel, and functional. Codes are
kept in float32/int8 depending on context; dequantization is `codes * scale`.

The noise-aware scale search implements Eq. (5)-(7) of the paper: the
expected distortion of storing Q(W; s) in a noisy MLC memory is

    L(s) ~= ||W - Q(W; s)||^2 + N * (p_- + p_+) * Delta(s)^2

with Delta(s) = s for a uniform quantizer. We minimize L over a grid of
candidate scales per channel.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.qconfig import NoiseModel


def qrange(bits: int) -> Tuple[int, int]:
    """Symmetric signed range for `bits` (e.g. 3 -> [-4, 3])."""
    return -(2 ** (bits - 1)), 2 ** (bits - 1) - 1


def quantize_codes(w: jax.Array, scale: jax.Array, bits: int) -> jax.Array:
    """Round-to-nearest codes, clipped to the signed range. Float carrier."""
    qmin, qmax = qrange(bits)
    s = jnp.where(scale > 0, scale, 1.0)
    return jnp.clip(jnp.round(w / s), qmin, qmax)


def dequantize(codes: jax.Array, scale: jax.Array) -> jax.Array:
    return codes.astype(scale.dtype) * scale


def fake_quant(w: jax.Array, scale: jax.Array, bits: int) -> jax.Array:
    return dequantize(quantize_codes(w, scale, bits), scale)


def _move_channel_last(w: jax.Array, channel_axis: int) -> jax.Array:
    if channel_axis in (-1, w.ndim - 1):
        return w
    return jnp.moveaxis(w, channel_axis, -1)


def minmax_scale(w: jax.Array, bits: int, channel_axis: int = -1,
                 eps: float = 1e-8) -> jax.Array:
    """Per-channel abs-max scale. Returns shape broadcastable against w."""
    qmin, qmax = qrange(bits)
    red = tuple(a for a in range(w.ndim) if a != channel_axis % w.ndim)
    amax = jnp.max(jnp.abs(w), axis=red, keepdims=True)
    return jnp.maximum(amax, eps) / float(qmax)


def _grid(lo: float, hi: float, n: int) -> jnp.ndarray:
    return jnp.linspace(lo, hi, n)


def mse_scale_search(w: jax.Array, bits: int, channel_axis: int = -1,
                     grid_lo: float = 0.3, grid_hi: float = 1.05,
                     grid_n: int = 48,
                     mask: Optional[jax.Array] = None) -> jax.Array:
    """Per-channel grid search minimizing ||W - Q(W;s)||^2 (Alg. 1, Step 3).

    `mask` (same shape as w, bool) restricts the objective to a subset of
    entries (used so inlier/outlier scale searches only see their own set).
    """
    return noise_aware_scale_search(
        w, bits, noise=None, channel_axis=channel_axis,
        grid_lo=grid_lo, grid_hi=grid_hi, grid_n=grid_n, mask=mask)


def noise_aware_scale_search(
        w: jax.Array, bits: int, noise: Optional[NoiseModel],
        channel_axis: int = -1, grid_lo: float = 0.3, grid_hi: float = 1.05,
        grid_n: int = 48, mask: Optional[jax.Array] = None) -> jax.Array:
    """Per-channel grid search minimizing Eq. (7).

    With `noise=None` this degrades to the plain MSE objective (Step 3);
    otherwise the per-channel inlier count times (p-+p+) * s^2 penalizes
    large steps (Step 2). Runs as a fori-loop over grid points so peak
    memory stays O(|W|) instead of O(|W| * grid_n).
    """
    ch = channel_axis % w.ndim
    red = tuple(a for a in range(w.ndim) if a != ch)
    base = minmax_scale(w, bits, channel_axis=ch)
    if mask is None:
        n_per_ch = jnp.array(float(w.size) / w.shape[ch])
        wm = w
    else:
        mask = mask.astype(w.dtype)
        n_per_ch = jnp.sum(mask, axis=red, keepdims=True)
        wm = w * mask  # zeros contribute 0 to masked objective below

    p_flip = 0.0 if noise is None else float(noise.p_flip)
    alphas = _grid(grid_lo, grid_hi, grid_n)

    def objective(alpha):
        s = base * alpha
        deq = fake_quant(w, s, bits)
        err = (w - deq) if mask is None else (w - deq) * mask
        dist = jnp.sum(jnp.square(err), axis=red, keepdims=True)
        return dist + n_per_ch * p_flip * jnp.square(s)

    def body(i, carry):
        best_loss, best_alpha = carry
        loss = objective(alphas[i])
        take = loss < best_loss
        return (jnp.where(take, loss, best_loss),
                jnp.where(take, alphas[i], best_alpha))

    init = (jnp.full_like(base, jnp.inf), jnp.ones_like(base))
    _, best_alpha = jax.lax.fori_loop(0, grid_n, body, init)
    del wm
    return base * best_alpha


def rtn_quantize(w: jax.Array, bits: int = 4, channel_axis: int = -1
                 ) -> jax.Array:
    """Rounding-to-nearest baseline: per-channel abs-max scale, fake-quant."""
    s = minmax_scale(w, bits, channel_axis=channel_axis)
    return fake_quant(w, s, bits)


def expected_noise_mse(w: jax.Array, scale: jax.Array, bits: int,
                       noise: NoiseModel) -> jax.Array:
    """Closed-form E_e ||W - (Q(W;s)+e)||^2 under the +-1-step flip model."""
    deq = fake_quant(w, scale, bits)
    dist = jnp.sum(jnp.square(w - deq))
    step2 = jnp.sum(jnp.broadcast_to(jnp.square(scale), w.shape)) * noise.p_flip
    return dist + step2
