"""GPTQ baseline (Frantar et al., 2022) — calibration-based PTQ.

Hessian-guided column-wise rounding with block error propagation, via the
Cholesky-of-inverse formulation. Runs eagerly in float64 numpy at PTQ time
(this is an offline procedure; stability > speed here).

Weights use our [din, dout] convention; per-output-channel symmetric scales.
Calibration inputs X are the captured layer inputs, shape [n_samples, din].
"""
from __future__ import annotations

import numpy as np

from repro.core.qconfig import GPTQConfig
from repro.core.quantizers import qrange


def gptq_quantize(w, x, cfg: GPTQConfig = GPTQConfig()):
    """Return fake-quantized weights (same shape/dtype as w)."""
    w_np = np.asarray(w, dtype=np.float64)          # [din, dout]
    x_np = np.asarray(x, dtype=np.float64).reshape(-1, w_np.shape[0])
    din, dout = w_np.shape
    qmin, qmax = qrange(cfg.bits)

    # Hessian of the layerwise objective ||XW - XW_q||^2
    h = 2.0 * (x_np.T @ x_np)
    dead = np.diag(h) == 0
    h[dead, dead] = 1.0
    w_work = w_np.T.copy()                          # [dout, din] rows=out ch
    w_work[:, dead] = 0.0

    damp = cfg.percdamp * np.mean(np.diag(h))
    h[np.arange(din), np.arange(din)] += damp

    # Cholesky of the inverse Hessian (upper-triangular), GPTQ's trick.
    hinv = np.linalg.inv(h)
    hinv = np.linalg.cholesky((hinv + hinv.T) / 2.0).T  # upper

    # per-output-channel abs-max scales from the *original* weights
    scale = np.maximum(np.abs(w_work).max(axis=1, keepdims=True), 1e-8) / qmax

    q_out = np.zeros_like(w_work)
    bs = cfg.block_size
    for b0 in range(0, din, bs):
        b1 = min(b0 + bs, din)
        w_blk = w_work[:, b0:b1].copy()
        err_blk = np.zeros_like(w_blk)
        for j in range(b1 - b0):
            col = w_blk[:, j]
            q = np.clip(np.round(col / scale[:, 0]), qmin, qmax)
            dq = q * scale[:, 0]
            q_out[:, b0 + j] = dq
            d = hinv[b0 + j, b0 + j]
            err = (col - dq) / d
            # propagate within the block
            if j + 1 < b1 - b0:
                w_blk[:, j + 1:] -= np.outer(err,
                                             hinv[b0 + j, b0 + j + 1:b1])
            err_blk[:, j] = err
        # propagate to the remaining columns
        if b1 < din:
            w_work[:, b1:] -= err_blk @ hinv[b0:b1, b1:]

    return q_out.T.astype(np.asarray(w).dtype)      # back to [din, dout]
