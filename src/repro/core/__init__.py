"""QMC core: outlier-aware robust quantization (paper's primary contribution)."""
from repro.core.qconfig import (AWQConfig, GPTQConfig, MXConfig, NoiseModel,
                                QMCConfig, RTNConfig)
from repro.core.qmc import (QMCResult, apply_reram_noise, qmc_fake_quant,
                            qmc_quantize, quantization_mse)
from repro.core.qtensor import (QTensor, dequantize_qtensor, qmatmul_ref,
                                quantize_qtensor)
from repro.core.apply import model_bits_per_weight, quantize_model

__all__ = [
    "AWQConfig", "GPTQConfig", "MXConfig", "NoiseModel", "QMCConfig",
    "RTNConfig", "QMCResult", "apply_reram_noise", "qmc_fake_quant",
    "qmc_quantize", "quantization_mse", "QTensor", "dequantize_qtensor",
    "qmatmul_ref", "quantize_qtensor", "model_bits_per_weight",
    "quantize_model",
]
