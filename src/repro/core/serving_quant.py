"""Model-level QMC serving-format conversion (concrete AND abstract).

`quantize_for_serving(params, ...)` converts eligible weight leaves of a
model pytree into the deployment format:

  * stacked 2-D projections  [G, din, dout]  -> ShardedQTensor per group,
    fields stacked over G (TP-shard streams, shard_map matmul);
  * MoE expert tensors       [G, E, d, ff]   -> QTensor per (G, E), fields
    stacked (dequant-on-the-fly grouped einsum, streams sharded over E);
  * everything else (norms, embeddings, small/non-tileable leaves) stays
    dense.

`serving_params_struct(...)` builds the same pytree out of
ShapeDtypeStructs without allocating — the multi-pod dry-run lowers against
this (the 314B/398B models never exist on the CPU host).

`build_exec_weights(params)` is the serving **weight execution plan**: a
one-time, per-process lowering of the stream-format leaves into whatever
the executing backend multiplies fastest. The QMC streams are the
*storage and transport* format — they are what the memsys DSE charges
bytes/energy for, and on TPU the stream-direct Pallas kernels
(``kernels/qmm.py``) consume them as-is. XLA backends without a fused
dequant-matmul (the CPU serving bench) would otherwise re-materialize
the dense working set inside every step call; the plan does that
re-materialization exactly once at engine setup instead (the same
load-time repack idiom llama.cpp/ExecuTorch use for formats their
matmul kernels cannot consume directly), so the per-call serving graph
degenerates to a dense matmul. ``ServeEngine`` builds it lazily and
keeps the stream-format tree as the source of truth for cost
attribution (``obs/costs.py`` models bytes/token from the streams).
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.apply import is_quantizable, path_str
from repro.core.qconfig import QMCConfig
from repro.core.qtensor import (QTensor, inlier_container_dtype,
                                quantize_qtensor)
from repro.core.qtensor_sharded import (ShardedQTensor,
                                        quantize_qtensor_sharded)

ROW_SHARDED = ("wo", "w_down", "out_proj")   # TP shards the input dim


def _shard_axis_for(path: str) -> int:
    name = path.split("/")[-1]
    return 0 if name in ROW_SHARDED else 1


def _tileable(din: int, dout: int, cfg: QMCConfig, shards: int,
              shard_axis: int) -> bool:
    r, c = cfg.subtile
    d0, d1 = din, dout
    if shard_axis == 0:
        d0 = din // shards if din % shards == 0 else 0
    else:
        d1 = dout // shards if dout % shards == 0 else 0
    return d0 >= r and d1 >= c and d0 % r == 0 and d1 % c == 0


def stream_sizes(din: int, dout: int, cfg: QMCConfig):
    r, c = cfg.subtile
    gr, gc = din // r, dout // c
    n_sub = gr * gc
    k_out = int(round(cfg.rho * n_sub))
    k_in = n_sub - k_out
    return gr, gc, max(k_in, 1), max(k_out, 1)


def qtensor_struct(din: int, dout: int, cfg: QMCConfig,
                   use_int4: bool = True) -> QTensor:
    """Abstract QTensor (ShapeDtypeStruct fields) for the dry-run."""
    r, c = cfg.subtile
    gr, gc, k_in, k_out = stream_sizes(din, dout, cfg)
    sds = jax.ShapeDtypeStruct
    idt = inlier_container_dtype() if use_int4 else jnp.int8
    return QTensor(
        in_codes=sds((k_in, r, c), idt),
        out_codes=sds((k_out, r, c), jnp.int8),
        stream_pos=sds((gr, gc), jnp.int32),
        is_out=sds((gr, gc), jnp.bool_),
        scale_in=sds((1, dout), jnp.float32),
        scale_out=sds((1, dout), jnp.float32),
        shape=(din, dout), bits_in=cfg.bits_in, bits_out=cfg.bits_out,
        subtile=(r, c))


def sharded_qtensor_struct(din: int, dout: int, cfg: QMCConfig, shards: int,
                           shard_axis: int,
                           use_int4: bool = True) -> ShardedQTensor:
    ldin = din // shards if shard_axis == 0 else din
    ldout = dout // shards if shard_axis == 1 else dout
    base = qtensor_struct(ldin, ldout, cfg, use_int4)
    sds = jax.ShapeDtypeStruct

    def stk(f):
        return sds((shards,) + f.shape, f.dtype)
    return ShardedQTensor(
        in_codes=stk(base.in_codes), out_codes=stk(base.out_codes),
        stream_pos=stk(base.stream_pos), is_out=stk(base.is_out),
        scale_in=stk(base.scale_in), scale_out=stk(base.scale_out),
        shape=(din, dout), bits_in=cfg.bits_in, bits_out=cfg.bits_out,
        subtile=cfg.subtile, shard_axis=shard_axis, n_shards=shards)


def _stack_pytrees(items):
    return jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *items)


def _stack_structs(items):
    def stk(*ls):
        f = ls[0]
        return jax.ShapeDtypeStruct((len(ls),) + f.shape, f.dtype)
    return jax.tree_util.tree_map(stk, *items)


def _convert_leaf(path: str, leaf, cfg: QMCConfig, shards: int,
                  abstract: bool, use_int4: bool) -> Any:
    """leaf: array or ShapeDtypeStruct. Returns converted leaf (or input)."""
    shape = leaf.shape
    is_moe = len(shape) == 4
    sa = _shard_axis_for(path)

    if is_moe:                           # [G, E, d, ff] -> QTensor stacks
        g, e, din, dout = shape
        if not _tileable(din, dout, cfg, 1, 1):
            return leaf
        if abstract:
            base = qtensor_struct(din, dout, cfg, use_int4)
            return jax.tree_util.tree_map(
                lambda f: jax.ShapeDtypeStruct((g, e) + f.shape, f.dtype),
                base)
        per_g = []
        for gi in range(g):
            per_e = [quantize_qtensor(leaf[gi, ei], cfg, use_int4)
                     for ei in range(e)]
            per_g.append(_stack_pytrees(per_e))
        return _stack_pytrees(per_g)

    if len(shape) == 3:                  # [G, din, dout] -> ShardedQTensor
        g, din, dout = shape
        eff_shards = shards if _tileable(din, dout, cfg, shards, sa) else 1
        if not _tileable(din, dout, cfg, eff_shards, sa):
            return leaf
        if abstract:
            base = sharded_qtensor_struct(din, dout, cfg, eff_shards, sa,
                                          use_int4)
            return jax.tree_util.tree_map(
                lambda f: jax.ShapeDtypeStruct((g,) + f.shape, f.dtype),
                base)
        per_g = [quantize_qtensor_sharded(leaf[gi], cfg, eff_shards, sa,
                                          use_int4) for gi in range(g)]
        return _stack_pytrees(per_g)

    if len(shape) == 2:                  # unstacked projection
        din, dout = shape
        eff_shards = shards if _tileable(din, dout, cfg, shards, sa) else 1
        if not _tileable(din, dout, cfg, eff_shards, sa):
            return leaf
        if abstract:
            return sharded_qtensor_struct(din, dout, cfg, eff_shards, sa,
                                          use_int4)
        return quantize_qtensor_sharded(leaf, cfg, eff_shards, sa, use_int4)
    return leaf


def quantize_for_serving(params, qmc: QMCConfig, tp_shards: int = 1,
                         use_int4: bool = True, min_dim: int = 128):
    """Concrete conversion (small models, tests, examples)."""
    return _walk(params, qmc, tp_shards, abstract=False, use_int4=use_int4,
                 min_dim=min_dim)


def serving_params_struct(params_struct, qmc: QMCConfig, tp_shards: int = 1,
                          use_int4: bool = True, min_dim: int = 128):
    """Abstract conversion (dry-run): params_struct holds ShapeDtypeStructs."""
    return _walk(params_struct, qmc, tp_shards, abstract=True,
                 use_int4=use_int4, min_dim=min_dim)


def build_exec_weights(params, dtype=jnp.float32):
    """Lower a serving-format pytree to its execution form (see module
    docstring): every QTensor / ShardedQTensor leaf dequantizes to a
    dense ``dtype`` array of its logical shape (stacked leaves via vmap
    over the extra leading dims); everything else passes through.
    Returns ``params`` unchanged (same object) when no stream leaves are
    present, so dense engines pay nothing."""
    from repro.core.qtensor import dequantize_qtensor
    from repro.core.qtensor_sharded import dequantize_sharded

    def is_q(x):
        return isinstance(x, (QTensor, ShardedQTensor))

    if not any(is_q(l) for l in
               jax.tree_util.tree_leaves(params, is_leaf=is_q)):
        return params

    def lower(leaf):
        if isinstance(leaf, ShardedQTensor):
            fn = lambda q: dequantize_sharded(q, dtype)  # noqa: E731
            extra = leaf.in_codes.ndim - 4   # [shards, k, r, c] is rank 4
        elif isinstance(leaf, QTensor):
            fn = lambda q: dequantize_qtensor(q, dtype)  # noqa: E731
            extra = leaf.in_codes.ndim - 3   # [k, r, c] is rank 3
        else:
            return leaf
        for _ in range(extra):               # stacked [G]/[G, E] leaves
            fn = jax.vmap(fn)
        return fn(leaf)

    return jax.tree_util.tree_map(lower, params, is_leaf=is_q)


def _walk(params, qmc, tp_shards, abstract, use_int4, min_dim):
    from repro.core.apply import EXCLUDE_SUBSTRINGS
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        p = path_str(path)
        shape_ok = (hasattr(leaf, "shape") and 2 <= len(leaf.shape) <= 4
                    and min(leaf.shape[-2:]) >= min_dim)
        name_ok = not any(s in p.lower() for s in EXCLUDE_SUBSTRINGS)
        dt = getattr(leaf, "dtype", None)
        dtype_ok = dt in (jnp.float32, jnp.bfloat16, jnp.float16)
        if shape_ok and name_ok and dtype_ok:
            out.append(_convert_leaf(p, leaf, qmc, tp_shards, abstract,
                                     use_int4))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)
