"""ShardedQTensor — QMC deployment format for tensor-parallel serving.

Production PTQ quantizes each weight *shard* independently (quantize-after-
shard), so every device holds the compact streams of its own TP slice and the
qmm kernel runs fully locally; column-sharded weights concat outputs, row-
sharded weights psum partials. All fields carry a leading TP-shard dim and
are sharded P("model", ...) — see launch/sharding.py.

Stream sizes are equal across shards because the subtile top-rho rule picks
exactly round(rho * n_sub_shard) outlier subtiles per shard.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.qconfig import QMCConfig
from repro.core.qtensor import QTensor, dequantize_qtensor, quantize_qtensor


@partial(jax.tree_util.register_dataclass,
         data_fields=["in_codes", "out_codes", "stream_pos", "is_out",
                      "scale_in", "scale_out"],
         meta_fields=["shape", "bits_in", "bits_out", "subtile",
                      "shard_axis", "n_shards"])
@dataclasses.dataclass
class ShardedQTensor:
    in_codes: jax.Array      # [S, n_in, 8, 128]
    out_codes: jax.Array     # [S, n_out, 8, 128]
    stream_pos: jax.Array    # [S, gr, gc]
    is_out: jax.Array        # [S, gr, gc]
    scale_in: jax.Array      # [S, 1, dout_shard]
    scale_out: jax.Array     # [S, 1, dout_shard]
    shape: Tuple[int, int]   # full (unsharded) weight shape
    bits_in: int
    bits_out: int
    subtile: Tuple[int, int]
    shard_axis: int          # 0 = row-sharded (input dim), 1 = column
    n_shards: int

    @property
    def ndim(self):
        return 2

    def local(self, i: int) -> QTensor:
        shard_shape = list(self.shape)
        shard_shape[self.shard_axis] //= self.n_shards
        return QTensor(self.in_codes[i], self.out_codes[i],
                       self.stream_pos[i], self.is_out[i],
                       self.scale_in[i], self.scale_out[i],
                       tuple(shard_shape), self.bits_in, self.bits_out,
                       self.subtile)


def quantize_qtensor_sharded(w: jax.Array, cfg: QMCConfig, n_shards: int,
                             shard_axis: int = 1,
                             use_int4: bool = True) -> ShardedQTensor:
    """Quantize each TP shard of W independently and stack the streams."""
    assert w.ndim == 2
    assert w.shape[shard_axis] % n_shards == 0
    shards = jnp.split(w, n_shards, axis=shard_axis)
    qts = [quantize_qtensor(s, cfg, use_int4=use_int4) for s in shards]
    sizes = {(q.in_codes.shape[0], q.out_codes.shape[0]) for q in qts}
    assert len(sizes) == 1, "per-shard stream sizes must match"
    stack = lambda f: jnp.stack([getattr(q, f) for q in qts])  # noqa: E731
    return ShardedQTensor(
        in_codes=stack("in_codes"), out_codes=stack("out_codes"),
        stream_pos=stack("stream_pos"), is_out=stack("is_out"),
        scale_in=stack("scale_in"), scale_out=stack("scale_out"),
        shape=tuple(w.shape), bits_in=cfg.bits_in, bits_out=cfg.bits_out,
        subtile=cfg.subtile, shard_axis=shard_axis, n_shards=n_shards)


def dequantize_sharded(sqt: ShardedQTensor, dtype=jnp.bfloat16) -> jax.Array:
    parts = [dequantize_qtensor(sqt.local(i), dtype)
             for i in range(sqt.n_shards)]
    return jnp.concatenate(parts, axis=sqt.shard_axis)


def qmm_sharded_ref(x: jax.Array, sqt: ShardedQTensor,
                    dtype=None) -> jax.Array:
    """Oracle: x [..., K] @ dequant(sqt) [K, N]."""
    w = dequantize_sharded(sqt, dtype or x.dtype)
    return jnp.matmul(x, w)


def qmm_shard_map(x: jax.Array, sqt: ShardedQTensor, mesh,
                  axis: str = "model",
                  dp: Tuple[str, ...] = (),
                  use_pallas: bool = False) -> jax.Array:
    """TP-local quantized matmul under shard_map.

    Column-sharded (shard_axis=1): every device computes its N/S output
    columns from its batch slice of x. Row-sharded (shard_axis=0): devices
    hold K/S input rows; x arrives sharded on its last dim; partials psum.
    Batch rows ride the dp axes untouched. The shard-local matmul goes
    through kernels.ops.qmm, so the block_m plan (decode-width vs
    column-strip, skinny-XLA vs ref) is picked per compiled step width
    exactly as on the single-device path.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    from repro.kernels import ops as kops

    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    bspec = tuple(dp) if dp else None
    if bspec is not None:
        names = list(mesh.axis_names)
        dp_n = 1
        for a in bspec:
            dp_n *= mesh.devices.shape[names.index(a)]
        if x2.shape[0] % dp_n:
            bspec = None        # e.g. batch-1 long-context decode
    qt_specs = ShardedQTensor(
        in_codes=P(axis), out_codes=P(axis), stream_pos=P(axis),
        is_out=P(axis), scale_in=P(axis), scale_out=P(axis),
        shape=sqt.shape, bits_in=sqt.bits_in, bits_out=sqt.bits_out,
        subtile=sqt.subtile, shard_axis=sqt.shard_axis,
        n_shards=sqt.n_shards)

    if sqt.shard_axis == 1:
        def body(xl, q):
            return kops.qmm(xl, q.local(0), use_pallas=use_pallas)
        y = shard_map(body, mesh=mesh,
                      in_specs=(P(bspec, None), qt_specs),
                      out_specs=P(bspec, axis))(x2, sqt)
    else:
        def body(xl, q):
            yl = kops.qmm(xl, q.local(0), use_pallas=use_pallas)
            return jax.lax.psum(yl, axis)
        y = shard_map(body, mesh=mesh,
                      in_specs=(P(bspec, axis), qt_specs),
                      out_specs=P(bspec, None))(x2, sqt)
    return y.reshape(*lead, sqt.shape[1])
