"""Quantization configuration dataclasses for QMC and baselines.

Everything here is a plain dataclass so configs hash/compare cleanly and can
be used as static arguments to jitted functions.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class NoiseModel:
    """Discrete MLC-ReRAM perturbation model (paper §3.4).

    A stored code flips by ±1 step with probabilities (p_minus, p_plus)
    determined by the device BER of the chosen MLC mode. The magnitudes
    below are derived from the confusion matrices of fabricated 40nm MLC
    ReRAM (paper Fig. 2 / [40]): 3-bit cells have tighter level spacing and
    therefore a substantially higher adjacent-state error rate than 2-bit
    cells.
    """

    cell_bits: int = 3            # MLC mode: 3-bit or 2-bit cells
    p_minus: float = 0.015        # P(code -> code-1)
    p_plus: float = 0.015         # P(code -> code+1)

    @property
    def p_flip(self) -> float:
        return self.p_minus + self.p_plus

    @staticmethod
    def for_mode(cell_bits: int) -> "NoiseModel":
        if cell_bits == 3:
            # 8 levels in the same conductance window: wide overlap tails.
            return NoiseModel(cell_bits=3, p_minus=0.015, p_plus=0.015)
        if cell_bits == 2:
            # 4 well-separated levels: ~an order of magnitude fewer errors.
            return NoiseModel(cell_bits=2, p_minus=0.002, p_plus=0.002)
        raise ValueError(f"unsupported MLC mode: {cell_bits}-bit cells")


@dataclasses.dataclass(frozen=True)
class QMCConfig:
    """Configuration for Algorithm 1 (Outlier-Aware Robust Quantization)."""

    rho: float = 0.3              # outlier ratio (fraction of |W| kept high-prec)
    bits_in: int = 3              # logical bits for ReRAM-resident inliers
    bits_out: int = 5             # logical bits for MRAM-resident outliers
    cell_bits: int = 3            # MLC mode (noise model + capacity accounting)
    granularity: str = "scalar"   # "scalar" (paper-faithful) | "subtile" (TPU)
    subtile: tuple = (8, 128)     # TPU VREG granule for structured variant
    # Scale search: candidates are alpha * s_minmax for alpha on this grid.
    scale_grid_lo: float = 0.30
    scale_grid_hi: float = 1.05
    scale_grid_n: int = 48
    channel_axis: int = -1        # per-channel axis (output channels)

    @property
    def noise(self) -> NoiseModel:
        return NoiseModel.for_mode(self.cell_bits)

    @property
    def avg_bits(self) -> float:
        """Logical bits/weight (memory-cell accounting, paper's 4.44x)."""
        return (1.0 - self.rho) * self.bits_in + self.rho * self.bits_out

    @property
    def compression_vs_fp16(self) -> float:
        return 16.0 / self.avg_bits


@dataclasses.dataclass(frozen=True)
class RTNConfig:
    bits: int = 4
    channel_axis: int = -1


@dataclasses.dataclass(frozen=True)
class MXConfig:
    """MXINT-style microscaling: shared 8-bit power-of-two exponent per block."""

    bits: int = 4
    block: int = 32
    block_axis: int = 0           # blocks along input-channel axis

    @property
    def avg_bits(self) -> float:
        return self.bits + 8.0 / self.block


@dataclasses.dataclass(frozen=True)
class GPTQConfig:
    bits: int = 4
    block_size: int = 128
    percdamp: float = 0.01
    channel_axis: int = -1


@dataclasses.dataclass(frozen=True)
class AWQConfig:
    bits: int = 4
    n_grid: int = 20              # alpha grid for s = mean|x|^alpha
    channel_axis: int = -1
