"""Model-level quantization: walk a parameter pytree and quantize the

matmul weights with a chosen method. This is the public PTQ entry point:

    qparams = quantize_model(params, method="qmc", qmc=QMCConfig(...))

Methods
-------
fp16        identity (baseline)
rtn4        rounding-to-nearest INT4 (per-out-channel abs-max)
mx4         MXINT4 microscaling
qmc         Algorithm 1, scalar granularity (paper-faithful), fake-quant
qmc_subtile Algorithm 1, (8,128)-subtile granularity (TPU variant), fake-quant
gptq        GPTQ (requires `taps`: captured per-layer inputs)
awq         AWQ (requires `taps`)
qtensor     QMC-TPU deployment format: leaves become QTensor pytrees

Leaf selection: 2-D (or batched 3-D, e.g. MoE experts [E, din, dout]) float
leaves with min(last two dims) >= min_dim, excluding embedding/norm-style
parameters by path name.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.awq import awq_quantize
from repro.core.gptq import gptq_quantize
from repro.core.mx import mx_fake_quant
from repro.core.qconfig import (AWQConfig, GPTQConfig, MXConfig, QMCConfig,
                                RTNConfig)
from repro.core.qmc import qmc_fake_quant
from repro.core.qtensor import quantize_qtensor
from repro.core.quantizers import rtn_quantize

EXCLUDE_SUBSTRINGS = ("embed", "norm", "scale", "bias", "a_log", "dt_bias",
                      "conv", "d_skip", "pos")


def path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def is_quantizable(path: str, leaf: Any, min_dim: int = 64) -> bool:
    if not isinstance(leaf, (jax.Array, np.ndarray)):
        return False
    if leaf.ndim < 2 or leaf.ndim > 4:
        return False
    if leaf.dtype not in (jnp.float32, jnp.bfloat16, jnp.float16):
        return False
    low = path.lower()
    if any(s in low for s in EXCLUDE_SUBSTRINGS):
        return False
    if min(leaf.shape[-2:]) < min_dim:
        return False
    return True


def _batched(fn: Callable, leaf: jax.Array) -> jax.Array:
    """Apply a 2-D quantizer over leading batch dims (stacked layers, MoE)."""
    if leaf.ndim == 2:
        return fn(leaf)
    flat = leaf.reshape((-1,) + leaf.shape[-2:])
    out = jnp.stack([fn(flat[i]) for i in range(flat.shape[0])])
    return out.reshape(leaf.shape)


def quantize_model(params, method: str = "qmc",
                   qmc: QMCConfig = QMCConfig(),
                   rtn: RTNConfig = RTNConfig(),
                   mx: MXConfig = MXConfig(),
                   gptq: GPTQConfig = GPTQConfig(),
                   awq: AWQConfig = AWQConfig(),
                   taps: Optional[Dict[str, Any]] = None,
                   noise_key: Optional[jax.Array] = None,
                   noise_aware: bool = True,
                   min_dim: int = 64,
                   use_int4: bool = True):
    """Quantize every eligible weight in `params`; returns a new pytree."""
    if method == "fp16":
        return params
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    key = noise_key
    for path, leaf in flat:
        p = path_str(path)
        if not is_quantizable(p, leaf, min_dim=min_dim):
            out.append(leaf)
            continue
        if method == "rtn4":
            q = _batched(lambda w: rtn_quantize(w, rtn.bits), leaf)
        elif method == "mx4":
            q = _batched(lambda w: mx_fake_quant(w, mx), leaf)
        elif method in ("qmc", "qmc_subtile"):
            cfg = qmc
            if method == "qmc_subtile" and cfg.granularity != "subtile":
                import dataclasses
                cfg = dataclasses.replace(cfg, granularity="subtile")
            if key is not None:
                key, sub = jax.random.split(key)
            else:
                sub = None
            q = _batched(
                lambda w: qmc_fake_quant(w, cfg, noise_key=sub,
                                         noise_aware=noise_aware), leaf)
        elif method in ("gptq", "awq"):
            fn = gptq_quantize if method == "gptq" else awq_quantize
            fcfg = gptq if method == "gptq" else awq
            # wk/wv share wq's input; w_gate shares w_up's (same tensor
            # feeds them), so alias the tap key when needed
            aliases = {"wk": "wq", "wv": "wq", "w_gate": "w_up"}
            name = p.split("/")[-1]
            p_alias = "/".join(p.split("/")[:-1]
                               + [aliases.get(name, name)])
            if taps is not None and p_alias in taps:    # unstacked leaf
                x = taps[p_alias]
                q = _batched(lambda w: jnp.asarray(fn(w, x, fcfg)), leaf)
            elif taps is not None and leaf.ndim == 3 \
                    and p.startswith("blocks/"):
                # stacked layers: per-group calibration capture under
                # "blocks/{g}/<rest>" (forward(..., scan_layers=False))
                rest = p_alias[len("blocks/"):]
                per_g = []
                for g in range(leaf.shape[0]):
                    key_g = f"blocks/{g}/{rest}"
                    if key_g in taps:
                        per_g.append(jnp.asarray(
                            fn(leaf[g], taps[key_g], fcfg)))
                    else:
                        per_g.append(rtn_quantize(leaf[g], gptq.bits))
                q = jnp.stack(per_g)
            else:
                # no calibration captured for this leaf -> RTN fallback,
                # mirroring how GPTQ/AWQ tooling skips unsupported modules.
                q = _batched(lambda w: rtn_quantize(w, gptq.bits), leaf)
        elif method == "qtensor":
            if leaf.ndim == 2 and leaf.shape[0] % qmc.subtile[0] == 0 \
                    and leaf.shape[1] % qmc.subtile[1] == 0:
                q = quantize_qtensor(leaf, qmc, use_int4=use_int4)
            else:
                out.append(leaf)   # non-tileable leaves stay dense
                continue
        else:
            raise ValueError(f"unknown method {method}")
        if not isinstance(q, (jax.Array, np.ndarray)) or method == "qtensor":
            out.append(q)
        else:
            out.append(q.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def model_bits_per_weight(params, method: str, qmc: QMCConfig = QMCConfig(),
                          mx: MXConfig = MXConfig()) -> float:
    """Average logical bits/weight over quantizable leaves (capacity view)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    n_q = n_total = 0
    for path, leaf in flat:
        if not hasattr(leaf, "size"):
            continue
        n_total += leaf.size
        if is_quantizable(path_str(path), leaf):
            n_q += leaf.size
    if n_total == 0:
        return 16.0
    bits_q = {"fp16": 16.0, "rtn4": 4.0, "gptq": 4.0, "awq": 4.0,
              "mx4": mx.avg_bits, "qmc": qmc.avg_bits,
              "qmc_subtile": qmc.avg_bits, "qtensor": qmc.avg_bits}[method]
    return (n_q * bits_q + (n_total - n_q) * 16.0) / n_total
