"""AWQ baseline (Lin et al., 2024) — activation-aware weight quantization.

Per-input-channel scales s_c = (mean|X_c|)^alpha (normalized), alpha chosen
on a grid to minimize the layer output error ||X W - X (Q(sW)/s)||^2.
Fake-quant equivalence: W_hat = Q(W * s) / s, so no runtime graph rewrite is
needed for accuracy evaluation.
"""
from __future__ import annotations

import numpy as np

from repro.core.qconfig import AWQConfig
from repro.core.quantizers import qrange


def _rtn(w, bits):
    qmin, qmax = qrange(bits)
    scale = np.maximum(np.abs(w).max(axis=0, keepdims=True), 1e-8) / qmax
    return np.clip(np.round(w / scale), qmin, qmax) * scale


def awq_quantize(w, x, cfg: AWQConfig = AWQConfig()):
    """Return fake-quantized weights (same shape/dtype as w).

    w: [din, dout]; x: [n_samples, din] captured calibration inputs.
    """
    w_np = np.asarray(w, dtype=np.float64)
    x_np = np.asarray(x, dtype=np.float64).reshape(-1, w_np.shape[0])

    x_mean = np.abs(x_np).mean(axis=0) + 1e-8       # [din]
    y_ref = x_np @ w_np

    best_err, best_w = np.inf, None
    for g in range(cfg.n_grid):
        alpha = g / cfg.n_grid
        s = np.power(x_mean, alpha)
        s = s / np.sqrt(s.max() * s.min() + 1e-12)  # normalize dynamic range
        s = np.maximum(s, 1e-4)
        w_q = _rtn(w_np * s[:, None], cfg.bits) / s[:, None]
        err = float(np.mean((y_ref - x_np @ w_q) ** 2))
        if err < best_err:
            best_err, best_w = err, w_q
    return best_w.astype(np.asarray(w).dtype)
