"""QTensor — the QMC-TPU deployment format (dual-stream quantized weights).

A weight matrix W[din, dout] is tiled into (8, 128) subtiles. The rho
fraction of subtiles with the largest max-|w| form the *outlier stream*
(5-bit codes in an int8 container); the rest form the *inlier stream*
(3-bit codes in an int4/int8 container, scale chosen noise-aware). A
per-subtile tag + stream position index reconstructs the dense tile — the
role the paper's Model Weight Controller plays when merging MRAM and ReRAM
fetches.

QTensor is a registered JAX pytree: it flows through jit/pjit/shardings and
optimizer-free serving paths like any other parameter leaf.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import partition as part
from repro.core.qconfig import QMCConfig
from repro.core.quantizers import (fake_quant, noise_aware_scale_search,
                                   mse_scale_search, quantize_codes, qrange)

# int4 halves the container footprint when the backend supports it.
_INT4_OK = True
try:  # pragma: no cover - environment probe
    jnp.zeros((8,), dtype=jnp.int4).astype(jnp.float32)
except Exception:  # pragma: no cover
    _INT4_OK = False


def inlier_container_dtype():
    return jnp.int4 if _INT4_OK else jnp.int8


@partial(jax.tree_util.register_dataclass,
         data_fields=["in_codes", "out_codes", "stream_pos", "is_out",
                      "scale_in", "scale_out"],
         meta_fields=["shape", "bits_in", "bits_out", "subtile"])
@dataclasses.dataclass
class QTensor:
    in_codes: jax.Array      # [n_in, 8, 128] int4/int8 container (3-bit codes)
    out_codes: jax.Array     # [n_out, 8, 128] int8 container (5-bit codes)
    stream_pos: jax.Array    # [gr, gc] int32: index into own stream
    is_out: jax.Array        # [gr, gc] bool tag
    scale_in: jax.Array      # [1, dout] f32 per-output-channel inlier scale
    scale_out: jax.Array     # [1, dout] f32 per-output-channel outlier scale
    shape: Tuple[int, int]
    bits_in: int
    bits_out: int
    subtile: Tuple[int, int]

    @property
    def dtype(self):  # logical dtype when dequantized
        return self.scale_in.dtype

    @property
    def ndim(self):
        return len(self.shape)

    def nbytes_packed(self) -> int:
        """Memory-cell accounting (logical bits, no container padding)."""
        n_in = int(np.prod(self.in_codes.shape))
        n_out = int(np.prod(self.out_codes.shape))
        meta = self.is_out.size / 8 + self.stream_pos.size * 4
        scales = (self.scale_in.size + self.scale_out.size) * 4
        return int((n_in * self.bits_in + n_out * self.bits_out) / 8
                   + meta + scales)

    def nbytes_container(self) -> int:
        """What the TPU actually stores (int4/int8 containers + metadata)."""
        in_bits = 4 if self.in_codes.dtype == jnp.int4 else 8
        n_in = int(np.prod(self.in_codes.shape))
        n_out = int(np.prod(self.out_codes.shape))
        meta = self.is_out.size + self.stream_pos.size * 4
        scales = (self.scale_in.size + self.scale_out.size) * 4
        return int(n_in * in_bits / 8 + n_out + meta + scales)


def quantize_qtensor(w: jax.Array, cfg: QMCConfig,
                     use_int4: bool = True) -> QTensor:
    """Build the dual-stream format from a dense weight matrix (PTQ-time)."""
    assert w.ndim == 2, "QTensor holds 2-D weights"
    r, c = cfg.subtile
    din, dout = w.shape
    gr, gc = din // r, dout // c
    n_sub = gr * gc

    sub_mask = part.subtile_outlier_mask(w, cfg.rho, cfg.subtile)  # [gr, gc]
    elem_mask = part.expand_subtile_mask(sub_mask, w.shape, cfg.subtile)

    scale_in = noise_aware_scale_search(
        w, cfg.bits_in, cfg.noise, channel_axis=-1,
        grid_lo=cfg.scale_grid_lo, grid_hi=cfg.scale_grid_hi,
        grid_n=cfg.scale_grid_n, mask=~elem_mask)
    scale_out = mse_scale_search(
        w, cfg.bits_out, channel_axis=-1,
        grid_lo=cfg.scale_grid_lo, grid_hi=cfg.scale_grid_hi,
        grid_n=cfg.scale_grid_n, mask=elem_mask)

    codes_in = quantize_codes(w, scale_in, cfg.bits_in)
    codes_out = quantize_codes(w, scale_out, cfg.bits_out)

    # --- compact streams (static sizes; PTQ runs eagerly) ---------------
    flat_mask = np.asarray(sub_mask).reshape(-1)
    k_out = int(flat_mask.sum())
    k_in = n_sub - k_out
    order = np.arange(n_sub)
    in_ids = order[~flat_mask]
    out_ids = order[flat_mask]

    # subtile view [n_sub, r, c] in grid scan order
    def tiles_of(x):
        return (x.reshape(gr, r, gc, c).transpose(0, 2, 1, 3)
                .reshape(n_sub, r, c))

    t_in = tiles_of(codes_in)[in_ids].astype(
        inlier_container_dtype() if use_int4 else jnp.int8)
    t_out = tiles_of(codes_out)[out_ids].astype(jnp.int8)

    pos = np.zeros(n_sub, np.int32)
    pos[in_ids] = np.arange(k_in, dtype=np.int32)
    pos[out_ids] = np.arange(k_out, dtype=np.int32)

    # guarantee non-empty streams so the pytree keeps static structure
    if k_in == 0:
        t_in = jnp.zeros((1, r, c), t_in.dtype)
    if k_out == 0:
        t_out = jnp.zeros((1, r, c), jnp.int8)

    return QTensor(
        in_codes=t_in, out_codes=t_out,
        stream_pos=jnp.asarray(pos.reshape(gr, gc)),
        is_out=jnp.asarray(flat_mask.reshape(gr, gc)),
        scale_in=scale_in.astype(jnp.float32),
        scale_out=scale_out.astype(jnp.float32),
        shape=(din, dout), bits_in=cfg.bits_in, bits_out=cfg.bits_out,
        subtile=(r, c))


def dequantize_qtensor(qt: QTensor, dtype=jnp.bfloat16) -> jax.Array:
    """Reassemble the dense weight matrix (the jnp oracle for the kernel)."""
    r, c = qt.subtile
    gr, gc = qt.is_out.shape
    din, dout = qt.shape
    pos = qt.stream_pos.reshape(-1)
    tags = qt.is_out.reshape(-1)

    take_in = jnp.take(qt.in_codes, jnp.where(tags, 0, pos), axis=0)
    take_out = jnp.take(qt.out_codes, jnp.where(tags, pos, 0), axis=0)
    tiles = jnp.where(tags[:, None, None],
                      take_out.astype(jnp.float32),
                      take_in.astype(jnp.float32))          # [n_sub, r, c]
    dense = (tiles.reshape(gr, gc, r, c).transpose(0, 2, 1, 3)
             .reshape(din, dout))
    emask = part.expand_subtile_mask(qt.is_out, (din, dout), qt.subtile)
    scale = jnp.where(emask, qt.scale_out, qt.scale_in)
    return (dense * scale).astype(dtype)


def qmatmul_ref(x: jax.Array, qt: QTensor,
                out_dtype=jnp.bfloat16) -> jax.Array:
    """x @ dequant(qt) — reference path used when the Pallas kernel is off."""
    w = dequantize_qtensor(qt, dtype=x.dtype)
    return jnp.matmul(x, w).astype(out_dtype)
