"""Bit-packing of low-bit codes into dense uint8 streams.

This is the capacity-accounting layer: logical 3-bit inliers and 5-bit
outliers are packed with zero padding waste (8 codes x 3 bits = 3 bytes;
8 codes x 5 bits = 5 bytes). The same routines model the paper's
"bit packing/unpacking due to the mismatch between 3-bit weight quantization
and 2-bit cell storage" overhead when cell_bits=2.

Implemented in jnp so the unpack path can serve as the oracle for the
Pallas unpack kernel. Codes are signed; they are biased to unsigned before
packing.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np


def _bias(bits: int) -> int:
    return 2 ** (bits - 1)


def pack_codes(codes, bits: int) -> jnp.ndarray:
    """Pack signed integer codes (any shape) into a flat uint8 stream.

    Layout: little-endian bit order within the concatenated bitstream,
    8/gcd groups at a time. Pure-numpy friendly (used offline at PTQ time).
    """
    flat = np.asarray(codes).reshape(-1).astype(np.int64) + _bias(bits)
    assert flat.min() >= 0 and flat.max() < 2 ** bits, "codes out of range"
    n = flat.size
    total_bits = n * bits
    nbytes = (total_bits + 7) // 8
    # Expand each code into its bits, then pack bits into bytes.
    bit_idx = np.arange(bits)
    bits_arr = ((flat[:, None] >> bit_idx[None, :]) & 1).astype(np.uint8)
    stream = bits_arr.reshape(-1)
    pad = nbytes * 8 - total_bits
    if pad:
        stream = np.concatenate([stream, np.zeros(pad, np.uint8)])
    byts = stream.reshape(nbytes, 8)
    packed = (byts << np.arange(8, dtype=np.uint8)[None, :]).sum(
        axis=1).astype(np.uint8)
    return jnp.asarray(packed)


def unpack_codes(packed, bits: int, n: int, shape: Tuple[int, ...] = None):
    """Inverse of pack_codes: uint8 stream -> signed codes of length n."""
    byts = jnp.asarray(packed, dtype=jnp.uint8)
    bitstream = ((byts[:, None] >> jnp.arange(8, dtype=jnp.uint8)[None, :])
                 & 1)
    bitstream = bitstream.reshape(-1)[: n * bits].reshape(n, bits)
    vals = jnp.sum(bitstream.astype(jnp.int32)
                   << jnp.arange(bits, dtype=jnp.int32)[None, :], axis=1)
    vals = vals - _bias(bits)
    if shape is not None:
        vals = vals.reshape(shape)
    return vals


def packed_nbytes(n_codes: int, bits: int) -> int:
    return (n_codes * bits + 7) // 8


def cells_per_weight(logical_bits: int, cell_bits: int) -> float:
    """MLC cells needed to store one logical weight (paper's 2-bit-mode

    packing mismatch: 3-bit weights in 2-bit cells need 1.5 cells/weight)."""
    return logical_bits / cell_bits
