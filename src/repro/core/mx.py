"""MXINT4 microscaling baseline (Sharify et al., arXiv:2405.07135).

Blocks of `block` consecutive elements along the input-channel axis share an
8-bit power-of-two scale (E8M0); elements are signed INT4. This is the
"hybrid data format" the paper compares against in Table 2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.qconfig import MXConfig
from repro.core.quantizers import qrange


def mx_fake_quant(w: jax.Array, cfg: MXConfig = MXConfig()) -> jax.Array:
    orig_dtype = w.dtype
    w = w.astype(jnp.float32)
    axis = cfg.block_axis % w.ndim
    if w.shape[axis] % cfg.block:
        # pad to a whole number of blocks, quantize, then crop
        pad = cfg.block - w.shape[axis] % cfg.block
        padding = [(0, 0)] * w.ndim
        padding[axis] = (0, pad)
        wq = mx_fake_quant(jnp.pad(w, padding), cfg)
        sl = [slice(None)] * w.ndim
        sl[axis] = slice(0, w.shape[axis])
        return wq[tuple(sl)].astype(orig_dtype)

    w_moved = jnp.moveaxis(w, axis, 0)
    lead = w_moved.shape[0]
    blocked = w_moved.reshape(lead // cfg.block, cfg.block, *w_moved.shape[1:])

    qmin, qmax = qrange(cfg.bits)
    amax = jnp.max(jnp.abs(blocked), axis=1, keepdims=True)
    # E8M0 shared exponent: scale is the power of two s.t. amax/scale <= qmax
    exp = jnp.ceil(jnp.log2(jnp.maximum(amax, 1e-30) / qmax))
    scale = jnp.exp2(exp)
    q = jnp.clip(jnp.round(blocked / scale), qmin, qmax)
    deq = (q * scale).reshape(w_moved.shape)
    return jnp.moveaxis(deq, 0, axis).astype(orig_dtype)
