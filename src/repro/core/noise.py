"""MLC ReRAM device-noise simulation (paper §3.4, Fig. 2).

The paper models read errors of multi-level ReRAM cells as discrete
perturbations on the *stored code*: with probability p_- the read code is one
step below the written one, with p_+ one step above (adjacent-level
confusion), otherwise exact. In weight space the error is
e in {-Delta(s), 0, +Delta(s)}.

We expose:
  * `perturb_codes`      — sample the flip process on integer codes.
  * `perturb_weights`    — apply it to fake-quantized weights given a scale.
  * `confusion_matrix`   — the level-confusion matrix implied by the model
                           (used by tests and the Fig.2-style benchmark).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.qconfig import NoiseModel
from repro.core.quantizers import qrange


def perturb_codes(key: jax.Array, codes: jax.Array, bits: int,
                  noise: NoiseModel) -> jax.Array:
    """Flip each code by -1/+1 with (p_minus, p_plus); clip to code range.

    Clipping mirrors the physical device: the lowest/highest conductance
    states can only be confused inward.
    """
    qmin, qmax = qrange(bits)
    u = jax.random.uniform(key, codes.shape)
    delta = jnp.where(u < noise.p_minus, -1.0,
                      jnp.where(u < noise.p_minus + noise.p_plus, 1.0, 0.0))
    return jnp.clip(codes + delta.astype(codes.dtype), qmin, qmax)


def perturb_weights(key: jax.Array, w_deq: jax.Array, scale: jax.Array,
                    bits: int, noise: NoiseModel) -> jax.Array:
    """Apply the code-flip model to dequantized weights W = codes * scale."""
    s = jnp.where(scale > 0, scale, 1.0)
    codes = jnp.round(w_deq / s)
    noisy = perturb_codes(key, codes, bits, noise)
    return noisy * jnp.broadcast_to(scale, w_deq.shape).astype(w_deq.dtype)


def confusion_matrix(bits: int, noise: NoiseModel) -> jnp.ndarray:
    """Level confusion matrix P(read=j | written=i) for 2**bits states."""
    n = 2 ** bits
    p_m, p_p = noise.p_minus, noise.p_plus
    m = jnp.zeros((n, n))
    idx = jnp.arange(n)
    m = m.at[idx, idx].set(1.0 - p_m - p_p)
    m = m.at[idx[1:], idx[1:] - 1].add(p_m)
    m = m.at[idx[:-1], idx[:-1] + 1].add(p_p)
    # Boundary states fold the outward flip back onto themselves (clipping).
    m = m.at[0, 0].add(p_m)
    m = m.at[n - 1, n - 1].add(p_p)
    return m


def ber_from_confusion(bits: int, noise: NoiseModel) -> float:
    """Aggregate raw bit-error-ish rate: P(read != written), uniform codes."""
    m = confusion_matrix(bits, noise)
    return float(1.0 - jnp.mean(jnp.diag(m)))
