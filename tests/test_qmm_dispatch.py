"""Decode-width qmm dispatch (`kernels.ops.qmm` / `qmm_plan`): the M
fallback fix. Historically any M % 8 != 0 silently fell back to a full
dequant + dense matmul; the plan now pads M to the subtile row count and
routes through the skinny-XLA stream einsum, the decode-width Pallas
kernel, or the column-strip kernel. Differential sweeps vs `qmm_ref`
across skinny M / dtypes / both backends, a hypothesis property that the
internal M padding is bitwise-invisible, and the single-shard
`matmul_any` routing."""
import importlib.util

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.qconfig import QMCConfig
from repro.core.qtensor import quantize_qtensor
from repro.kernels import ops as kops
from repro.kernels.ref import qmm_ref

K, N = 128, 256
CFG_Q = QMCConfig(rho=0.3, granularity="subtile")


def _qt(k=K, n=N, seed=0):
    w = jax.random.t(jax.random.PRNGKey(seed), df=3.0, shape=(k, n))
    return quantize_qtensor(w, CFG_Q)


def _x(m, k=K, dtype=jnp.float32, seed=1):
    return jax.random.normal(jax.random.PRNGKey(seed), (m, k)).astype(dtype)


# ---- plan selection --------------------------------------------------------

def test_qmm_plan_paths():
    st = (8, 128)
    # XLA route: stream einsum only at the narrowest decode widths,
    # ref dequant above (measured crossover, kernels/ops.py)
    assert kops.qmm_plan(1, K, N, st)["path"] == "skinny_xla"
    assert kops.qmm_plan(2, K, N, st)["path"] == "skinny_xla"
    assert kops.qmm_plan(3, K, N, st)["path"] == "ref"
    # Pallas route: decode-width tiling pads M up to the subtile rows;
    # column-strip takes over at M % 128 == 0
    for m in (1, 3, 7, 8, 16):
        p = kops.qmm_plan(m, K, N, st, use_pallas=True)
        assert p["path"] == "decode"
        assert p["pad_m"] % 8 == 0 and p["pad_m"] >= m
    assert kops.qmm_plan(128, K, N, st, use_pallas=True)["path"] == \
        "colstrip"
    # widest N strip that divides N
    assert kops.qmm_plan(1, 128, 512, st, use_pallas=True)["block_n"] == 512
    assert kops.qmm_plan(1, 128, 384, st, use_pallas=True)["block_n"] == 128
    # non-tileable shapes always take the reference path
    assert kops.qmm_plan(8, K, N, (8, 32), use_pallas=True)["path"] == "ref"
    assert kops.qmm_plan(8, 120, N, st, use_pallas=True)["path"] == "ref"


# ---- differential sweeps vs qmm_ref ---------------------------------------

TOL = {jnp.float32: dict(atol=2e-3, rtol=2e-3),
       jnp.bfloat16: dict(atol=6e-2, rtol=6e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m", [1, 3, 4, 7, 8])
def test_skinny_m_xla_differential(m, dtype):
    qt = _qt()
    x = _x(m, dtype=dtype)
    y = kops.qmm(x, qt)
    y_ref = qmm_ref(x, qt)
    assert y.shape == (m, N) and y.dtype == dtype
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               **TOL[dtype])


@pytest.mark.kernel
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m", [1, 3, 4, 7, 8])
def test_skinny_m_pallas_differential(m, dtype):
    qt = _qt()
    x = _x(m, dtype=dtype)
    y = kops.qmm(x, qt, use_pallas=True)
    y_ref = qmm_ref(x, qt)
    assert y.shape == (m, N) and y.dtype == dtype
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               **TOL[dtype])


@pytest.mark.kernel
@pytest.mark.parametrize("k,n", [(128, 256), (256, 128), (128, 512)])
def test_colstrip_differential(k, n):
    qt = _qt(k, n)
    x = _x(128, k)
    assert kops.qmm_plan(128, k, n, qt.subtile,
                         use_pallas=True)["path"] == "colstrip"
    y = kops.qmm(x, qt, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(qmm_ref(x, qt)),
                               atol=2e-3, rtol=2e-3)


# ---- hypothesis: the internal M padding is bitwise-invisible ---------------

HAS_HYP = importlib.util.find_spec("hypothesis") is not None


@pytest.mark.kernel
@pytest.mark.parametrize("m", [1, 3, 7])
def test_pad_m_bitwise_fixed(m):
    """Deterministic slice of the hypothesis property below — runs even
    where hypothesis isn't installed."""
    qt = _qt()
    x = _x(8, seed=42)
    y_m = kops.qmm(x[:m], qt, use_pallas=True)
    x_pad = jnp.concatenate([x[:m], jnp.zeros((8 - m, K), x.dtype)])
    y_pad = kops.qmm(x_pad, qt, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(y_m), np.asarray(y_pad)[:m])


@pytest.mark.kernel
@pytest.mark.skipif(not HAS_HYP,
                    reason="property test needs hypothesis")
def test_pad_m_bitwise_invariant():
    """qmm of m rows == qmm of the zero-padded (m -> 8) batch, sliced —
    bit for bit: the pad rows must not perturb live rows through the
    kernel's accumulator or the epilogue."""
    import hypothesis.strategies as st
    from hypothesis import given, settings

    qt = _qt()

    @settings(max_examples=8, deadline=None)
    @given(m=st.integers(1, 7), seed=st.integers(0, 2 ** 16))
    def prop(m, seed):
        x = _x(8, seed=seed)
        x_m = x[:m]
        y_m = kops.qmm(x_m, qt, use_pallas=True)
        x_pad = jnp.concatenate([x_m, jnp.zeros((8 - m, K), x.dtype)])
        y_pad = kops.qmm(x_pad, qt, use_pallas=True)
        np.testing.assert_array_equal(np.asarray(y_m),
                                      np.asarray(y_pad)[:m])

    prop()


# ---- single-shard ShardedQTensor routes through the plan -------------------

def test_matmul_any_single_shard_routes_qmm():
    from repro.core.qtensor_sharded import (quantize_qtensor_sharded,
                                            qmm_sharded_ref)
    from repro.models.layers import matmul_any
    w = jax.random.normal(jax.random.PRNGKey(3), (K, N))
    sqt = quantize_qtensor_sharded(w, CFG_Q, 1, 1)
    for m in (1, 5, 8):
        x = _x(m)
        np.testing.assert_allclose(
            np.asarray(matmul_any(x, sqt)),
            np.asarray(qmm_sharded_ref(x, sqt)), atol=2e-3, rtol=2e-3)
