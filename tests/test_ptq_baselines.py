"""MXINT4 / GPTQ / AWQ baseline correctness + model-level PTQ pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.apply import quantize_model
from repro.core.awq import awq_quantize
from repro.core.gptq import gptq_quantize
from repro.core.mx import mx_fake_quant
from repro.core.qconfig import (AWQConfig, GPTQConfig, MXConfig, QMCConfig)
from repro.core.quantizers import rtn_quantize


def _calib(key, n, din):
    # activations with per-channel variance spread (realistic for LLMs)
    scales = jnp.exp(jax.random.normal(key, (din,)))
    return jax.random.normal(jax.random.PRNGKey(9), (n, din)) * scales


def test_mx_better_than_rtn_on_blockwise_data():
    """Per-block shared exponents preserve the small-magnitude blocks that

    a whole-channel RTN scale flushes to zero."""
    scales = jnp.where(jnp.arange(8)[:, None] % 2 == 0, 0.01, 10.0)
    w = (jax.random.normal(jax.random.PRNGKey(1), (8, 32)) *
         scales).reshape(256, 1)
    w = jnp.tile(w, (1, 16))
    small = jnp.abs(w) < 0.05
    q_mx = mx_fake_quant(w, MXConfig(block=32, block_axis=0))
    q_rtn = rtn_quantize(w, 4)
    rel_mx = float(jnp.sum(jnp.square((w - q_mx) * small))
                   / jnp.sum(jnp.square(w * small)))
    rel_rtn = float(jnp.sum(jnp.square((w - q_rtn) * small))
                    / jnp.sum(jnp.square(w * small)))
    assert rel_mx < rel_rtn      # RTN flushes small blocks to zero (==1.0)
    assert rel_mx < 0.5


def test_gptq_beats_rtn_on_layer_output():
    key = jax.random.PRNGKey(2)
    w = jax.random.t(key, df=4.0, shape=(64, 48))
    x = _calib(jax.random.PRNGKey(3), 256, 64)
    wq_gptq = jnp.asarray(gptq_quantize(w, x, GPTQConfig(bits=4)))
    wq_rtn = rtn_quantize(w, 4)
    e_gptq = float(jnp.mean(jnp.square(x @ w - x @ wq_gptq)))
    e_rtn = float(jnp.mean(jnp.square(x @ w - x @ wq_rtn)))
    assert e_gptq < e_rtn


def test_awq_beats_rtn_on_layer_output():
    key = jax.random.PRNGKey(4)
    w = jax.random.t(key, df=4.0, shape=(64, 48))
    x = _calib(jax.random.PRNGKey(5), 256, 64)
    wq_awq = jnp.asarray(awq_quantize(w, x, AWQConfig(bits=4)))
    wq_rtn = rtn_quantize(w, 4)
    e_awq = float(jnp.mean(jnp.square(x @ w - x @ wq_awq)))
    e_rtn = float(jnp.mean(jnp.square(x @ w - x @ wq_rtn)))
    assert e_awq <= e_rtn * 1.0001


def test_quantize_model_walks_tree(tiny_dense):
    from repro.models.model import init_params, train_loss
    params = init_params(tiny_dense, jax.random.PRNGKey(0))
    for method in ("rtn4", "mx4", "qmc", "qmc_subtile"):
        q = quantize_model(params, method=method,
                           qmc=QMCConfig(rho=0.3), min_dim=32)
        # embeddings/norms untouched; weights changed
        np.testing.assert_array_equal(
            np.asarray(q["embed"]["tok"]),
            np.asarray(params["embed"]["tok"]))
        wq = np.asarray(jax.tree_util.tree_leaves(q["blocks"])[0])
        assert q["blocks"].keys() == params["blocks"].keys()
        # loss still computes
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                    tiny_dense.vocab)
        loss, _ = train_loss(tiny_dense, q,
                             {"tokens": tokens, "labels": tokens},
                             remat=False)
        assert np.isfinite(float(loss))


def test_quantize_model_gptq_with_taps(tiny_dense):
    """Calibration capture -> GPTQ on captured inputs, per layer."""
    from repro.models.model import forward, init_params
    params = init_params(tiny_dense, jax.random.PRNGKey(0))
    taps = {}
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                tiny_dense.vocab)
    forward(tiny_dense, params, tokens, taps=taps, scan_layers=False)
    assert any("wq" in k for k in taps)
    q = quantize_model(params, method="gptq", taps=taps, min_dim=32)
    changed = np.asarray(jax.tree_util.tree_leaves(q["blocks"])[0])
    orig = np.asarray(jax.tree_util.tree_leaves(params["blocks"])[0])
    assert changed.shape == orig.shape
