"""Noise model, bit-packing, and partitioning tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev)")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core import partition as part
from repro.core.noise import ber_from_confusion, confusion_matrix, \
    perturb_codes
from repro.core.packing import cells_per_weight, pack_codes, packed_nbytes, \
    unpack_codes
from repro.core.qconfig import NoiseModel


def test_confusion_rows_sum_to_one():
    for bits in (2, 3):
        m = np.asarray(confusion_matrix(bits, NoiseModel.for_mode(bits)))
        np.testing.assert_allclose(m.sum(axis=1), 1.0, atol=1e-7)
        assert m.shape == (2 ** bits,) * 2


def test_2bit_mode_less_noisy_than_3bit():
    assert ber_from_confusion(2, NoiseModel.for_mode(2)) < \
        ber_from_confusion(3, NoiseModel.for_mode(3))


def test_empirical_flip_rate():
    noise = NoiseModel(cell_bits=3, p_minus=0.02, p_plus=0.03)
    codes = jnp.zeros((200_000,)) + 1  # interior state
    noisy = perturb_codes(jax.random.PRNGKey(0), codes, 3, noise)
    d = np.asarray(noisy - codes)
    assert abs((d == -1).mean() - 0.02) < 0.003
    assert abs((d == 1).mean() - 0.03) < 0.003
    assert np.all(np.isin(d, [-1, 0, 1]))


@settings(deadline=None, max_examples=30)
@given(st.integers(2, 8), st.integers(1, 500))
def test_pack_roundtrip(bits, n):
    rng = np.random.default_rng(bits * 1000 + n)
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    codes = rng.integers(lo, hi + 1, size=n)
    packed = pack_codes(codes, bits)
    assert packed.nbytes == packed_nbytes(n, bits)
    out = np.asarray(unpack_codes(packed, bits, n))
    np.testing.assert_array_equal(out, codes)


def test_cells_per_weight_paper_modes():
    assert cells_per_weight(3, 3) == 1.0     # 3-bit MLC: 1 cell/weight
    assert cells_per_weight(3, 2) == 1.5     # 2-bit MLC packing mismatch


def test_scalar_partition_fraction_and_identity():
    w = jax.random.normal(jax.random.PRNGKey(0), (256, 256))
    for rho in (0.1, 0.3, 0.5):
        w_in, w_out = part.partition(w, rho, "scalar")
        np.testing.assert_allclose(np.asarray(w_in + w_out),
                                   np.asarray(w), rtol=0, atol=0)
        frac = float((jnp.abs(w_out) > 0).mean())
        assert abs(frac - rho) < 0.02


def test_subtile_partition_exact_count():
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 512))   # 8x4 subtiles
    mask = part.subtile_outlier_mask(w, 0.25, (8, 128))
    assert int(mask.sum()) == round(0.25 * mask.size)
    em = part.expand_subtile_mask(mask, w.shape, (8, 128))
    assert em.shape == w.shape
    # top-scoring subtile must be selected
    scores = part.subtile_scores(w, (8, 128))
    top = np.unravel_index(int(jnp.argmax(scores)), scores.shape)
    assert bool(mask[top])
