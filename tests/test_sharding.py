"""Sharding-rule unit tests (no multi-device requirement: specs only)."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.launch import sharding as shd


class FakeLeaf:
    def __init__(self, shape):
        self.shape = shape
        self.ndim = len(shape)


class FakeMesh:
    """Duck-typed mesh: axis_names + devices.shape (enough for specs)."""
    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape, dtype=object)


MESH = FakeMesh((16, 16), ("data", "model"))


def spec(path, shape):
    return tuple(shd.param_spec(path, FakeLeaf(shape), MESH))


def test_attention_rules():
    assert spec("blocks/b0/attn/wq", (24, 4096, 4096)) == \
        (None, "data", "model")
    assert spec("blocks/b0/attn/wo", (24, 4096, 4096)) == \
        (None, "model", "data")


def test_embed_vocab_sharded_when_divisible():
    assert spec("embed/tok", (100352, 2048)) == ("model", "data")
    # 92553 is not divisible by 16 -> replicated on that dim
    assert spec("embed/tok", (92553, 2048)) == (None, "data")


def test_moe_expert_rules_with_fallback():
    # 16 experts / 16-way model axis: expert parallelism
    assert spec("blocks/b0/ffn/w_gate", (40, 16, 6144, 10752)) == \
        (None, "model", "data", None)
    # 8 experts: fall back to megatron FFN sharding
    assert spec("blocks/b0/ffn/w_gate", (64, 8, 6144, 32768)) == \
        (None, None, "data", "model")
    assert spec("blocks/b0/ffn/w_down", (64, 8, 32768, 6144)) == \
        (None, None, "model", "data")


def test_norms_replicated():
    assert spec("blocks/b0/norm1", (24, 4096)) == (None, None)
    assert spec("final_norm", (4096,)) == (None,)


def test_mamba_rules():
    assert spec("blocks/b0/mamba/in_proj", (48, 1024, 4384)) == \
        (None, "data", "model")
    assert spec("blocks/b0/mamba/out_proj", (48, 2048, 1024)) == \
        (None, "model", "data")


def test_non_divisible_dims_replicate():
    # 25 heads * 64 = 1600 attn dim: 1600 % 16 == 0 so still sharded;
    # but a 25-dim axis would replicate
    assert spec("blocks/b0/attn/wq", (32, 1600, 1600)) == \
        (None, "data", "model")
    assert spec("blocks/b0/attn/wq", (32, 25, 50)) == (None, None, None)


def test_qtensor_field_specs():
    # ShardedQTensor stacked over groups: [G, S, n, 8, 128]
    sp = shd._qtensor_field_spec("blocks/b0/attn/wq/in_codes",
                                 FakeLeaf((24, 16, 128, 8, 128)), MESH)
    assert tuple(sp) == (None, "model", None, None, None)
    # MoE expert-stacked QTensor: [G, E, n, 8, 128] with E=16
    sp = shd._qtensor_field_spec("blocks/b0/ffn/w_up/in_codes",
                                 FakeLeaf((40, 16, 504, 8, 128)), MESH)
    assert tuple(sp) == (None, "model", None, None, None)
    # scales [G, S, 1, d]
    sp = shd._qtensor_field_spec("blocks/b0/attn/wq/scale_in",
                                 FakeLeaf((24, 16, 1, 256)), MESH)
    assert tuple(sp) == (None, "model", None, None)


def test_cache_specs():
    # flat cache layout [G, B, T, KV*hd]
    leaf = FakeLeaf((24, 128, 32768, 8 * 128))
    sp = shd.cache_spec("blocks/b0/attn/k", leaf, MESH, 128)
    assert tuple(sp) == (None, "data", None, "model")
    # batch 1: sequence-parallel cache on data
    sp = shd.cache_spec("blocks/b0/attn/k",
                        FakeLeaf((9, 1, 524288, 8 * 128)), MESH, 1)
    assert tuple(sp) == (None, None, "data", "model")
    # int8 cache scales shard like the cache
    sp = shd.cache_spec("blocks/b0/attn/k_scale",
                        FakeLeaf((24, 128, 32768, 32)), MESH, 128)
    assert tuple(sp) == (None, "data", None, "model")
