"""QTensor / ShardedQTensor deployment-format tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.qconfig import QMCConfig
from repro.core.qmc import qmc_quantize, quantization_mse
from repro.core.qtensor import (QTensor, dequantize_qtensor, qmatmul_ref,
                                quantize_qtensor)
from repro.core.qtensor_sharded import (dequantize_sharded,
                                        qmm_sharded_ref,
                                        quantize_qtensor_sharded)

CFG = QMCConfig(rho=0.3, granularity="subtile")


def test_qtensor_matches_subtile_fake_quant():
    """The packed format must dequantize to exactly the subtile-granular

    Algorithm 1 output (same partition, same scales)."""
    w = jax.random.t(jax.random.PRNGKey(0), df=3.0, shape=(128, 256))
    qt = quantize_qtensor(w, CFG)
    ref = qmc_quantize(w, CFG)          # granularity="subtile" via CFG
    np.testing.assert_allclose(np.asarray(dequantize_qtensor(
        qt, jnp.float32)), np.asarray(ref.w_hat), atol=1e-5, rtol=1e-5)


def test_qtensor_roundtrip_through_pytree():
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 128))
    qt = quantize_qtensor(w, CFG)
    leaves, treedef = jax.tree_util.tree_flatten(qt)
    qt2 = jax.tree_util.tree_unflatten(treedef, leaves)
    np.testing.assert_array_equal(np.asarray(dequantize_qtensor(qt)),
                                  np.asarray(dequantize_qtensor(qt2)))


def test_qmatmul_ref():
    w = jax.random.normal(jax.random.PRNGKey(2), (128, 128))
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 128))
    qt = quantize_qtensor(w, CFG)
    y = qmatmul_ref(x, qt, jnp.float32)
    y_ref = x @ dequantize_qtensor(qt, jnp.float32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)


def test_capacity_accounting():
    w = jax.random.normal(jax.random.PRNGKey(4), (1024, 1024))
    qt = quantize_qtensor(w, CFG)
    fp16 = w.size * 2
    ratio_cells = fp16 / qt.nbytes_packed()
    ratio_container = fp16 / qt.nbytes_container()
    assert 3.9 < ratio_cells < 4.45       # paper: 4.44x minus metadata
    assert 2.6 < ratio_container < 3.1    # int4+int8 containers


@pytest.mark.parametrize("shard_axis", [0, 1])
def test_sharded_qtensor_matches_unsharded_matmul(shard_axis):
    w = jax.random.t(jax.random.PRNGKey(5), df=3.0, shape=(256, 256))
    x = jax.random.normal(jax.random.PRNGKey(6), (4, 256))
    sqt = quantize_qtensor_sharded(w, CFG, n_shards=2,
                                   shard_axis=shard_axis)
    y = qmm_sharded_ref(x, sqt)
    # per-shard quantization differs from whole-tensor quantization, so
    # compare against the sharded dequant (exact) and the fp32 matmul
    # (loose)
    y_exact = x @ dequantize_sharded(sqt, jnp.float32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_exact),
                               atol=1e-4, rtol=1e-4)
    y_fp = x @ w
    rel = float(jnp.linalg.norm(y - y_fp) / jnp.linalg.norm(y_fp))
    assert rel < 0.35   # sanity only; exactness asserted above


def test_sharded_streams_stack_uniformly():
    w = jax.random.normal(jax.random.PRNGKey(7), (128, 512))
    sqt = quantize_qtensor_sharded(w, CFG, n_shards=4, shard_axis=1)
    assert sqt.in_codes.shape[0] == 4
    assert sqt.out_codes.shape[0] == 4
    local = sqt.local(2)
    assert local.shape == (128, 128)
