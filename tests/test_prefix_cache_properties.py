"""Refcount invariants of the prefix cache + paged pool under random op
sequences (property-based; see test_prefix_cache.py for example-based
coverage of the same subsystem)."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev)")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.models.config import ModelConfig  # noqa: E402
from repro.serve.paged_kv import PagedKVPool  # noqa: E402
from repro.serve.prefix_cache import PrefixCache  # noqa: E402

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=64)


def _check_accounting(pool, cache):
    counts = np.zeros_like(pool.ref)
    for pages in pool.slot_pages:
        for pid in pages:
            counts[pid] += 1
    for pid in cache._nodes:
        counts[pid] += 1
    assert (counts[1:] == pool.ref[1:]).all()
    assert all(pool.ref[pid] == 0 for pid in pool.free)
    assert len(pool.free) == len(pool._free_set)
    assert pool.used_count == int((counts[1:] > 0).sum())
    cache.check_invariants()


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_refcount_invariants_random_ops(data):
    """Mini-engine: random admit/finish/evict sequences (with COW on
    whole-prompt hits) keep the pool+index accounting exact — every
    refcount equals its table and index reference population, the free
    list holds exactly the ref-0 pages, and the radix tree never
    dangles."""
    page, slots = 4, 3
    pool = PagedKVPool(CFG, n_pages=10, page=page, max_slots=slots,
                       max_pages_per_seq=4)
    cache = PrefixCache(pool)
    live = {}                                        # slot -> prompt

    for _ in range(data.draw(st.integers(5, 30), label="n_ops")):
        op = data.draw(st.sampled_from(
            ["admit", "finish", "evict"]), label="op")
        if op == "admit" and len(live) < slots:
            slot = next(s for s in range(slots) if s not in live)
            n = data.draw(st.integers(2, 16), label="len")
            prompt = np.array(
                data.draw(st.lists(st.integers(2, 5), min_size=n,
                                   max_size=n), label="prompt"),
                np.int32)
            pages, c = cache.match(prompt)
            start = min(c, len(prompt) - 1)
            pool.adopt(slot, pages)
            if pool.ensure(slot, len(prompt)) is None or (
                    c >= len(prompt)
                    and pool.cow(slot, start) is False):
                pool.free_slot(slot)                 # admission aborted
            else:
                n_full = len(prompt) // page
                cache.insert(prompt, pool.slot_pages[slot][:n_full])
                live[slot] = prompt
        elif op == "finish" and live:
            slot = data.draw(st.sampled_from(sorted(live)), label="slot")
            pool.free_slot(slot)
            del live[slot]
        elif op == "evict":
            cache.evict(data.draw(st.integers(1, 4), label="n"))
        _check_accounting(pool, cache)
