"""Paged KV pool + continuous-batching engine: parity with the legacy

per-slot engine (fp32 and int8 caches, attention and hybrid stacks), page
recycling, scheduler preemption under pool exhaustion, termination edge
cases, throughput, and the memsys paged-traffic hook."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.model import init_params
from repro.serve.engine import LegacyServeEngine, Request, ServeEngine
from repro.serve.paged_kv import PagedKVPool, PoolExhausted, pages_for
from repro.serve.scheduler import bucket_len

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab=64)
CFG = ModelConfig(name="t", family="dense", **BASE)
CFG_INT8 = ModelConfig(name="t8", family="dense", kv_cache_quant=True,
                       **BASE)
CFG_HYBRID = ModelConfig(name="th", family="hybrid", pattern=("hybrid",),
                         d_state=16, ssm_headdim=32, **BASE)


def _params(cfg):
    return init_params(cfg, jax.random.PRNGKey(0))


def _requests(cfg, n=8, max_new=6, seed=5, lo=4, hi=14):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(2, cfg.vocab,
                                        size=int(L)).astype(np.int32),
                    max_new_tokens=max_new)
            for i, L in enumerate(rng.integers(lo, hi, size=n))]


def _clone(reqs):
    return [Request(uid=r.uid, prompt=r.prompt,
                    max_new_tokens=r.max_new_tokens, eos_id=r.eos_id)
            for r in reqs]


def _run_both(cfg, reqs, *, slots=4, max_len=32, **paged_kw):
    params = _params(cfg)
    legacy = _clone(reqs)
    LegacyServeEngine(cfg, params, slots=slots, max_len=max_len).run(legacy)
    paged = _clone(reqs)
    eng = ServeEngine(cfg, params, slots=slots, max_len=max_len,
                      page_size=8, **paged_kw)
    eng.run(paged)
    return legacy, paged, eng


# -------------------------------------------------------------------------
# decode parity: paged gather == contiguous slab, token for token
# -------------------------------------------------------------------------
@pytest.mark.parametrize("cfg", [CFG, CFG_INT8, CFG_HYBRID],
                         ids=["fp32", "int8kv", "hybrid"])
def test_paged_matches_legacy(cfg):
    legacy, paged, eng = _run_both(cfg, _requests(cfg))
    assert [r.out_tokens for r in legacy] == [r.out_tokens for r in paged]
    assert all(r.done for r in paged)
    assert eng.stats.tokens_out == sum(len(r.out_tokens) for r in paged)


def test_paged_batched_not_sequential():
    """8 requests on 8 slots must decode in ~max_new jit calls, not 8x."""
    reqs = _requests(CFG, n=8, max_new=6)
    _, paged, eng = _run_both(CFG, reqs, slots=8)
    assert eng.stats.decode_steps <= 6     # one batched call per token step
    assert all(len(r.out_tokens) == 6 for r in paged)


# -------------------------------------------------------------------------
# pool mechanics: free/reuse, preemption
# -------------------------------------------------------------------------
def test_pool_alloc_free_recycles_pages():
    pool = PagedKVPool(CFG, n_pages=6, page=8, max_slots=2,
                       max_pages_per_seq=3)
    assert pool.free_count == 6
    fresh = pool.ensure(0, 17)                 # 3 pages
    assert len(fresh) == 3 and pool.free_count == 3
    assert 0 not in fresh                      # null page never handed out
    assert pool.ensure(0, 20) == []            # already covered
    assert list(pool.block_tables[0][:3]) == fresh
    # exhaustion: only 3 free pages left but slot 1 wants 3 after slot 0
    # grows -- exhausted pool returns None (caller preempts)
    pool.ensure(1, 17)
    assert pool.free_count == 0
    pool.free_slot(1)
    freed = pool.free_slot(0)
    assert freed == 3 and pool.free_count == 6
    assert not pool.block_tables.any()
    # recycled ids are handed out again (free list holds exactly 1..6)
    again = pool.ensure(1, 24)
    assert sorted(set(again)) == sorted(again) and len(again) == 3
    assert set(again) <= set(range(1, 7))


def test_pool_exhausted_returns_none():
    pool = PagedKVPool(CFG, n_pages=4, page=8, max_slots=2,
                       max_pages_per_seq=3)
    assert pool.ensure(0, 17) is not None      # 3 pages
    assert pool.ensure(1, 17) is None          # 1 page left, needs 3


def test_pool_exceeding_per_seq_capacity_raises():
    pool = PagedKVPool(CFG, n_pages=8, page=8, max_slots=1,
                       max_pages_per_seq=2)
    with pytest.raises(PoolExhausted):
        pool.ensure(0, 17)


def test_engine_page_reuse_across_requests():
    """A pool too small for all requests at once still completes them by

    recycling pages of finished sequences."""
    reqs = _requests(CFG, n=6, max_new=4, lo=8, hi=13)
    total_demand = sum(pages_for(len(r.prompt) + r.max_new_tokens, 8)
                      for r in reqs)
    _, paged, eng = _run_both(CFG, reqs, slots=2, n_pages=6)
    assert all(r.done for r in paged)
    assert eng.stats.pages_peak <= 6 < total_demand


def test_scheduler_preemption_under_exhaustion():
    """Two growing sequences cannot coexist in a 4-page pool: the younger

    is evicted, requeued, and still produces the exact legacy output."""
    rng = np.random.default_rng(9)
    reqs = [Request(uid=i,
                    prompt=rng.integers(2, CFG.vocab, 8).astype(np.int32),
                    max_new_tokens=15)
            for i in range(2)]
    legacy, paged, eng = _run_both(CFG, reqs, slots=2, n_pages=4)
    assert eng.stats.preemptions >= 1
    assert [r.out_tokens for r in legacy] == [r.out_tokens for r in paged]


# -------------------------------------------------------------------------
# termination edge cases (legacy fixes ride along)
# -------------------------------------------------------------------------
@pytest.mark.parametrize("engine_cls", [LegacyServeEngine, ServeEngine],
                         ids=["legacy", "paged"])
def test_eos_at_prefill_burns_no_decode_slot(engine_cls):
    params = _params(CFG)
    probe = [Request(uid=0, prompt=np.arange(2, 8, dtype=np.int32),
                     max_new_tokens=4)]
    engine_cls(CFG, params, slots=2, max_len=32).run(probe)
    first = probe[0].out_tokens[0]

    req = Request(uid=0, prompt=np.arange(2, 8, dtype=np.int32),
                  max_new_tokens=4, eos_id=first)
    eng = engine_cls(CFG, params, slots=2, max_len=32)
    eng.run([req])
    assert req.done and req.out_tokens == [first]
    assert eng.stats.decode_steps == 0         # never entered a decode slot


@pytest.mark.parametrize("engine_cls", [LegacyServeEngine, ServeEngine],
                         ids=["legacy", "paged"])
def test_cache_capacity_fully_used(engine_cls):
    """max_len positions are writable: a prompt of L generates

    1 + (max_len - L) tokens before the cache is full (the old guard lost
    the final slot to an off-by-one)."""
    params = _params(CFG)
    L, max_len = 6, 16
    req = Request(uid=0, prompt=np.arange(2, 2 + L, dtype=np.int32),
                  max_new_tokens=64)
    engine_cls(CFG, params, slots=1, max_len=max_len).run([req])
    assert req.done
    assert len(req.out_tokens) == 1 + (max_len - L)


# -------------------------------------------------------------------------
# throughput + scheduler shape bounding
# -------------------------------------------------------------------------
def test_bucketing_is_power_of_two_pages():
    assert bucket_len(1, 8) == 8
    assert bucket_len(8, 8) == 8
    assert bucket_len(9, 8) == 16
    assert bucket_len(33, 8) == 64
    for n in range(1, 70):
        b = bucket_len(n, 8)
        assert b >= n and b % 8 == 0 and (b & (b - 1)) == 0


def test_paged_throughput_beats_legacy_8_slots():
    params = _params(CFG)
    reqs = _requests(CFG, n=8, max_new=16, lo=6, hi=14)

    def timed(engine_cls):
        # warm-up run compiles; second run measures steady-state decode
        engine_cls(CFG, params, slots=8, max_len=32).run(_clone(reqs))
        eng = engine_cls(CFG, params, slots=8, max_len=32)
        t0 = time.monotonic()
        out = eng.run(_clone(reqs))
        dt = time.monotonic() - t0
        return sum(len(r.out_tokens) for r in out) / dt

    legacy_tps = timed(LegacyServeEngine)
    paged_tps = timed(ServeEngine)
    assert paged_tps >= legacy_tps, (legacy_tps, paged_tps)


# -------------------------------------------------------------------------
# memsys hook: the DSE sees page-rounded batch KV traffic
# -------------------------------------------------------------------------
def test_kv_traffic_paged_accounting():
    from repro.memsys.workload import (kv_bits_per_step, kv_traffic_paged,
                                       make_traffic)
    lens = [10, 17, 32]
    t = kv_traffic_paged(CFG, lens, page=16)
    assert t.n_pages == 1 + 2 + 2
    expect = sum(kv_bits_per_step(CFG, -(-n // 16) * 16) for n in lens)
    assert t.kv_bits_per_step == pytest.approx(expect)
    exact = sum(kv_bits_per_step(CFG, n) for n in lens)
    assert t.kv_bits_per_step_exact == pytest.approx(exact)
    assert t.frag_bits_per_step >= 0
    assert 0 < t.utilization <= 1
    # page-aligned batch has zero fragmentation
    t2 = kv_traffic_paged(CFG, [16, 32], page=16)
    assert t2.frag_bits_per_step == pytest.approx(0.0)
    # the hook rebinding a Traffic for the Eq.(3) DSE
    base = make_traffic(CFG, "qmc", seq_len=2048)
    rebased = t.apply(base)
    assert rebased.kv_bits == pytest.approx(t.kv_bits_per_step)
    assert rebased.weight_bits == pytest.approx(base.weight_bits)
