"""Dry-run machinery test — runs in a SUBPROCESS so the forced host device

count (8 here; 512 in production) never leaks into the main pytest jax."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

SCRIPT = r"""
import repro.launch.dryrun as dr
import jax
from repro.launch import mesh as meshlib
from repro.launch import roofline as rl
from repro.configs import reduced_config
from repro.configs.shapes import ShapeSuite
import json, sys

assert len(jax.devices()) == 8, jax.devices()
mesh = meshlib.make_mesh((2, 2, 2), ("pod", "data", "model"))
out = {}
for arch in ["gemma2-2b", "dbrx-132b", "mamba2-370m"]:
    cfg = reduced_config(arch)
    for suite in [ShapeSuite("t", "train", 32, 8),
                  ShapeSuite("d", "decode", 32, 8)]:
        lowered, compiled, extra = dr.lower_cell(
            arch, suite.name, multi_pod=True, mesh=mesh, cfg=cfg,
            suite=suite)
        cost = dr.cost_dict(compiled)
        coll = rl.collective_bytes(compiled.as_text())
        out[f"{arch}/{suite.kind}"] = {
            "flops": float(cost.get("flops", 0)),
            "coll": coll["total"], "n_coll": coll["count"]}
print("RESULT" + json.dumps(out))
"""


@pytest.mark.slow
def test_dryrun_multipod_small():
    env = dict(os.environ, DRYRUN_DEVICES="8",
               PYTHONPATH=os.path.join(ROOT, "src"))
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT")][0]
    out = json.loads(line[len("RESULT"):])
    assert len(out) == 6
    for k, v in out.items():
        assert v["flops"] > 0, k
        # the pod axis forces cross-pod collectives in the train steps
        if "train" in k:
            assert v["n_coll"] > 0, k


def test_production_artifacts_if_present():
    """Validate the real 512-device sweep artifacts when they exist."""
    d = os.path.join(ROOT, "artifacts", "dryrun")
    if not os.path.isdir(d) or not os.listdir(d):
        pytest.skip("production dry-run artifacts not generated yet")
    recs = [json.load(open(os.path.join(d, f))) for f in os.listdir(d)
            if f.endswith(".json")]
    ok = [r for r in recs if r.get("ok")]
    assert len(ok) >= 60, f"only {len(ok)} cells passed"
    meshes = {r["mesh"] for r in ok}
    assert {"pod16x16", "pod2x16x16"} <= meshes
    for r in ok:
        assert r["roofline"]["flops_per_dev"] > 0, (r["arch"], r["shape"])
