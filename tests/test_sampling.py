"""Jitted sampling head + self-speculative decode
(``serve/sampling.py``, ``serve/speculative.py`` and their engine/step
integration).

Pins the contracts ISSUE 9 landed:

* **Greedy is the oracle** — ``temperature=0`` through the fused
  sampling head is token-identical (bitwise argmax) to the engine
  default, and logprobs ride along without changing selection.
* **Sampling is layout-independent** — a request's stream depends only
  on ``(seed, uid, position)``: identical across runs, and identical
  whether the lane decodes through the B=1 solo step or the full-width
  batch step.
* **Top-k / top-p mass properties** on :func:`select_tokens` directly —
  fixed cases always, hypothesis sweeps when available.
* **Nothing vocab-sized leaves the jit** — the step returns ``[B, C]``
  int32 tokens, dead columns carry ``DEAD_TOKEN``, and a whole round
  reaches the device through exactly ONE attributed step dispatch
  (the stray post-step ``jnp.argmax`` this PR killed would show up as
  either a second dispatch or a ``[B, C, V]`` output).
* **Self-speculative greedy is token-identical** to plain greedy at
  every k, verify grants draw on the round prefill budget, and EOS
  truncates acceptance (the EOS contract: the eos token IS emitted,
  then generation stops — mid-chunk and at a chunk boundary alike).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve import sampling as samplib
from repro.serve import speculative
from repro.serve import steps as serve_steps
from repro.serve.engine import Request, ServeEngine
from repro.serve.sampling import DEAD_TOKEN, SamplingParams, select_tokens
from repro.serve.scheduler import FifoScheduler, SchedulerConfig

PAGE = 8
SLOTS = 4
MAX_LEN = 48


def _engine(cfg, params, **kw):
    kw.setdefault("slots", SLOTS)
    return ServeEngine(cfg, params, max_len=MAX_LEN, page_size=PAGE, **kw)


def _batch_only_steps(cfg):
    """Step set with the solo lane stripped — forces every round through
    the full-width batch step (layout-invariance tests)."""
    full = serve_steps.build_paged_steps(
        cfg, page=PAGE, n_pages=serve_steps.default_n_pages(
            SLOTS, MAX_LEN // PAGE),
        max_slots=SLOTS, max_pages_per_seq=MAX_LEN // PAGE)
    return dataclasses.replace(full, solo_step=None)


def _reqs(n=3, max_new=8, seed=13, vocab=64, sampling=None):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(2, vocab, int(L)).astype(np.int32),
                    max_new_tokens=max_new, sampling=sampling)
            for i, L in enumerate(rng.integers(5, 14, size=n))]


def _rep_reqs(n=3, max_new=12, seed=29, vocab=64):
    """Repetitive prompts so the prompt-lookup draft actually fires."""
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=np.tile(rng.integers(2, vocab, 4),
                                   4).astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


# ==========================================================================
# greedy oracle + logprobs
# ==========================================================================
def test_temperature_zero_is_greedy_oracle(serve_cfg, serve_params):
    base = _engine(serve_cfg, serve_params).run(_reqs())
    sp = SamplingParams(temperature=0.0, logprobs=True)
    out = _engine(serve_cfg, serve_params).run(_reqs(sampling=sp))
    assert [r.out_tokens for r in out] == [r.out_tokens for r in base]
    for r in out:
        assert len(r.out_logprobs) == len(r.out_tokens)
        assert all(lp <= 0.0 for lp in r.out_logprobs)
    for r in base:                       # logprobs only on request
        assert r.out_logprobs == []


def test_fixed_seed_determinism_across_runs(serve_cfg, serve_params):
    sp = SamplingParams(temperature=0.9, seed=5, logprobs=True)
    a = _engine(serve_cfg, serve_params).run(_reqs(sampling=sp))
    b = _engine(serve_cfg, serve_params).run(_reqs(sampling=sp))
    assert [r.out_tokens for r in a] == [r.out_tokens for r in b]
    assert [r.out_logprobs for r in a] == [r.out_logprobs for r in b]
    greedy = _engine(serve_cfg, serve_params).run(_reqs())
    assert [r.out_tokens for r in a] != [r.out_tokens for r in greedy]


def test_sampled_stream_is_layout_independent(serve_cfg, serve_params):
    """One request, solo lane vs full-width batch step: the PRNG stream
    keys on (seed, uid, position) only, so the drawn tokens must match
    across batch layouts bit for bit."""
    sp = SamplingParams(temperature=0.8, seed=3)
    def one():
        return [Request(uid=7, prompt=np.arange(2, 12, dtype=np.int32),
                        max_new_tokens=8, sampling=sp)]
    solo = _engine(serve_cfg, serve_params)
    out_s = solo.run(one())
    batch = _engine(serve_cfg, serve_params,
                    step_set=_batch_only_steps(serve_cfg))
    out_b = batch.run(one())
    assert solo.stats.solo_rounds > 0 and batch.stats.solo_rounds == 0
    assert out_s[0].out_tokens == out_b[0].out_tokens


def test_sampling_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=1.5)


# ==========================================================================
# select_tokens: the pure head, called directly
# ==========================================================================
def _head(logits, *, temp=1.0, top_k=0, top_p=1.0, seed=0, n_new=None):
    b, c, _ = logits.shape
    pos = np.broadcast_to(np.arange(c, dtype=np.int32), (b, c))
    key = np.stack([samplib.request_key(seed, u) for u in range(b)])
    return select_tokens(
        jnp.asarray(logits), jnp.full(b, temp, jnp.float32),
        jnp.full(b, top_k, jnp.int32), jnp.full(b, top_p, jnp.float32),
        jnp.asarray(key), jnp.asarray(pos),
        jnp.asarray(n_new if n_new is not None
                    else np.full(b, c, np.int32)))


def test_head_greedy_matches_argmax_bitwise(rng):
    lg = rng.standard_normal((3, 5, 32)).astype(np.float32)
    tok, logp = _head(lg, temp=0.0)
    np.testing.assert_array_equal(np.asarray(tok), lg.argmax(-1))
    want = jax.nn.log_softmax(jnp.asarray(lg), axis=-1)
    got = np.take_along_axis(np.asarray(want), lg.argmax(-1)[..., None],
                             axis=-1)[..., 0]
    np.testing.assert_array_equal(np.asarray(logp), got)


def test_head_dead_columns_are_sentinel(rng):
    lg = rng.standard_normal((2, 4, 16)).astype(np.float32)
    tok, logp = _head(lg, temp=0.0, n_new=np.array([2, 0], np.int32))
    tok, logp = np.asarray(tok), np.asarray(logp)
    assert (tok[0, 2:] == DEAD_TOKEN).all() and (tok[1] == DEAD_TOKEN).all()
    assert (logp[0, 2:] == 0.0).all() and (logp[1] == 0.0).all()
    assert (tok[0, :2] == lg[0, :2].argmax(-1)).all()
    assert tok.dtype == np.int32 and logp.dtype == np.float32


def _topk_ok(lg_row, k, tok):
    return lg_row[tok] >= np.sort(lg_row)[-k]


def _topp_ok(lg_row, p, temp, tok):
    scaled = lg_row / temp
    probs = np.exp(scaled - scaled.max())
    probs /= probs.sum()
    order = np.argsort(-scaled)
    n_keep = int(np.sum(np.cumsum(probs[order]) < p)) + 1
    return tok in order[:n_keep]


def test_head_top_k_membership(rng):
    lg = rng.standard_normal((4, 6, 64)).astype(np.float32)
    for k in (1, 3, 8):
        tok = np.asarray(_head(lg, temp=0.7, top_k=k, seed=k)[0])
        for b in range(4):
            for c in range(6):
                assert _topk_ok(lg[b, c], k, tok[b, c])
    # k=1 at any temperature IS greedy
    tok1 = np.asarray(_head(lg, temp=5.0, top_k=1)[0])
    np.testing.assert_array_equal(tok1, lg.argmax(-1))


def test_head_top_p_nucleus_membership(rng):
    lg = (3.0 * rng.standard_normal((4, 6, 64))).astype(np.float32)
    for p in (0.1, 0.5, 0.9):
        tok = np.asarray(_head(lg, temp=0.7, top_p=p, seed=int(p * 10))[0])
        for b in range(4):
            for c in range(6):
                assert _topp_ok(lg[b, c], p, 0.7, tok[b, c])


def test_head_hypothesis_mass_properties():
    pytest.importorskip(
        "hypothesis", reason="property tests need hypothesis "
        "(requirements-dev)")
    from hypothesis import given, settings
    import hypothesis.strategies as st

    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.integers(1, 16),
           st.floats(0.05, 1.0), st.floats(0.2, 3.0))
    def prop(data_seed, k, p, temp):
        r = np.random.default_rng(data_seed)
        lg = (3.0 * r.standard_normal((2, 3, 32))).astype(np.float32)
        tok = np.asarray(_head(lg, temp=temp, top_k=k, top_p=p,
                               seed=data_seed % 97)[0])
        for b in range(2):
            for c in range(3):
                assert _topk_ok(lg[b, c], k, tok[b, c])
                assert _topp_ok(lg[b, c], p, temp, tok[b, c])

    prop()


# ==========================================================================
# nothing vocab-sized leaves the jit
# ==========================================================================
def test_step_returns_tokens_not_logits(serve_cfg, serve_params):
    """Direct step call: outputs are [B, C] int32 / float32 — no vocab
    axis crosses the boundary — and an idle lane reads DEAD_TOKEN."""
    eng = _engine(serve_cfg, serve_params)
    eng.run([Request(uid=0, prompt=np.arange(2, 6, dtype=np.int32),
                     max_new_tokens=1)])     # materializes pool + arena
    steps, arena = eng._steps, eng._arena
    c = steps.chunk
    toks = jnp.zeros((SLOTS, c), jnp.int32) + 2
    n_new = jnp.asarray([c, 0, 0, 0], jnp.int32)
    samp = {k: jnp.asarray(v)
            for k, v in samplib.lane_inputs(SLOTS).items()}
    tok, logp, _ = steps.step(eng._exec_params, toks, arena,
                              jnp.zeros(SLOTS, jnp.int32), n_new, samp)
    assert tok.shape == (SLOTS, c) and tok.dtype == jnp.int32
    assert logp.shape == (SLOTS, c) and logp.dtype == jnp.float32
    tok = np.asarray(tok)
    assert (tok[1:] == DEAD_TOKEN).all()
    assert ((0 <= tok[0]) & (tok[0] < serve_cfg.vocab)).all()


def test_one_attributed_dispatch_per_round(serve_cfg, serve_params):
    """The regression this PR exists for: token selection is fused into
    the compiled step, so a round issues exactly ONE attributed device
    dispatch — the stray out-of-jit argmax would break this count."""
    from repro.obs import costs as obs_costs
    prev = obs_costs.enable_capture()
    try:
        eng = _engine(serve_cfg, serve_params, slots=2)
        eng.run(_reqs())
        rep = eng.last_cost_report
        step_rows = [r for r in rep.fns if r.fn in ("step", "solo_step")]
        assert sum(r.calls for r in step_rows) == eng.stats.rounds
    finally:
        obs_costs.enable_capture(prev)


# ==========================================================================
# self-speculative decode
# ==========================================================================
def test_propose_prompt_lookup():
    h = np.array([5, 6, 7, 8, 5, 6, 7], np.int32)
    np.testing.assert_array_equal(speculative.propose(h, 3), [8, 5, 6])
    assert speculative.propose(np.array([1, 2, 3], np.int32), 4).size == 0
    assert speculative.propose(h, 0).size == 0


def test_accept_greedy_prefix():
    d = np.array([4, 5, 6], np.int32)
    assert speculative.accept_greedy(d, np.array([4, 5, 6, 7])) == 4
    assert speculative.accept_greedy(d, np.array([4, 9, 1, 2])) == 2
    assert speculative.accept_greedy(d, np.array([9, 9, 9, 9])) == 1
    assert speculative.accept_greedy(d, np.array([4])) == 1


@pytest.mark.parametrize("k", [2, 4])
def test_speculative_greedy_token_identical(serve_cfg, serve_params, k):
    base = _engine(serve_cfg, serve_params).run(_rep_reqs())
    spec = _engine(serve_cfg, serve_params, speculative_k=k)
    out = spec.run(_rep_reqs())
    assert [r.out_tokens for r in out] == [r.out_tokens for r in base]
    s = spec.stats
    assert s.spec_rounds > 0 and s.spec_draft_tokens > 0
    assert 0.0 <= s.spec_acceptance_rate <= 1.0
    assert s.spec_accepted_tokens <= s.spec_draft_tokens


def test_speculative_sampled_lanes_fall_back(serve_cfg, serve_params):
    """temperature > 0 lanes never verify (no rejection sampling yet) —
    the run completes with zero speculative rounds and stays equal to
    the non-speculative sampled stream."""
    sp = SamplingParams(temperature=0.9, seed=2)
    reqs = lambda: [dataclasses.replace(r, sampling=sp)
                    for r in _rep_reqs()]
    plain = _engine(serve_cfg, serve_params).run(reqs())
    spec = _engine(serve_cfg, serve_params, speculative_k=4)
    out = spec.run(reqs())
    assert spec.stats.spec_rounds == 0
    assert [r.out_tokens for r in out] == [r.out_tokens for r in plain]


def test_grant_verify_draws_on_round_budget():
    sched = FifoScheduler(SchedulerConfig(chunk=6, max_prefill_tokens=8))
    sched.start_round()
    assert sched.grant_chunk(6) == 6      # first grant, budget -> 2
    assert sched.grant_verify(4) == 2     # clamped to what is left
    assert sched.grant_verify(4) == 0     # exhausted
    sched.start_round()
    assert sched.grant_verify(30) == 8    # no first-grant exemption
    assert sched.grant_verify(1) == 0


# ==========================================================================
# EOS contract: emitted, then stop — all paths agree
# ==========================================================================
def _learned_eos_run(cfg, params, prompt_len, *, idx, max_new=10, **kw):
    """Run greedy once, pick the ``idx``-th generated token as eos_id,
    re-run: output must be the baseline truncated just past that token's
    FIRST occurrence (the eos is emitted, nothing follows)."""
    prompt = np.arange(2, 2 + prompt_len, dtype=np.int32)
    def one(eos=None):
        return [Request(uid=0, prompt=prompt, max_new_tokens=max_new,
                        eos_id=eos)]
    base = ServeEngine(cfg, params, slots=2, max_len=MAX_LEN,
                       page_size=PAGE, **kw).run(one())[0].out_tokens
    eos = base[idx]
    streamed = []
    out = ServeEngine(cfg, params, slots=2, max_len=MAX_LEN,
                      page_size=PAGE, **kw).run(
        one(eos), on_token=lambda s, t, r: streamed.append(int(t)))
    got = out[0].out_tokens
    assert got == base[:base.index(eos) + 1]
    assert got[-1] == eos                 # emitted, not swallowed
    assert streamed == got                # on_token saw the eos too
    return base


def test_eos_emitted_then_stop_mid_chunk(serve_cfg, serve_params):
    # prompt ends mid-page (11 % 8 != 0): the first token comes from a
    # chunk whose last column is mid-chunk
    _learned_eos_run(serve_cfg, serve_params, 11, idx=3)


def test_eos_emitted_then_stop_at_chunk_boundary(serve_cfg, serve_params):
    # chunk_tokens=8 and a 16-token prompt: the final prefill chunk ends
    # exactly at the chunk boundary, then eos at the very first token
    _learned_eos_run(serve_cfg, serve_params, 16, idx=0, chunk_tokens=8)


def test_eos_truncates_speculative_acceptance(serve_cfg, serve_params):
    """Speculative greedy with an eos learned from the baseline: still
    token-identical, and nothing ever follows the eos even when the
    verify step accepted a longer prefix."""
    base = _engine(serve_cfg, serve_params).run(_rep_reqs(n=1))
    toks = base[0].out_tokens
    eos = toks[len(toks) // 2]
    def one(eos_id):
        r = _rep_reqs(n=1)[0]
        return [dataclasses.replace(r, eos_id=eos_id)]
    plain = _engine(serve_cfg, serve_params).run(one(eos))
    spec = _engine(serve_cfg, serve_params, speculative_k=4)
    out = spec.run(one(eos))
    assert out[0].out_tokens == plain[0].out_tokens
    assert out[0].out_tokens[-1] == eos
    assert eos not in out[0].out_tokens[:-1]
