"""Per-architecture smoke tests: REDUCED config of the same family, one

forward/train step on CPU, asserting output shapes + no NaNs (assignment
requirement), plus decode-vs-forward consistency for the serving path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, PAPER_ARCHS, reduced_config
from repro.models.model import (decode_step, forward, init_params, prefill,
                                train_loss)

ALL = ASSIGNED_ARCHS + PAPER_ARCHS


def _batch_for(cfg, b=2, s=16):
    key = jax.random.PRNGKey(7)
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab)}
    batch["labels"] = batch["tokens"]
    if cfg.n_vis_tokens:
        batch["vis_embeds"] = jnp.full((b, cfg.n_vis_tokens, cfg.d_model),
                                       0.01, jnp.float32)
    if cfg.is_encdec:
        batch["frames"] = jnp.full((b, cfg.enc_seq, cfg.d_model), 0.01,
                                   jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ALL)
def test_smoke_train_step(arch):
    cfg = reduced_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    logits, _, _ = forward(cfg, params, batch["tokens"],
                           vis_embeds=batch.get("vis_embeds"),
                           frames=batch.get("frames"))
    assert logits.shape == (2, 16, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    loss, metrics = train_loss(cfg, params, batch, remat=False)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: train_loss(cfg, p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.square(g)))
             for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "gemma2-2b",
                                  "mamba2-370m", "jamba-1.5-large-398b",
                                  "dbrx-132b", "whisper-medium",
                                  "internvl2-2b", "hymba-1.5b"])
def test_smoke_decode_matches_forward(arch):
    import dataclasses
    # capacity drops legitimately differ between full-forward (B*S tokens)
    # and decode (B tokens); disable drops for the consistency check
    cfg = dataclasses.replace(reduced_config(arch), capacity_factor=16.0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 12
    batch = _batch_for(cfg, b, s)
    tokens = batch["tokens"]
    kw = {k: batch[k] for k in ("vis_embeds", "frames") if k in batch}
    full, _, _ = forward(cfg, params, tokens, **kw)
    lg, cache = prefill(cfg, params, tokens[:, : s - 3],
                        max_len=s + cfg.n_vis_tokens + 2,
                        cache_dtype=jnp.float32, **kw)
    errs = [float(jnp.max(jnp.abs(lg - full[:, s - 4])))]
    for i in range(s - 3, s):
        pos = i + cfg.n_vis_tokens
        lg, cache = decode_step(cfg, params, tokens[:, i:i + 1], cache,
                                jnp.asarray(pos))
        if i + 1 < s:
            errs.append(float(jnp.max(jnp.abs(lg - full[:, i]))))
    assert max(errs) < 5e-4, errs
