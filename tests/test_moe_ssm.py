"""Correctness of the MoE dispatch and the chunked SSD scan."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.moe import moe_block
from repro.models.ssm import ssd_chunked


def _dense_moe_reference(p, x, cfg):
    """Compute every expert densely, combine with the same top-k gates."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    gates, ids = jax.lax.top_k(probs, cfg.topk)
    gates = gates / gates.sum(-1, keepdims=True)
    outs = []
    for e in range(cfg.n_experts):
        h = jax.nn.silu(xf @ p["w_gate"][e]) * (xf @ p["w_up"][e])
        outs.append(h @ p["w_down"][e])
    outs = jnp.stack(outs, 1)                       # [T, E, d]
    y = jnp.zeros_like(xf)
    for k in range(cfg.topk):
        y += gates[:, k:k + 1] * jnp.take_along_axis(
            outs, ids[:, k][:, None, None], axis=1)[:, 0]
    return y.reshape(b, s, d)


def test_moe_dispatch_matches_dense_reference():
    cfg = ModelConfig(name="m", family="moe", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                      n_experts=4, topk=2, moe_pattern=(True,),
                      capacity_factor=4.0)   # no drops
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    p = {"router": jax.random.normal(ks[0], (32, 4)) * 0.1,
         "w_gate": jax.random.normal(ks[1], (4, 32, 64)) * 0.1,
         "w_up": jax.random.normal(ks[2], (4, 32, 64)) * 0.1,
         "w_down": jax.random.normal(ks[3], (4, 64, 32)) * 0.1}
    x = jax.random.normal(ks[4], (2, 16, 32))
    y, aux = moe_block(p, x, cfg)
    y_ref = _dense_moe_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-3)
    assert float(aux) > 0


def test_moe_capacity_drops_dont_crash():
    cfg = ModelConfig(name="m", family="moe", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=32, vocab=64,
                      n_experts=4, topk=2, moe_pattern=(True,),
                      capacity_factor=0.25)  # heavy drops
    key = jax.random.PRNGKey(1)
    p = {"router": jnp.ones((16, 4)) * 0.1,   # degenerate router
         "w_gate": jax.random.normal(key, (4, 16, 32)) * 0.1,
         "w_up": jax.random.normal(key, (4, 16, 32)) * 0.1,
         "w_down": jax.random.normal(key, (4, 32, 16)) * 0.1}
    x = jax.random.normal(key, (2, 32, 16))
    y, _ = moe_block(p, x, cfg)
    assert np.all(np.isfinite(np.asarray(y)))


def _ssd_naive(x, a_dt, b, c):
    """Token-by-token recurrence reference."""
    bsz, l, h, p = x.shape
    n = b.shape[-1]
    hg = h // b.shape[2]
    bh = np.repeat(np.asarray(b), hg, axis=2)
    ch = np.repeat(np.asarray(c), hg, axis=2)
    xn, an = np.asarray(x, np.float64), np.asarray(a_dt, np.float64)
    state = np.zeros((bsz, h, p, n))
    ys = np.zeros((bsz, l, h, p))
    for t in range(l):
        state = state * np.exp(an[:, t])[:, :, None, None] + \
            np.einsum("bhp,bhn->bhpn", xn[:, t], bh[:, t])
        ys[:, t] = np.einsum("bhpn,bhn->bhp", state, ch[:, t])
    return ys, state


@pytest.mark.parametrize("l,chunk", [(32, 8), (64, 16), (24, 8)])
def test_ssd_chunked_matches_naive(l, chunk):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    bsz, h, p, g, n = 2, 4, 8, 2, 16
    x = jax.random.normal(ks[0], (bsz, l, h, p)) * 0.5
    a_dt = -jnp.abs(jax.random.normal(ks[1], (bsz, l, h))) * 0.3
    b = jax.random.normal(ks[2], (bsz, l, g, n)) * 0.3
    c = jax.random.normal(ks[3], (bsz, l, g, n)) * 0.3
    y, hf = ssd_chunked(x, a_dt, b, c, None, chunk=chunk)
    y_ref, h_ref = _ssd_naive(x, a_dt, b, c)
    np.testing.assert_allclose(np.asarray(y, np.float64), y_ref,
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(hf, np.float64), h_ref,
                               atol=1e-3, rtol=1e-3)


def test_ssd_initial_state_continuation():
    """Running two halves with state carry == running the full sequence."""
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 4)
    bsz, l, h, p, g, n = 1, 32, 2, 4, 1, 8
    x = jax.random.normal(ks[0], (bsz, l, h, p)) * 0.5
    a_dt = -jnp.abs(jax.random.normal(ks[1], (bsz, l, h))) * 0.2
    b = jax.random.normal(ks[2], (bsz, l, g, n)) * 0.3
    c = jax.random.normal(ks[3], (bsz, l, g, n)) * 0.3
    y_full, h_full = ssd_chunked(x, a_dt, b, c, None, chunk=8)
    y1, h1 = ssd_chunked(x[:, :16], a_dt[:, :16], b[:, :16], c[:, :16],
                         None, chunk=8)
    y2, h2 = ssd_chunked(x[:, 16:], a_dt[:, 16:], b[:, 16:], c[:, 16:],
                         h1, chunk=8)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               atol=1e-4, rtol=1e-4)
