"""Prefix-cache subsystem: radix match/insert/evict mechanics, refcounted
COW page sharing in the pool, engine parity with caching on vs off (fp32
and int8 KV), eviction-under-pressure vs preemption, streaming callbacks,
scheduler tie-breaking, and the memsys prefix-traffic DSE hook."""
import jax
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.model import init_params
from repro.serve.engine import Request, ServeEngine
from repro.serve.paged_kv import PageAccountingError, PagedKVPool
from repro.serve.prefix_cache import PrefixCache
from repro.serve.scheduler import FifoScheduler, SchedulerConfig

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab=64)
CFG = ModelConfig(name="t", family="dense", **BASE)
CFG_INT8 = ModelConfig(name="t8", family="dense", kv_cache_quant=True,
                       **BASE)
CFG_HYBRID = ModelConfig(name="th", family="hybrid", pattern=("hybrid",),
                         d_state=16, ssm_headdim=32, **BASE)

PAGE = 8


# params are the session-scoped conftest fixtures (CFG/CFG_INT8 equal the
# conftest configs field-for-field, so the cached weights match) — shared
# with tests/test_paged_attention_kernel.py
@pytest.fixture(scope="module")
def params(serve_cfg, serve_params):
    assert serve_cfg == CFG
    return serve_params


@pytest.fixture(scope="module")
def params_int8(serve_cfg_int8, serve_params_int8):
    assert serve_cfg_int8 == CFG_INT8
    return serve_params_int8


def _pool(n_pages=16, max_slots=4, max_pages=8):
    return PagedKVPool(CFG, n_pages=n_pages, page=PAGE, max_slots=max_slots,
                       max_pages_per_seq=max_pages)


def _prompt(rng, n):
    return rng.integers(2, CFG.vocab, n).astype(np.int32)


def _tenant_requests(n=6, sys_len=24, user_lo=4, user_hi=12, max_new=5,
                     seed=3):
    """Shared system prompt + unique user suffix per request."""
    rng = np.random.default_rng(seed)
    sys_prompt = _prompt(rng, sys_len)
    return [Request(uid=i,
                    prompt=np.concatenate(
                        [sys_prompt, _prompt(rng, int(u))]).astype(np.int32),
                    max_new_tokens=max_new)
            for i, u in enumerate(rng.integers(user_lo, user_hi, size=n))]


def _clone(reqs):
    return [Request(uid=r.uid, prompt=r.prompt,
                    max_new_tokens=r.max_new_tokens, eos_id=r.eos_id)
            for r in reqs]


def _run_pair(cfg, p, reqs, **kw):
    """Same workload with caching off and on; returns (off, on, engine)."""
    off = _clone(reqs)
    ServeEngine(cfg, p, page_size=PAGE, **kw).run(off)
    on = _clone(reqs)
    eng = ServeEngine(cfg, p, page_size=PAGE, prefix_cache=True, **kw)
    eng.run(on)
    return off, on, eng


# -------------------------------------------------------------------------
# radix index mechanics (no engine, host-side only)
# -------------------------------------------------------------------------
def test_radix_hit_miss_partial():
    pool = _pool()
    cache = PrefixCache(pool)
    rng = np.random.default_rng(0)
    prompt = _prompt(rng, 3 * PAGE + 3)

    assert cache.match(prompt) == ([], 0)            # cold miss
    pages = pool.ensure(0, len(prompt))
    cache.insert(prompt, pages[:3])                  # 3 full pages cached
    assert len(cache) == 3

    got, n = cache.match(prompt)                     # full hit
    assert n == 3 * PAGE and got == pages[:3]
    # partial: same first page, divergent second
    other = prompt.copy()
    other[PAGE + 1] ^= 1
    got, n = cache.match(other)
    assert n == PAGE and got == pages[:1]
    # miss: diverges inside page 0
    third = prompt.copy()
    third[0] ^= 1
    assert cache.match(third) == ([], 0)
    # prompts shorter than a page can never match
    assert cache.match(prompt[:PAGE - 1]) == ([], 0)


def test_radix_match_covers_whole_prompt_only_in_full_pages():
    pool = _pool()
    cache = PrefixCache(pool)
    prompt = np.arange(2, 2 + 2 * PAGE, dtype=np.int32)   # page-aligned
    cache.insert(prompt, pool.ensure(0, len(prompt)))
    got, n = cache.match(prompt)
    assert n == len(prompt) and len(got) == 2        # engine COWs last page
    got, n = cache.match(prompt[:2 * PAGE - 1])
    assert n == PAGE and len(got) == 1


def test_radix_insert_existing_block_keeps_first_page():
    pool = _pool()
    cache = PrefixCache(pool)
    prompt = np.arange(2, 2 + PAGE, dtype=np.int32)
    first = pool.ensure(0, PAGE)
    cache.insert(prompt, first)
    dup = pool.ensure(1, PAGE)                       # concurrent duplicate
    assert cache.insert(prompt, dup) == 0
    assert cache.match(prompt.tolist() + [9])[0] == first
    assert pool.ref[dup[0]] == 1                     # newcomer stays private


def test_radix_lru_leaf_first_eviction():
    pool = _pool()
    cache = PrefixCache(pool)
    a = np.arange(0, 3 * PAGE, dtype=np.int32) % 60 + 2
    b = a.copy()
    b[2 * PAGE] ^= 1                                 # shares 2 pages with a
    pa = pool.ensure(0, len(a))
    cache.insert(a, pa)
    _, na = cache.match(a)
    pb_own = pool.ensure(1, PAGE)                    # b's divergent page 2
    cache.insert(b, pa[:2] + pb_own)
    pool.free_slot(0)
    pool.free_slot(1)
    assert cache.evictable_pages() == 4
    # a's leaf is older than b's leaf -> evicted first
    freed = cache.evict(1)
    assert freed == 1
    assert cache.match(a)[1] == 2 * PAGE             # interior pages intact
    assert cache.match(b)[1] == 3 * PAGE
    # evicting everything walks leaves upward until the tree is empty
    assert cache.evict(100) == 3
    assert len(cache) == 0 and pool.free_count == pool.n_pages


def test_radix_pinned_pages_not_evictable():
    pool = _pool()
    cache = PrefixCache(pool)
    prompt = np.arange(2, 2 + 2 * PAGE, dtype=np.int32)
    pages = pool.ensure(0, len(prompt))
    cache.insert(prompt, pages)
    assert cache.evictable_pages() == 0              # slot 0 still maps them
    assert cache.evict(5) == 0
    pool.free_slot(0)
    assert cache.evictable_pages() == 2
    got, _ = cache.match(prompt)
    pool.adopt(1, got)                               # adoption re-pins
    assert cache.evictable_pages() == 0 and cache.evict(5) == 0
    assert pool.pinned_count == 2 and pool.cached_only_count == 0


# -------------------------------------------------------------------------
# pool hardening: refcounts, COW, loud free-list failures
# -------------------------------------------------------------------------
def test_pool_release_refcounts_and_double_free():
    pool = _pool()
    (pid,) = pool.ensure(0, 4)
    pool.retain(pid)                                 # cache-style second ref
    assert pool.release(pid) is False                # still cache-held
    assert pool.release(pid) is True                 # now recycled
    with pytest.raises(PageAccountingError):
        pool.release(pid)                            # double free is loud
    with pytest.raises(PageAccountingError):
        pool.retain(pid)                             # retain of a free page


def test_pool_free_slot_spares_cached_pages():
    pool = _pool()
    pages = pool.ensure(0, 2 * PAGE)
    for pid in pages:
        pool.retain(pid)
    assert pool.free_slot(0) == 0                    # cache refs keep both
    assert pool.free_count == pool.n_pages - 2
    for pid in pages:
        assert pool.release(pid)
    assert pool.free_count == pool.n_pages


def test_pool_adopt_requires_live_pages_and_empty_slot():
    pool = _pool()
    pages = pool.ensure(0, PAGE)
    pool.adopt(1, pages)
    assert pool.ref[pages[0]] == 2
    with pytest.raises(PageAccountingError):
        pool.adopt(1, pages)                         # non-empty slot
    free_pid = pool.free[0]
    with pytest.raises(PageAccountingError):
        pool.adopt(2, [free_pid])                    # unallocated page


def test_pool_cow_semantics():
    pool = _pool(n_pages=3, max_slots=3, max_pages=2)
    pages = pool.ensure(0, 2 * PAGE)
    assert pool.cow(0, 0) is None                    # private: no copy
    pool.adopt(1, pages)
    src_dst = pool.cow(1, 0)                         # shared: privatize
    assert src_dst == (pages[0], 3) or src_dst[0] == pages[0]
    src, dst = src_dst
    assert pool.slot_pages[1][0] == dst != src
    assert pool.block_tables[1, 0] == dst
    assert pool.ref[src] == 1 and pool.ref[dst] == 1
    assert pool.cow_copies == 1
    # second COW in the same pool: free list is now empty
    pool.adopt(2, [pages[1]])
    assert pool.cow(2, 0) is False                   # caller must evict


# -------------------------------------------------------------------------
# engine parity: cache on == cache off, token for token
# -------------------------------------------------------------------------
@pytest.mark.parametrize("cfg_name", ["fp32", "int8"])
def test_prefix_cache_parity_shared_prompt(cfg_name, params, params_int8):
    cfg = CFG if cfg_name == "fp32" else CFG_INT8
    p = params if cfg_name == "fp32" else params_int8
    reqs = _tenant_requests(n=6, sys_len=24)
    off, on, eng = _run_pair(cfg, p, reqs, slots=4, max_len=64)
    assert [r.out_tokens for r in off] == [r.out_tokens for r in on]
    assert all(r.done for r in on)
    s = eng.stats
    assert s.cache_hits >= 5                         # every follower hits
    assert s.cache_hit_tokens >= 5 * 24
    assert s.prefill_tokens < s.prompt_tokens
    assert s.prefill_token_reduction >= 0.5


@pytest.mark.parametrize("cfg_name", ["fp32", "int8"])
def test_prefix_cache_cow_divergence_after_shared_prefix(cfg_name, params,
                                                        params_int8):
    """Identical page-aligned prompts: followers adopt EVERY page and COW
    the one the recomputed final token lands in; divergent generations
    after the shared prefix never corrupt each other."""
    cfg = CFG if cfg_name == "fp32" else CFG_INT8
    p = params if cfg_name == "fp32" else params_int8
    rng = np.random.default_rng(7)
    prompt = _prompt(rng, 2 * PAGE)                  # aligned whole prompt
    reqs = [Request(uid=i, prompt=prompt.copy(), max_new_tokens=6)
            for i in range(3)]
    off, on, eng = _run_pair(cfg, p, reqs, slots=4, max_len=64)
    assert [r.out_tokens for r in off] == [r.out_tokens for r in on]
    assert eng.stats.cow_copies == 2                 # one per follower
    assert eng.stats.cache_hits == 2
    # identical prompts under greedy decode produce identical outputs
    assert on[0].out_tokens == on[1].out_tokens == on[2].out_tokens


def test_prefix_cache_persists_across_runs(params):
    reqs = _tenant_requests(n=4, sys_len=24)
    eng = ServeEngine(CFG, params, slots=4, max_len=64, page_size=PAGE,
                      prefix_cache=True)
    eng.run(_clone(reqs))
    first = eng.stats.cache_hits
    out2 = eng.run(_clone(reqs))
    assert eng.stats.cache_hits == len(reqs) > first  # run 2: all hit
    off = _clone(reqs)
    ServeEngine(CFG, params, slots=4, max_len=64, page_size=PAGE).run(off)
    assert [r.out_tokens for r in off] == [r.out_tokens for r in out2]


def test_prefix_cache_rejects_recurrent_stacks(params):
    with pytest.raises(NotImplementedError):
        ServeEngine(CFG_HYBRID, init_params(CFG_HYBRID,
                                            jax.random.PRNGKey(0)),
                    prefix_cache=True)


# -------------------------------------------------------------------------
# eviction under pressure + preemption interplay
# -------------------------------------------------------------------------
def test_eviction_under_pressure_with_preemption(params):
    """A pool too small to keep every published page forces LRU eviction
    of cached pages (and possibly preemption); outputs stay identical to
    the cache-off engine and nothing deadlocks."""
    reqs = _tenant_requests(n=8, sys_len=16, user_lo=6, user_hi=12,
                            max_new=10, seed=9)
    off, on, eng = _run_pair(CFG, params, reqs, slots=2, max_len=48,
                             n_pages=10)
    assert all(r.done for r in on)
    assert [r.out_tokens for r in off] == [r.out_tokens for r in on]
    s = eng.stats
    assert s.cache_hits >= 1
    assert s.cache_evictions >= 1                    # pressure really bit
    pool = eng._pool
    # no leaks: at rest only index-held pages remain allocated
    assert pool.pinned_count == 0
    assert pool.used_count == eng.prefix_cache.cached_pages()


# -------------------------------------------------------------------------
# streaming callback (satellite)
# -------------------------------------------------------------------------
def test_streaming_tokens_match_final_outputs(params):
    reqs = _tenant_requests(n=6, sys_len=24)
    streams = {}
    eng = ServeEngine(CFG, params, slots=3, max_len=64, page_size=PAGE,
                      prefix_cache=True)
    out = eng.run(_clone(reqs), on_token=lambda s, tok, req:
                  streams.setdefault(req.uid, []).append((s, tok)))
    for r in out:
        assert [t for _, t in streams[r.uid]] == r.out_tokens
    # every request's decode tokens came from one stable slot
    for r in out:
        slots = {s for s, _ in streams[r.uid]}
        assert len(slots) == 1


def test_streaming_eos_at_prefill_reports_no_slot(params):
    probe = Request(uid=0, prompt=np.arange(2, 12, dtype=np.int32),
                    max_new_tokens=4)
    ServeEngine(CFG, params, slots=2, max_len=32,
                page_size=PAGE).run([probe])
    first = probe.out_tokens[0]
    seen = []
    req = Request(uid=1, prompt=np.arange(2, 12, dtype=np.int32),
                  max_new_tokens=4, eos_id=first)
    ServeEngine(CFG, params, slots=2, max_len=32, page_size=PAGE).run(
        [req], on_token=lambda s, tok, r: seen.append((s, tok)))
    assert seen == [(-1, first)]


# -------------------------------------------------------------------------
# scheduler: deterministic preemption order (satellite regression)
# -------------------------------------------------------------------------
def test_choose_victim_breaks_stamp_ties_by_slot_id():
    for order in ([1, 2, 3], [3, 2, 1], [2, 3, 1]):
        sched = FifoScheduler(SchedulerConfig())
        sched.admitted_at = {0: 5}
        for slot in order:
            sched.admitted_at[slot] = 7              # forged equal stamps
        assert sched.choose_victim(0) == 3           # (stamp, slot) max
    sched = FifoScheduler(SchedulerConfig())
    sched.admitted_at = {0: 5, 1: 9, 2: 7}
    assert sched.choose_victim(0) == 1               # stamp still dominates
    assert sched.choose_victim(1) is None            # no younger slot


def test_grant_chunk_round_budget():
    """Per-round chunk grants: the round's FIRST grant ignores the token
    budget (anti-deadlock — a chunk wider than the budget must still
    run), every later grant is capped by what is left, and a spent
    budget idles further lanes until the next round."""
    sched = FifoScheduler(SchedulerConfig(page=PAGE, chunk=16,
                                          max_prefill_tokens=24))
    sched.start_round()
    assert sched.grant_chunk(64) == 16               # first: full chunk
    assert sched.grant_chunk(64) == 8                # capped by remainder
    assert sched.grant_chunk(64) == 0                # budget spent
    sched.start_round()
    assert sched.grant_chunk(5) == 5                 # remainder < chunk
    assert sched.grant_chunk(64) == 16
    assert sched.grant_chunk(64) == 3
    # a chunk wider than the whole budget still runs when it is first
    wide = FifoScheduler(SchedulerConfig(page=PAGE, chunk=64,
                                         max_prefill_tokens=32))
    wide.start_round()
    assert wide.grant_chunk(100) == 64
    assert wide.grant_chunk(100) == 0


# -------------------------------------------------------------------------
# memsys DSE hook: prefill-write credit for cache hits
# -------------------------------------------------------------------------
def test_kv_traffic_prefix_accounting():
    from repro.memsys.workload import (kv_bits_per_step, kv_traffic_paged,
                                       kv_traffic_prefix, make_traffic)
    page = 16
    per_tok = (kv_bits_per_step(CFG, 1) - kv_bits_per_step(CFG, 0))
    lens, cached = [40, 40, 24], [0, 32, 16]
    t = kv_traffic_prefix(CFG, lens, cached, page=page)
    # page-rounded prefill writes, minus the cached tokens
    assert t.prefill_write_bits_nocache == pytest.approx(
        per_tok * (48 + 48 + 32))
    assert t.prefill_write_bits == pytest.approx(
        per_tok * (48 + 16 + 16))
    assert t.saved_prefill_write_bits == pytest.approx(per_tok * 48)
    assert t.hit_rate == pytest.approx(48 / 104)
    # residency dedups the shared prefix (unique_cached defaults to max)
    assert t.n_pages_nocache == 3 + 3 + 2
    assert t.n_pages == (3 - 0) + (3 - 2) + (2 - 1) + 2
    assert t.resident_bits == pytest.approx(t.n_pages * per_tok * page)
    # decode reads are the plain paged stream
    paged = kv_traffic_paged(CFG, lens, page=page)
    assert t.kv_bits_per_step == pytest.approx(paged.kv_bits_per_step)
    # Eq.(3)/(4) rebinding, with and without prefill amortization
    base = make_traffic(CFG, "qmc", seq_len=2048)
    assert t.apply(base).kv_bits == pytest.approx(t.kv_bits_per_step)
    amort = t.apply(base, amortize_tokens=64)
    assert amort.kv_bits == pytest.approx(
        t.kv_bits_per_step + t.prefill_write_bits / (3 * 64))
    with pytest.raises(ValueError):
        kv_traffic_prefix(CFG, [16], [9], page=page)  # partial-page cached


# -------------------------------------------------------------------------
# Pallas paged-attention kernel: end-to-end greedy parity (PR-4 tentpole).
# The kernel streams only live pages; the reference engine gathers the
# full block-table width — greedy decode must not see the difference.
# -------------------------------------------------------------------------
@pytest.mark.kernel
@pytest.mark.parametrize("prefix", [False, True], ids=["nocache", "prefix"])
@pytest.mark.parametrize("cfg_name", ["fp32", "int8"])
def test_paged_attention_engine_parity(cfg_name, prefix, params,
                                       params_int8):
    cfg = CFG if cfg_name == "fp32" else CFG_INT8
    p = params if cfg_name == "fp32" else params_int8
    reqs = _tenant_requests(n=5, sys_len=24)
    ref = _clone(reqs)
    ServeEngine(cfg, p, slots=3, max_len=64, page_size=PAGE,
                prefix_cache=prefix).run(ref)
    ker = _clone(reqs)
    eng = ServeEngine(cfg, p, slots=3, max_len=64, page_size=PAGE,
                      prefix_cache=prefix, paged_attention=True)
    eng.run(ker)
    assert [r.out_tokens for r in ref] == [r.out_tokens for r in ker]
    assert all(r.done for r in ker)
    # the kernel path really did less gather work than full width
    s = eng.stats
    assert 0 < s.kv_pages_live < s.kv_pages_full
    if prefix:
        assert s.cache_hits >= 4          # followers still hit the index


def test_paged_attention_step_set_compat(params):
    """A step set built without the kernel cannot serve an engine that
    asks for it (and vice versa) — the flag is part of the geometry."""
    from repro.serve import steps as serve_steps
    step_set = serve_steps.build_paged_steps(
        CFG, None, page=PAGE, n_pages=32, max_slots=4,
        max_pages_per_seq=8)
    with pytest.raises(ValueError):
        ServeEngine(CFG, params, slots=4, max_len=64, page_size=PAGE,
                    n_pages=32, step_set=step_set, paged_attention=True)


# refcount-invariant property tests live in
# tests/test_prefix_cache_properties.py (whole-module hypothesis guard,
# matching test_quantizers.py idiom)
