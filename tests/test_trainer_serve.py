"""Integration: training loop (loss decreases, checkpoint/resume) and the

serving engine end-to-end, including QMC-quantized serving."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.qconfig import QMCConfig
from repro.core.serving_quant import quantize_for_serving
from repro.data.synthetic import CorpusConfig, SyntheticCorpus
from repro.models.config import ModelConfig
from repro.models.model import init_params
from repro.optim.adamw import AdamWConfig
from repro.serve.engine import Request, ServeEngine
from repro.train.trainer import TrainConfig, train

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=64)


def test_train_loss_decreases(tmp_path):
    tc = TrainConfig(steps=40, global_batch=8, seq_len=32, log_every=1000,
                     ckpt_dir=str(tmp_path), ckpt_every=20, warmup=5)
    out = train(CFG, tc, AdamWConfig(lr=2e-3), log_fn=lambda s: None)
    hist = out["history"]
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first * 0.9, (first, last)
    # checkpoints were written
    from repro.checkpoint import ckpt
    assert ckpt.latest_step(str(tmp_path)) == 40


def test_train_resume_continues(tmp_path):
    tc1 = TrainConfig(steps=10, global_batch=4, seq_len=16, log_every=1000,
                      ckpt_dir=str(tmp_path), ckpt_every=5)
    out1 = train(CFG, tc1, AdamWConfig(lr=1e-3), log_fn=lambda s: None)
    tc2 = TrainConfig(steps=15, global_batch=4, seq_len=16, log_every=1000,
                      ckpt_dir=str(tmp_path), ckpt_every=5, resume=True)
    out2 = train(CFG, tc2, AdamWConfig(lr=1e-3), log_fn=lambda s: None)
    steps = [h["step"] for h in out2["history"]]
    assert steps[0] == 10 and steps[-1] == 14   # resumed at the ckpt step


def test_serve_engine_deterministic_and_quantized():
    params = init_params(CFG, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(2, CFG.vocab, size=8).astype(np.int32)
               for _ in range(5)]

    def run(p):
        reqs = [Request(uid=i, prompt=pr, max_new_tokens=6)
                for i, pr in enumerate(prompts)]
        eng = ServeEngine(CFG, p, slots=2, max_len=32)
        eng.run(reqs)
        return [r.out_tokens for r in reqs], eng.stats

    out_fp, stats = run(params)
    assert stats.tokens_out == 5 * 6
    out_fp2, _ = run(params)
    assert out_fp == out_fp2                     # deterministic

    qparams = quantize_for_serving(
        params, QMCConfig(rho=0.3, granularity="subtile"), tp_shards=1,
        min_dim=64)
    # at least one leaf converted to the packed format
    from repro.core.qtensor_sharded import ShardedQTensor
    leaves = jax.tree_util.tree_leaves(
        qparams, is_leaf=lambda x: isinstance(x, ShardedQTensor))
    assert any(isinstance(l, ShardedQTensor) for l in leaves)
    out_q, _ = run(qparams)
    # greedy decode under 3.6-bit quantization agrees on most early tokens
    agree = np.mean([a[:3] == b[:3] for a, b in zip(out_fp, out_q)])
    assert agree >= 0.4


def test_trained_model_better_than_random_at_cloze():
    tc = TrainConfig(steps=60, global_batch=16, seq_len=48, log_every=1000,
                     warmup=5)
    out = train(CFG, tc, AdamWConfig(lr=2e-3), log_fn=lambda s: None)
    corpus: SyntheticCorpus = out["corpus"]
    from repro.models.model import forward
    probe = corpus.sample_batch(32, 32, step=999_999)
    logits, _, _ = forward(CFG, out["params"],
                           jnp.asarray(probe["tokens"]))
    pred = np.asarray(jnp.argmax(logits[:, :-1], -1))
    acc = (pred == probe["labels"][:, :-1]).mean()
    assert acc > 0.05   # chance is ~1/64 on structured bigram data
