"""Checkpoint (atomic/async/restore), elastic resharding, watchdog tests."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.ft.elastic import choose_mesh_shape
from repro.ft.watchdog import StepWatchdog


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {"a": jax.random.normal(k, (32, 16)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": jnp.float32(3.5)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(t, str(tmp_path), step=7)
    assert ckpt.latest_step(str(tmp_path)) == 7
    restored, step = ckpt.restore(jax.eval_shape(lambda: t),
                                  str(tmp_path))
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_pointer_advances(tmp_path):
    t = _tree()
    ckpt.save(t, str(tmp_path), step=1)
    ckpt.save(t, str(tmp_path), step=2)
    assert ckpt.latest_step(str(tmp_path)) == 2
    _, s = ckpt.restore(jax.eval_shape(lambda: t), str(tmp_path))
    assert s == 2


def test_async_checkpointer(tmp_path):
    t = _tree()
    saver = ckpt.AsyncCheckpointer()
    saver.save(t, str(tmp_path), step=5)
    saver.wait()
    assert ckpt.latest_step(str(tmp_path)) == 5
    restored, _ = ckpt.restore(jax.eval_shape(lambda: t), str(tmp_path))
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(t["a"]))


def test_restore_with_shardings(tmp_path):
    """Elastic path: restore onto an explicit (1-device) mesh sharding."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    t = _tree()
    ckpt.save(t, str(tmp_path), step=3)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    sh = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), t)
    restored, _ = ckpt.restore(jax.eval_shape(lambda: t), str(tmp_path),
                               shardings=sh)
    assert restored["a"].sharding.mesh.shape["data"] == 1


def test_shape_mismatch_rejected(tmp_path):
    t = _tree()
    ckpt.save(t, str(tmp_path), step=1)
    bad = {"a": jnp.zeros((8, 8)), "nested": t["nested"]}
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore(jax.eval_shape(lambda: bad), str(tmp_path))


@pytest.mark.parametrize("n,tp_expected", [(512, 16), (256, 16), (128, 16),
                                           (24, 8), (6, 2), (7, 1)])
def test_choose_mesh_shape(n, tp_expected):
    shape, axes = choose_mesh_shape(n, want_tp=16)
    total = 1
    for s in shape:
        total *= s
    assert total <= n
    if "model" in axes:
        assert shape[axes.index("model")] == tp_expected


def test_watchdog_logs_incident():
    wd = StepWatchdog(deadline_s=0.05, policy="log")
    wd.arm(step=3)
    time.sleep(0.15)
    wd.disarm()
    assert len(wd.incidents) == 1
    assert wd.incidents[0].step == 3
    wd.check()  # log policy: no raise


def test_watchdog_raise_policy():
    wd = StepWatchdog(deadline_s=0.05, policy="raise")
    wd.arm(step=1)
    time.sleep(0.15)
    wd.disarm()
    with pytest.raises(TimeoutError):
        wd.check()
