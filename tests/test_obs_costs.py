"""Cost-attribution layer (``obs/costs.py``) invariants:

  * capture degrades to zeros — never raises — on callables/backends
    without AOT cost analysis, and the drift gauge is SUPPRESSED (not
    set to 0) for such rows;
  * a real jax.jit call shape captures nonzero cost exactly once per
    shape per wrapper, even when the underlying jit is lru-warm;
  * FnCost roofline math matches the v5e constants by hand;
  * modeled bytes/token for a known config + fabricated EngineStats
    matches an explicit hand computation, and qmc is strictly below
    fp32 on identical counters;
  * an engine run under capture produces a CostReport, the
    ``serve_cost_*`` metrics, and the pool/queue Perfetto counter
    tracks — and produces NONE of it with capture off (the default).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch.roofline import HBM_BW, PEAK_FLOPS
from repro.memsys.workload import make_traffic
from repro.obs import costs as obs_costs
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve.engine import EngineStats, Request, ServeEngine
from repro.serve.steps import TracedJit

PAGE = 16


@pytest.fixture
def capture():
    prev = obs_costs.enable_capture()
    yield
    obs_costs.enable_capture(prev)


def _reqs(n=3, lo=8, hi=20, seed=0, max_new=4):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(2, 64, size=int(L)).astype(np.int32),
                    max_new_tokens=max_new)
            for i, L in enumerate(rng.integers(lo, hi, size=n))]


# ==========================================================================
# capture mechanics + fallback
# ==========================================================================
def test_capture_off_by_default_costs_nothing():
    tj = TracedJit("probe", jax.jit(lambda x: x * 2))
    tj(jnp.ones(4))
    assert tj.cost_by_key == {}
    assert tj.calls_by_key == {}
    assert tj.calls == 1               # plain counters still work


def test_capture_fallback_never_raises(capture):
    # a plain Python callable has no .lower — capture must degrade to
    # zeros and the call must still go through
    tj = TracedJit("plain", lambda x: x + 1)
    assert tj(41) == 42
    assert tj.cost_by_key["call"] == {"flops": 0.0, "bytes": 0.0}
    assert tj.calls_by_key["call"] == 1
    rows = obs_costs.collect(_StepSet(tj))
    assert len(rows) == 1 and not rows[0].captured
    assert rows[0].drift == 0.0        # no roofline -> no drift claim


def test_capture_real_jit_per_shape(capture):
    tj = TracedJit("f", jax.jit(lambda x: x @ x))
    for _ in range(3):
        tj(jnp.ones((8, 8)))
    tj(jnp.ones((16, 16)))
    assert set(tj.calls_by_key) == {"call"}   # default key: one bucket
    assert tj.calls_by_key["call"] == 4
    cost = tj.cost_by_key["call"]
    assert cost["flops"] >= 0 and cost["bytes"] >= 0


def test_capture_fires_on_warm_jit(capture):
    # capture keys on shapes THIS wrapper has seen, not on jit-cache
    # growth: a second wrapper over the same (warm) jit still captures
    jitted = jax.jit(lambda x: x + 1)
    jitted(jnp.ones(4))                # warm the executable cache
    tj = TracedJit("warm", jitted)
    tj(jnp.ones(4))
    assert "call" in tj.cost_by_key
    assert tj.calls_by_key["call"] == 1


def test_cost_key_failure_degrades_to_default(capture):
    tj = TracedJit("f", jax.jit(lambda x: x),
                   cost_key=lambda a, k: a[5].shape)   # IndexError
    tj(jnp.ones(2))
    assert set(tj.calls_by_key) == {"call"}


# ==========================================================================
# FnCost roofline math
# ==========================================================================
def test_fncost_roofline_by_hand():
    # one call whose FLOPs take exactly 1s at peak and whose bytes take
    # 0.5s at HBM bandwidth: the bound is the max stream = 1s
    r = obs_costs.FnCost(fn="step", key="C1", calls=2, wall_s=6.0,
                         flops_per_call=PEAK_FLOPS,
                         bytes_per_call=HBM_BW * 0.5)
    assert r.roofline_s == pytest.approx(2.0)          # 2 calls x 1s
    assert r.drift == pytest.approx(3.0)               # 6s wall / 2s bound
    assert r.roofline_fraction == pytest.approx(1 / 3)
    assert r.arithmetic_intensity == pytest.approx(
        PEAK_FLOPS / (HBM_BW * 0.5))
    assert r.captured
    d = r.to_dict()
    assert d["drift"] == pytest.approx(3.0)
    assert d["fn"] == "step" and d["key"] == "C1"


class _StepSet:
    """Duck-typed step-set stand-in: any attrs with cost tables count."""

    def __init__(self, step, page_copy=None):
        self.step = step
        self.page_copy = page_copy
        self.reset_state = None


def test_collect_diffs_against_baseline(capture):
    tj = TracedJit("f", jax.jit(lambda x: x * 3))
    ss = _StepSet(tj)
    tj(jnp.ones(4))
    base = obs_costs.snapshot(ss)
    tj(jnp.ones(4))
    tj(jnp.ones(4))
    rows = obs_costs.collect(ss, base)
    assert len(rows) == 1 and rows[0].calls == 2       # this run only
    assert obs_costs.collect(ss, obs_costs.snapshot(ss)) == []


# ==========================================================================
# modeled memsys cost: hand-pinned formula + qmc < fp32
# ==========================================================================
def _fake_stats():
    s = EngineStats()
    s.rounds = 10
    s.tokens_out = 20
    s.prefill_chunks = 4
    s.kv_pages_live = 30
    s.prefill_kv_pages_live = 12
    s.prefill_kv_pages_written = 6
    return s


def test_modeled_bytes_per_token_by_hand(serve_cfg):
    cfg = serve_cfg                    # 2 attn layers, kv_dim 32
    bits = 32                          # fp32 KV cache
    m = obs_costs.modeled_memsys(cfg, _fake_stats(), method="fp32",
                                 page=PAGE, kv_dtype_bits=bits)
    # per-page KV bits: 2 (K+V) x n_attn_layers x kv_dim x page x dtype
    kv_dim = cfg.n_kv_heads * (cfg.d_model // cfg.n_heads)
    per_page = 2 * cfg.n_layers * kv_dim * PAGE * bits
    assert per_page == 2 * 2 * 32 * 16 * 32
    lane_steps = 20 + 4                # tokens_out + prefill_chunks
    kv_read = (30 + 12) * per_page     # decode + chunk page reads (no SSM)
    kv_write = 6 * per_page + 20 * per_page / PAGE
    kv_per_round = (kv_read + kv_write) / 10
    act_per_round = 4 * cfg.n_layers * cfg.d_model * 16 * lane_steps / 10
    w_per_round = cfg.active_param_count() * 32.0
    expect = (w_per_round + kv_per_round + act_per_round) * 10 / 8 / 20
    assert m["kv_bits_per_round"] == pytest.approx(kv_per_round)
    assert m["act_bits_per_round"] == pytest.approx(act_per_round)
    assert m["weight_bits_per_round"] == pytest.approx(w_per_round)
    assert m["bytes_per_token"] == pytest.approx(expect)
    assert not m["degenerate"]
    assert m["hetero"]["energy_j"] > 0
    assert m["hetero"]["latency_s"] > 0
    assert m["conventional"]["latency_s"] > 0


def test_modeled_qmc_strictly_below_fp32(serve_cfg):
    stats = _fake_stats()
    fp32 = obs_costs.modeled_memsys(serve_cfg, stats, method="fp32",
                                    page=PAGE)
    qmc = obs_costs.modeled_memsys(serve_cfg, stats, method="qmc",
                                   page=PAGE)
    assert qmc["bytes_per_token"] < fp32["bytes_per_token"]
    # identical KV/act streams — only the weight stream shrinks
    assert qmc["kv_bits_per_round"] == fp32["kv_bits_per_round"]
    assert qmc["weight_bits_per_round"] < fp32["weight_bits_per_round"]


def test_modeled_degenerate_run(serve_cfg):
    m = obs_costs.modeled_memsys(serve_cfg, EngineStats(), method="fp16",
                                 page=PAGE)
    assert m["degenerate"] and m["bytes_per_token"] == 0.0


def test_make_traffic_fp32_baseline(serve_cfg):
    t32 = make_traffic(serve_cfg, "fp32")
    t16 = make_traffic(serve_cfg, "fp16")
    assert t32.weight_bits == pytest.approx(2 * t16.weight_bits)


def test_detect_weights_method(serve_cfg, serve_params):
    assert obs_costs.detect_weights_method(serve_params) == "fp32"
    from repro.core.qconfig import QMCConfig
    from repro.core.serving_quant import quantize_for_serving
    q = quantize_for_serving(serve_params,
                             QMCConfig(rho=0.3, granularity="subtile"),
                             tp_shards=1, min_dim=64)
    assert obs_costs.detect_weights_method(q) == "qmc"


# ==========================================================================
# flush: drift suppression + metric names
# ==========================================================================
def test_flush_suppresses_drift_for_uncaptured_rows():
    reg = obs_metrics.Registry()
    rows = [obs_costs.FnCost(fn="step", key="C1", calls=4, wall_s=1.0,
                             flops_per_call=1e9, bytes_per_call=1e6),
            obs_costs.FnCost(fn="page_copy", key="call", calls=2,
                             wall_s=0.1, flops_per_call=0.0,
                             bytes_per_call=0.0)]
    report = obs_costs.CostReport(fns=rows, modeled={"degenerate": True},
                                  measured_wall_s=1.1,
                                  measured_device_s=1.0, tokens_out=8)
    obs_costs.flush_metrics(reg, report)
    snap = reg.snapshot()
    assert snap["serve_cost_flops_total"]["series"] == [
        {"labels": {"fn": "page_copy/call"}, "value": 0.0},
        {"labels": {"fn": "step/C1"}, "value": 4e9}]
    drift = snap["serve_cost_drift_ratio"]["series"]
    assert [s["labels"]["fn"] for s in drift] == ["step/C1"]
    # degenerate modeled section -> no modeled gauges at all
    assert "serve_cost_modeled_bytes_per_token" not in snap


# ==========================================================================
# end to end through the engine
# ==========================================================================
def test_engine_run_attributes_costs(serve_cfg, serve_params, capture):
    reg = obs_metrics.Registry()
    trc = obs_trace.Tracer(enabled=True)
    eng = ServeEngine(serve_cfg, serve_params, slots=2, max_len=64,
                      page_size=PAGE, metrics=reg, tracer=trc)
    eng.run(_reqs())
    rep = eng.last_cost_report
    assert rep is not None
    step_rows = [r for r in rep.fns if r.fn in ("step", "solo_step")]
    assert step_rows and all(r.key.startswith("C") for r in step_rows)
    # every round reaches the device through exactly one step dispatch —
    # the batch step or the B=1 solo lane
    assert sum(r.calls for r in step_rows) == eng.stats.rounds
    assert rep.tokens_out == eng.stats.tokens_out
    assert rep.measured_wall_s > 0
    assert not rep.modeled["degenerate"]
    assert rep.modeled["method"] == "fp32"
    assert rep.table()                 # renders without raising
    snap = reg.snapshot()
    assert "serve_cost_flops_total" in snap
    assert "serve_cost_modeled_bytes_per_token" in snap
    # each captured row reports drift; uncaptured rows (CPU backends
    # without a cost model) suppress it instead of claiming drift=0
    drift_fns = {s["labels"]["fn"]
                 for s in snap["serve_cost_drift_ratio"]["series"]}
    for r in rep.fns:
        assert (r.label in drift_fns) == r.captured
    # pool-pressure counter tracks, one sample per round
    counters = [e for e in trc.events if e["ph"] == "C"]
    pool = [e for e in counters if e["name"] == "pool/pages"]
    queue = [e for e in counters if e["name"] == "sched/queue"]
    assert len(pool) == eng.stats.rounds == len(queue)
    assert {"live", "free"} <= set(pool[0]["args"])
    assert "prefill_pending" in queue[0]["args"]


def test_engine_run_no_capture_no_report(serve_cfg, serve_params):
    reg = obs_metrics.Registry()
    eng = ServeEngine(serve_cfg, serve_params, slots=2, max_len=64,
                      page_size=PAGE, metrics=reg)
    eng.run(_reqs())
    assert eng.last_cost_report is None
    assert "serve_cost_flops_total" not in reg.snapshot()


def test_cost_counter_track_via_default_tracer(serve_cfg, serve_params,
                                               capture):
    # the cumulative cost/<fn> track goes to the PROCESS tracer (same
    # routing as the jit/compile instants deep call sites use)
    trc = obs_trace.Tracer(enabled=True)
    prev = obs_trace.set_tracer(trc)
    try:
        eng = ServeEngine(serve_cfg, serve_params, slots=2, max_len=64,
                          page_size=PAGE,
                          metrics=obs_metrics.Registry())
        eng.run(_reqs())
    finally:
        obs_trace.set_tracer(prev)
    # solo-lane rounds emit on their own cost/solo_step track; every
    # round lands on exactly one of the two
    cost_tracks = [e for e in trc.events if e["ph"] == "C"
                   and e["name"] in ("cost/step", "cost/solo_step")]
    rows = [r for r in eng.last_cost_report.fns
            if r.fn in ("step", "solo_step")]
    if any(r.captured for r in rows):      # backend exposes a cost model
        assert len(cost_tracks) == eng.stats.rounds
        for name in ("cost/step", "cost/solo_step"):
            cum = [e["args"]["bytes"] for e in cost_tracks
                   if e["name"] == name]
            assert cum == sorted(cum)      # cumulative, monotonic
    else:
        assert cost_tracks == []           # zero-cost rows emit no track
