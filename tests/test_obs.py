"""Observability subsystem: metrics registry + span tracer mechanics, and
the engine integration invariants the obs contract promises —

  * token conservation: ``tokens_out == sum(step_tokens) -
    tokens_discarded`` on every run, preemptions included;
  * exactly ONE ``req/first_token`` instant per emitting request, even
    across preemption/recompute;
  * round phase spans are non-overlapping per thread and nested inside
    their round's umbrella span;
  * the exported Chrome trace parses and carries the schema Perfetto
    needs (name/ph/ts/pid/tid, dur on "X" events);
  * page-op counters (adopt / page_copy / tables_rebuild) land in both
    ``EngineStats`` and ``serve_page_ops_total``;
  * TracedJit attributes compiles to the cold engine only and flags
    cache growth beyond a declared compile surface.
"""
import json

import jax
import numpy as np
import pytest

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.metrics import Registry, log_buckets
from repro.obs.trace import Tracer
from repro.serve.engine import Request, ServeEngine
from repro.serve.steps import TracedJit

PAGE = 8


# ==========================================================================
# metrics mechanics
# ==========================================================================
def test_counter_inc_value_and_labels():
    reg = Registry()
    c = reg.counter("hits_total", "hits", labels=("kind",))
    c.inc(kind="a")
    c.inc(2, kind="a")
    c.inc(5, kind="b")
    assert c.value(kind="a") == 3
    assert c.value(kind="b") == 5
    assert c.value(kind="never") == 0
    with pytest.raises(ValueError):
        c.inc(-1, kind="a")            # counters only go up
    with pytest.raises(ValueError):
        c.inc(wrong="a")               # undeclared label name


def test_gauge_set_add():
    g = Registry().gauge("pages")
    g.set(4)
    g.add(-1)
    assert g.value() == 3


def test_histogram_buckets_and_sum():
    h = Registry().histogram("lat", buckets=(0.001, 0.01, 0.1))
    for v in (0.0005, 0.005, 0.005, 5.0):
        h.observe(v)
    assert h.count() == 4
    assert h.sum() == pytest.approx(5.0105)
    row = h._series[()]
    assert row[0] == [1, 2, 0, 1]      # last slot = implicit +Inf bucket
    with pytest.raises(ValueError):
        Registry().histogram("bad", buckets=(1.0, 1.0, 2.0))


def test_log_buckets_span():
    b = log_buckets()
    assert b[0] == pytest.approx(1e-6)
    assert b[-1] > 1.0                 # reaches into cold-compile seconds
    assert all(x < y for x, y in zip(b, b[1:]))


def test_registry_get_or_create_and_mismatch():
    reg = Registry()
    c1 = reg.counter("x_total", labels=("k",))
    assert reg.counter("x_total", labels=("k",)) is c1
    with pytest.raises(ValueError):
        reg.counter("x_total", labels=("other",))   # label drift
    with pytest.raises(ValueError):
        reg.gauge("x_total")                        # type drift
    h = reg.histogram("h", buckets=(1.0, 2.0))
    assert reg.histogram("h", buckets=(1.0, 2.0)) is h
    with pytest.raises(ValueError):
        reg.histogram("h", buckets=(1.0, 3.0))      # bucket drift


def test_prometheus_exposition():
    reg = Registry()
    reg.counter("req_total", "requests", labels=("kind",)).inc(3, kind="a")
    reg.gauge("pages").set(7)
    h = reg.histogram("lat_seconds", "latency", buckets=(0.01, 0.1))
    h.observe(0.005)
    h.observe(0.05)
    h.observe(9.0)
    text = reg.to_prometheus()
    assert '# TYPE req_total counter' in text
    assert 'req_total{kind="a"} 3' in text
    assert 'pages 7' in text
    # cumulative le buckets + +Inf + sum/count
    assert 'lat_seconds_bucket{le="0.01"} 1' in text
    assert 'lat_seconds_bucket{le="0.1"} 2' in text
    assert 'lat_seconds_bucket{le="+Inf"} 3' in text
    assert 'lat_seconds_count 3' in text


def test_snapshot_json_roundtrip(tmp_path):
    reg = Registry()
    reg.counter("a_total", labels=("k",)).inc(2, k="x")
    reg.histogram("h", buckets=(1.0,)).observe(0.5)
    path = tmp_path / "m.json"
    reg.write_json(str(path))
    snap = json.loads(path.read_text())
    assert snap["a_total"]["series"] == [
        {"labels": {"k": "x"}, "value": 2}]
    assert snap["h"]["type"] == "histogram"
    assert snap["h"]["series"][0]["count"] == 1


# ==========================================================================
# tracer mechanics
# ==========================================================================
def test_disabled_tracer_is_noop():
    t = Tracer(enabled=False)
    s1 = t.span("a")
    s2 = t.span("b", x=1)
    assert s1 is s2                    # shared null span, no allocation
    with s1:
        t.instant("i", u=1)
        t.counter("c", v=2)
    assert t.events == []


def test_chrome_trace_schema(tmp_path):
    t = Tracer()
    with t.span("outer", tag="o"):
        with t.span("inner"):
            pass
        t.instant("point", uid=3)
    t.counter("pages", used=4)
    for ev in t.events:
        assert {"name", "ph", "ts", "pid"} <= set(ev)
        if ev["ph"] == "X":
            assert ev["dur"] >= 0 and "tid" in ev
        if ev["ph"] == "i":
            assert ev["s"] == "t" and "tid" in ev
    path = tmp_path / "t.json"
    n = t.export(str(path))
    doc = json.loads(path.read_text())
    assert len(doc["traceEvents"]) == n == 4
    assert doc["displayTimeUnit"] == "ms"


def test_span_nesting_and_phase_totals():
    t = Tracer()
    with t.span("outer"):
        with t.span("inner"):
            pass
    inner = next(e for e in t.events if e["name"] == "inner")
    outer = next(e for e in t.events if e["name"] == "outer")
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6
    totals = t.phase_totals()
    assert totals["inner"] <= totals["outer"]


def test_default_tracer_swap():
    assert not obs_trace.get_tracer().enabled   # process default is off
    mine = Tracer()
    prev = obs_trace.set_tracer(mine)
    try:
        assert obs_trace.active(None) is mine
        other = Tracer(enabled=False)
        assert obs_trace.active(other) is other
    finally:
        obs_trace.set_tracer(prev)


# ==========================================================================
# engine integration
# ==========================================================================
def _reqs(n=6, seed=3, vocab=64, max_new=6, lo=4, hi=20):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(2, vocab, int(L)).astype(np.int32),
                    max_new_tokens=max_new)
            for i, L in enumerate(rng.integers(lo, hi, size=n))]


def _check_phase_spans(events):
    """Round phase spans must not overlap within a thread, and must sit
    inside their round's umbrella span."""
    phases = [e for e in events if e["ph"] == "X"
              and e["name"].startswith("round/")]
    rounds = [e for e in events if e["ph"] == "X" and e["name"] == "round"]
    assert phases and rounds
    by_tid = {}
    for e in phases:
        by_tid.setdefault(e["tid"], []).append(e)
    for evs in by_tid.values():
        evs.sort(key=lambda e: e["ts"])
        for a, b in zip(evs, evs[1:]):
            assert a["ts"] + a["dur"] <= b["ts"] + 1.0, \
                f"{a['name']} overlaps {b['name']}"
    # an aborted round (everything preempted/idled) records admit/grant
    # spans but no umbrella — containment is only promised for the phases
    # that imply the round completed
    for e in phases:
        if e["name"] in ("round/host_prep", "round/device_step",
                         "round/emit"):
            assert any(r["ts"] - 1.0 <= e["ts"] and
                       e["ts"] + e["dur"] <= r["ts"] + r["dur"] + 1.0
                       for r in rounds), f"{e['name']} outside any round"


def test_engine_trace_and_conservation(serve_cfg, serve_params, tmp_path):
    tracer = Tracer()
    reg = Registry()
    eng = ServeEngine(serve_cfg, serve_params, slots=2, max_len=32,
                      page_size=PAGE, tracer=tracer, metrics=reg)
    # 2 slots x small pool, 6 requests: rounds interleave admits/finishes
    out = eng.run(_reqs())
    s = eng.stats
    assert all(r.done for r in out)
    # token conservation: every emitted token is either delivered or
    # accounted as discarded by a preemption
    assert s.tokens_out == sum(s.step_tokens) - s.tokens_discarded
    assert s.tokens_out == sum(len(r.out_tokens) for r in out)
    # exactly one first_token instant per emitting request
    firsts = [e["args"]["uid"] for e in tracer.events
              if e["name"] == "req/first_token"]
    emitting = {r.uid for r in out if r.out_tokens}
    assert sorted(firsts) == sorted(emitting)
    # every admission got an instant; finishes cover every request
    admitted = [e for e in tracer.events if e["name"] == "req/admitted"]
    finished = {e["args"]["uid"] for e in tracer.events
                if e["name"] == "req/finished"}
    assert len(admitted) >= len(out)
    assert finished == {r.uid for r in out}
    _check_phase_spans(tracer.events)
    # phase accounting mirrors the trace (both sides of the same clock)
    assert set(s.phase_seconds) >= {"round/admit", "round/host_prep",
                                    "round/device_step", "round/emit"}
    assert s.rounds == sum(1 for e in tracer.events
                           if e["ph"] == "X" and e["name"] == "round")
    assert s.host_seconds() > 0 and s.device_seconds() > 0
    # exported file is valid Chrome trace JSON
    path = tmp_path / "trace.json"
    n = tracer.export(str(path))
    doc = json.loads(path.read_text())
    assert len(doc["traceEvents"]) == n > 0
    # metrics flushed: registry totals equal the stats the run reported
    assert reg.counter("serve_rounds_total").value() == s.rounds
    assert reg.counter("serve_tokens_total", labels=("kind",)) \
              .value(kind="emitted") == s.tokens_out
    hist = reg.histogram("serve_phase_seconds", labels=("phase",))
    assert hist.count(phase="round/device_step") == s.rounds


def test_engine_itl_from_emission_timestamps(serve_cfg, serve_params):
    eng = ServeEngine(serve_cfg, serve_params, slots=2, max_len=32,
                      page_size=PAGE, metrics=Registry())
    out = eng.run(_reqs(n=3, max_new=5))
    s = eng.stats
    gaps = s.itl_s()
    # each surviving request contributes len(times) - 1 gaps
    want = sum(max(0, len(t) - 1) for t in s.emit_times.values())
    assert len(gaps) == want > 0
    assert all(g >= 0 for g in gaps)
    # no preemption here: emission timestamps cover every delivered token
    assert s.tokens_discarded == 0
    assert sum(len(t) for t in s.emit_times.values()) == \
        sum(len(r.out_tokens) for r in out)


def test_engine_page_op_counters(serve_cfg, serve_params):
    """Shared-prefix tenants: adopts, COW page copies and table rebuilds
    all fire, land in EngineStats AND in serve_page_ops_total."""
    rng = np.random.default_rng(5)
    sys_prompt = rng.integers(2, 64, 2 * PAGE)      # two full shared pages
    reqs = [Request(uid=i,
                    prompt=np.concatenate(
                        [sys_prompt, rng.integers(2, 64, 4)]
                    ).astype(np.int32),
                    max_new_tokens=3)
            for i in range(4)]
    # whole-prompt page-aligned hits: the recomputed final token's KV
    # write COWs the shared page -> page_copy dispatches must fire
    reqs += [Request(uid=4 + i, prompt=sys_prompt.astype(np.int32),
                     max_new_tokens=3) for i in range(2)]
    reg = Registry()
    eng = ServeEngine(serve_cfg, serve_params, slots=2, max_len=32,
                      page_size=PAGE, prefix_cache=True, metrics=reg)
    eng.run(reqs)
    s = eng.stats
    assert s.adopt_calls > 0                  # later tenants adopted pages
    assert s.page_copy_calls == s.cow_copies > 0
    assert s.device_tables_rebuilds > 0
    ops = reg.counter("serve_page_ops_total", labels=("op",))
    assert ops.value(op="adopt") == s.adopt_calls
    assert ops.value(op="page_copy") == s.page_copy_calls
    assert ops.value(op="tables_rebuild") == s.device_tables_rebuilds
    adm = reg.counter("serve_admissions_total", labels=("kind",))
    assert adm.value(kind="hit") == s.cache_hits
    assert adm.value(kind="miss") >= 1


def test_traced_jit_cold_vs_warm(serve_cfg, serve_params):
    """Cold geometry pays compiles; a second engine on the same (lru-warm)
    geometry observes zero compiles of its own."""
    # slots=3 is unique to this test -> guaranteed-cold jit geometry
    kw = dict(slots=3, max_len=32, page_size=PAGE, chunk_tokens=PAGE)
    cold = ServeEngine(serve_cfg, serve_params, metrics=Registry(), **kw)
    cold.run(_reqs(n=3))
    assert cold.stats.jit_compiles >= 2       # step widths C in {1, chunk}
    assert cold.stats.jit_compile_s > 0
    warm = ServeEngine(serve_cfg, serve_params, metrics=Registry(), **kw)
    warm.run(_reqs(n=3))
    assert warm.stats.jit_compiles == 0
    assert warm.stats.jit_compile_s == 0.0


def test_traced_jit_unexpected_retrace():
    """Cache growth beyond the declared compile surface raises the
    retrace counter and instant — the late-flag-flip bug class."""
    reg = Registry()
    tracer = Tracer()
    prev_reg = obs_metrics.set_registry(reg)
    prev_trc = obs_trace.set_tracer(tracer)
    try:
        tj = TracedJit("probe", jax.jit(lambda x: x * 2),
                       expected_shapes=1)
        tj(np.zeros(4, np.float32))            # expected first shape
        tj(np.zeros(8, np.float32))            # surprise second shape
        assert tj.compiles == 2
        retr = reg.counter("serve_jit_retraces_unexpected_total",
                           labels=("fn",))
        assert retr.value(fn="probe") == 1
        assert reg.counter("serve_jit_compiles_total",
                           labels=("fn",)).value(fn="probe") == 2
        names = [e["name"] for e in tracer.events]
        assert names.count("jit/compile") == 2
        assert names.count("jit/unexpected_retrace") == 1
    finally:
        obs_metrics.set_registry(prev_reg)
        obs_trace.set_tracer(prev_trc)


def test_traced_jit_tolerates_non_jit():
    calls = []
    tj = TracedJit("plain", lambda x: calls.append(x) or x)
    assert tj(3) == 3
    assert tj.calls == 1 and tj.compiles == 0
