"""Optimizer + gradient-compression tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.optim.grad_compress import (compress, decompress, init_error)


def test_adamw_minimizes_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0.0)
    state = adamw.init(params, cfg)

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - target))

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.update(g, state, params, cfg)
    assert float(loss(params)) < 1e-3


def test_adamw_bf16_moments():
    params = {"w": jnp.zeros((8, 8))}
    cfg = AdamWConfig(moment_dtype="bfloat16")
    state = adamw.init(params, cfg)
    assert state.m["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((8, 8))}
    p2, s2, _ = adamw.update(g, state, params, cfg)
    assert s2.m["w"].dtype == jnp.bfloat16
    assert np.all(np.isfinite(np.asarray(p2["w"])))


def test_lr_schedule_shape():
    import numpy as np
    s = [float(adamw.lr_schedule(jnp.asarray(i), warmup=10, total=100))
         for i in range(100)]
    assert s[0] < s[9] <= 1.0            # warmup rises
    assert s[99] < s[20]                 # cosine decays
    assert min(s[10:]) >= 0.099          # min_frac floor


def test_compress_roundtrip_bounded_error():
    key = jax.random.PRNGKey(0)
    g = {"a": jax.random.normal(key, (1000,)),
         "b": jax.random.normal(key, (64, 32)) * 5}
    err = init_error(g)
    q, err2 = compress(g, err)
    deq = decompress(q, g)
    for k in g:
        scale = np.abs(np.asarray(g[k])).max() / 127.0
        assert np.max(np.abs(np.asarray(deq[k]) - np.asarray(g[k]))) \
            <= scale * 1.01
    # error feedback holds the residual
    for k in g:
        np.testing.assert_allclose(
            np.asarray(err2[k]),
            np.asarray(g[k]) - np.asarray(deq[k]), atol=1e-6)


def test_error_feedback_convergence():
    """Compressed-gradient descent with EF tracks exact descent closely

    (simulating the 2-pod int8 all-reduce)."""
    target = jnp.asarray(np.random.default_rng(0).normal(size=64))

    def loss(w):
        return 0.5 * jnp.sum(jnp.square(w - target))

    w_exact = jnp.zeros(64)
    w_comp = jnp.zeros(64)
    err = {"w": jnp.zeros(64)}
    lr = 0.1
    for i in range(150):
        g_exact = jax.grad(loss)(w_exact)
        w_exact = w_exact - lr * g_exact
        # two "pods" with slightly different minibatch gradients
        g1 = jax.grad(loss)(w_comp) + 0.01 * np.sin(i)
        g2 = jax.grad(loss)(w_comp) - 0.01 * np.sin(i)
        q1, e1 = compress({"w": g1}, {"w": err["w"]})
        q2, _ = compress({"w": g2}, {"w": jnp.zeros(64)})
        g_avg = 0.5 * (decompress(q1, {"w": g1})["w"]
                       + decompress(q2, {"w": g2})["w"])
        err = e1
        w_comp = w_comp - lr * g_avg
    assert float(loss(w_comp)) < 1e-4
    assert float(jnp.max(jnp.abs(w_comp - w_exact))) < 1e-2
