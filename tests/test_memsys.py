"""Memory co-design simulator tests: Eq. 3/4 semantics + paper ratios,
plus the DSE-vs-implementation consistency check: the bytes
``kv_traffic_paged(live_only=True)`` charges equal the paged-attention
kernel's actual per-step K/V gather volume for a scripted workload."""
import pytest

from repro.configs import get_config
from repro.core.qconfig import QMCConfig
from repro.memsys import (MemSystemConfig, dse, evaluate_conventional,
                          evaluate_hetero, make_traffic)


@pytest.fixture(scope="module")
def hymba():
    return get_config("hymba-1.5b")


def test_eq3_max_rule(hymba):
    """T_final = max(streams) + T_sync: growing only the non-dominant

    stream (KV on LPDDR5) must not change latency until it dominates."""
    sys_cfg = MemSystemConfig(mram_channels=8, reram_banks=8)
    t_small = make_traffic(hymba, "qmc", seq_len=128)
    t_big = make_traffic(hymba, "qmc", seq_len=2048)
    r_small = evaluate_hetero(t_small, sys_cfg)
    r_big = evaluate_hetero(t_big, sys_cfg)
    assert abs(r_small.latency_s - r_big.latency_s) / r_big.latency_s < 0.01


def test_eq4_power_budget_filters(hymba):
    t = make_traffic(hymba, "qmc", seq_len=1024)
    tight = MemSystemConfig(mram_channels=14, reram_banks=12,
                            power_budget_w=1.0)
    assert not evaluate_hetero(t, tight).feasible
    ok = dse(t, power_budget_w=8.0)
    assert ok is not None
    assert evaluate_hetero(t, ok).feasible


def test_dse_picks_latency_minimal_feasible(hymba):
    t = make_traffic(hymba, "qmc", seq_len=1024)
    best = dse(t, power_budget_w=8.0)
    r_best = evaluate_hetero(t, best)
    # any other feasible config must not beat it
    import itertools
    for ch, banks in itertools.product((1, 4, 8, 14), (1, 4, 8, 12)):
        cfgp = MemSystemConfig(mram_channels=ch, reram_banks=banks,
                               power_budget_w=8.0)
        r = evaluate_hetero(t, cfgp)
        if r.feasible:
            assert r.latency_s >= r_best.latency_s - 1e-12


def test_capacity_ratios_match_paper(hymba):
    """7.27x (3-bit MLC) / 6.27x (2-bit MLC) memory-cell reduction vs FP16;

    eMEMs comparisons 1.82x / 0.61x (paper Table 4)."""
    t16 = make_traffic(hymba, "fp16", seq_len=1024)
    q3 = make_traffic(hymba, "qmc", seq_len=1024,
                      qmc=QMCConfig(rho=0.3, cell_bits=3))
    q2 = make_traffic(hymba, "qmc", seq_len=1024,
                      qmc=QMCConfig(rho=0.3, cell_bits=2))
    em_m = make_traffic(hymba, "emems_mram", seq_len=1024)
    em_r = make_traffic(hymba, "emems_reram", seq_len=1024)
    assert abs(t16.total_cells / q3.total_cells - 7.27) < 0.05
    assert abs(t16.total_cells / q2.total_cells - 6.27) < 0.05
    assert abs(em_m.total_cells / q3.total_cells - 1.82) < 0.02
    assert abs(em_r.total_cells / q3.total_cells - 0.61) < 0.02


def test_external_transfer_reduction(hymba):
    """~7.6x external data movement vs FP16 (MRAM traffic is on-chip)."""
    sys_cfg = MemSystemConfig()
    t16 = evaluate_conventional(make_traffic(hymba, "fp16", seq_len=512),
                                sys_cfg)
    q3 = evaluate_hetero(make_traffic(hymba, "qmc", seq_len=512), sys_cfg)
    ratio = t16.external_bits / q3.external_bits
    assert 6.0 < ratio < 8.0


@pytest.mark.kernel
def test_kv_traffic_live_only_matches_kernel_gather(serve_cfg,
                                                    serve_params):
    """The consistency test the ROADMAP kept deferring: the Eq. (3)/(4)
    DSE's ``live_only=True`` page charge must equal what the serving
    implementation actually streams per decode step — counted by the
    engine as it drives the Pallas kernel over a scripted workload —
    while ``live_only=False`` reproduces the reference gather's
    full-block-table width."""
    import numpy as np
    from repro.memsys.workload import kv_traffic_paged, pages_for
    from repro.serve.engine import Request, ServeEngine

    page, max_new = 8, 5
    prompt_lens = [4, 9, 16]                  # sub-page / ragged / aligned
    rng = np.random.default_rng(11)
    reqs = [Request(uid=i, prompt=rng.integers(
        2, serve_cfg.vocab, L).astype(np.int32), max_new_tokens=max_new)
        for i, L in enumerate(prompt_lens)]
    eng = ServeEngine(serve_cfg, serve_params, slots=4, max_len=32,
                      page_size=page, paged_attention=True)
    eng.run(reqs)
    assert all(len(r.out_tokens) == max_new for r in reqs)

    # script the same workload: all 3 admit together, each runs
    # max_new - 1 decode steps (token 1 comes from prefill) at
    # seq = prompt + 1 + t. Charge each step with the DSE.
    from repro.memsys.workload import kv_bits_per_step
    live = full = 0
    live_bits = 0.0
    for t in range(max_new - 1):
        lens = [L + 1 + t for L in prompt_lens]
        traffic = kv_traffic_paged(serve_cfg, lens, page=page)
        assert traffic.n_pages == sum(pages_for(n, page) for n in lens)
        live += traffic.n_pages
        live_bits += traffic.kv_bits_per_step
        wide = kv_traffic_paged(serve_cfg, lens, page=page,
                                live_only=False,
                                max_pages_per_seq=eng.max_pages_per_seq)
        # full width only changes the STREAM; residency stays live
        assert wide.kv_bits_per_step == pytest.approx(
            len(lens) * kv_bits_per_step(
                serve_cfg, eng.max_pages_per_seq * page))
        assert wide.n_pages == traffic.n_pages
        assert wide.resident_bits == pytest.approx(traffic.resident_bits)
        full += len(lens) * eng.max_pages_per_seq
    # page-for-page agreement between the DSE account and the engine's
    # instrumented kernel gather (and the reference full-width read)
    assert eng.stats.kv_pages_live == live
    assert eng.stats.kv_pages_full == full
    assert live_bits > 0 and live < full

    with pytest.raises(ValueError):
        kv_traffic_paged(serve_cfg, [8], page=page, live_only=False)


@pytest.mark.kernel
def test_kv_traffic_chunked_matches_engine_counters(serve_cfg,
                                                    serve_params):
    """Chunk-granular Eq. (3)/(4) prefill traffic: the pages
    ``kv_traffic_chunked`` charges per prompt equal — page for page —
    what the engine records while driving the ragged kernel through a
    chunked-prefill workload (``prefill_kv_pages_live`` mirrors the
    kernel's per-q-block stream, ``prefill_kv_pages_written`` the
    page-rounded chunk scatters)."""
    import inspect

    import numpy as np
    from repro.kernels.paged_attention import Q_BLOCK
    from repro.memsys.workload import (chunk_pages_streamed,
                                       kv_traffic_chunked)
    from repro.serve.engine import Request, ServeEngine

    # the DSE's default q-block must mirror the kernel tiling it models
    assert inspect.signature(chunk_pages_streamed).parameters[
        "q_block"].default == Q_BLOCK

    page, chunk = 8, 8
    prompt_lens = [4, 9, 20, 17]          # sub-page / ragged / multi-chunk
    rng = np.random.default_rng(13)
    reqs = [Request(uid=i, prompt=rng.integers(
        2, serve_cfg.vocab, L).astype(np.int32), max_new_tokens=3)
        for i, L in enumerate(prompt_lens)]
    eng = ServeEngine(serve_cfg, serve_params, slots=4, max_len=32,
                      page_size=page, chunk_tokens=chunk,
                      paged_attention=True)
    eng.run(reqs)
    assert all(r.done for r in reqs)

    traffics = [kv_traffic_chunked(serve_cfg, L, chunk=chunk, page=page)
                for L in prompt_lens]
    assert eng.stats.prefill_kv_pages_live == sum(
        t.kv_pages_read for t in traffics)
    assert eng.stats.prefill_kv_pages_written == sum(
        t.kv_pages_written for t in traffics)
    assert eng.stats.prefill_chunks == sum(t.n_chunks for t in traffics)

    # unit semantics of the account itself
    t = kv_traffic_chunked(serve_cfg, 20, chunk=8, page=8)
    assert t.n_chunks == 3                       # 8 + 8 + 4
    assert t.kv_pages_written == 3               # ceil(20/8) pages once
    # chunk reads: [0,8)->1 page, [8,16)->2, [16,20)->3
    assert t.kv_pages_read == 1 + 2 + 3
    assert t.kv_pages_read_monolithic == chunk_pages_streamed(
        0, 20, page=8, q_block=16)
    assert t.kv_read_bits > 0 and t.kv_write_bits > 0
    base = make_traffic(serve_cfg, "fp16", seq_len=32)
    amort = t.apply(base, amortize_tokens=16)
    assert amort.kv_bits == pytest.approx(
        base.kv_bits + (t.kv_read_bits + t.kv_write_bits) / 16)
    with pytest.raises(ValueError):
        kv_traffic_chunked(serve_cfg, 16, chunk=8, cached_len=5)

    # decode view: one q block, one token -> ceil(seq/page), the same
    # rule kv_traffic_paged charges per lane
    assert chunk_pages_streamed(12, 1, page=8) == 2
    assert chunk_pages_streamed(0, 0, page=8) == 0


def test_system_gains_order(hymba):
    """QMC beats FP16 and 4-bit DRAM baselines on energy and latency."""
    sys_cfg = MemSystemConfig()
    t_fp = evaluate_conventional(make_traffic(hymba, "fp16", seq_len=1024),
                                 sys_cfg)
    t_rtn = evaluate_conventional(make_traffic(hymba, "rtn4", seq_len=1024),
                                  sys_cfg)
    q = evaluate_hetero(make_traffic(hymba, "qmc", seq_len=1024),
                        dse(make_traffic(hymba, "qmc", seq_len=1024)))
    assert q.energy_j < t_rtn.energy_j < t_fp.energy_j
    assert q.latency_s < t_rtn.latency_s < t_fp.latency_s
    assert t_fp.energy_j / q.energy_j > 6.0
    assert t_fp.latency_s / q.latency_s > 8.0
