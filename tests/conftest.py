import os
import sys

# tests see ONE cpu device (the dry-run subprocess sets its own flags)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.models.config import ModelConfig  # noqa: E402


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running (subprocess compile-heavy) tests")
    config.addinivalue_line(
        "markers", "kernel: Pallas kernel parity sweeps (the `-m kernel` "
        "CI lane runs these in both matrix jobs)")


# ---------------------------------------------------------------------------
# shared serving fixtures: one tiny dense config (fp32 + int8-KV variants)
# with session-cached params, reused by test_prefix_cache.py and
# test_paged_attention_kernel.py so the kernel-vs-reference engine parity
# tests extend the existing fixtures instead of duplicating them.
# ---------------------------------------------------------------------------
SERVE_BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                  vocab=64)


@pytest.fixture(scope="session")
def serve_cfg():
    return ModelConfig(name="t", family="dense", **SERVE_BASE)


@pytest.fixture(scope="session")
def serve_cfg_int8():
    return ModelConfig(name="t8", family="dense", kv_cache_quant=True,
                       **SERVE_BASE)


@pytest.fixture(scope="session")
def serve_params(serve_cfg):
    from repro.models.model import init_params
    return init_params(serve_cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="session")
def serve_params_int8(serve_cfg_int8):
    from repro.models.model import init_params
    return init_params(serve_cfg_int8, jax.random.PRNGKey(0))


@pytest.fixture(scope="session")
def tiny_dense():
    return ModelConfig(name="t-dense", family="dense", n_layers=2,
                       d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                       vocab=128)


@pytest.fixture(scope="session")
def tiny_moe():
    return ModelConfig(name="t-moe", family="moe", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                       n_experts=4, topk=2, moe_pattern=(True,))


@pytest.fixture(scope="session")
def tiny_mamba():
    return ModelConfig(name="t-mamba", family="ssm", n_layers=2, d_model=64,
                       n_heads=0, n_kv_heads=0, head_dim=1, d_ff=0,
                       vocab=128, pattern=("mamba",), d_state=16,
                       ssm_headdim=16)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


# ---------------------------------------------------------------------------
# paged-attention differential-harness helpers (test_paged_attention_kernel)
# ---------------------------------------------------------------------------
def make_paged_case(rng, *, page=8, n_kv=2, gqa=2, hd=16, quantized=False,
                    seq_lens=(0, 1, 7, 8, 9, 16, 24), n_tbl=None,
                    poison=1e3):
    """Build one (q, cache, seq_len) paged-decode case.

    Lanes with seq 0 keep an all-null block table (parked on page 0);
    live lanes get *shuffled* page ids so the gather is genuinely
    indirect. The null page is poisoned with ``poison`` so any leak of
    dead-page data breaks parity loudly."""
    import jax.numpy as jnp
    seq = np.asarray(seq_lens, np.int32)
    bsz, kvd = len(seq), n_kv * hd
    live = [max(0, -(-int(L) // page)) for L in seq]
    n_tbl = n_tbl or max(max(live), 1) + 1          # slack dead tail slots
    n_pages = 1 + sum(live) + 2                     # null + live + spare
    kf = rng.standard_normal((n_pages, page, n_kv, hd)).astype(np.float32)
    vf = rng.standard_normal((n_pages, page, n_kv, hd)).astype(np.float32)
    kf[0] = vf[0] = poison
    ids = list(rng.permutation(np.arange(1, n_pages)))
    tbl = np.zeros((bsz, n_tbl), np.int32)
    for b in range(bsz):
        for j in range(live[b]):
            tbl[b, j] = ids.pop()
    cache = {"block_tbl": jnp.asarray(tbl)}
    if quantized:
        from repro.models.kvcache import quantize_kv
        kq, ks = quantize_kv(jnp.asarray(kf))
        vq, vs = quantize_kv(jnp.asarray(vf))
        cache.update(k_pages=kq.reshape(n_pages, page, kvd),
                     v_pages=vq.reshape(n_pages, page, kvd),
                     k_scale_pages=ks, v_scale_pages=vs)
    else:
        cache.update(k_pages=jnp.asarray(kf.reshape(n_pages, page, kvd)),
                     v_pages=jnp.asarray(vf.reshape(n_pages, page, kvd)))
    q = jnp.asarray(rng.standard_normal(
        (bsz, 1, n_kv * gqa, hd)).astype(np.float32))
    return q, cache, jnp.asarray(seq)


def paged_reference(q, cache, seq, *, n_kv, hd, window=None,
                    attn_softcap=None):
    """Reference decode attention: full-width gather + masked attend."""
    import jax.numpy as jnp
    from repro.models.attention import attend, paged_cache_read
    k_all, v_all = paged_cache_read(cache, jnp.float32, n_kv, hd)
    bsz, t = k_all.shape[:2]
    kv_pos = jnp.broadcast_to(jnp.arange(t)[None], (bsz, t))
    return attend(q, k_all, v_all,
                  q_positions=jnp.maximum(seq - 1, 0)[:, None],
                  kv_positions=kv_pos, kv_valid_len=seq, causal=True,
                  window=window, attn_softcap=attn_softcap)


def make_ragged_case(rng, *, page=8, n_kv=2, gqa=2, hd=16, quantized=False,
                     lanes=((0, 0), (0, 1), (3, 5), (8, 8)), n_tbl=None,
                     poison=1e3):
    """Build one multi-query (ragged) paged case.

    ``lanes`` is a per-lane ``(q_start, n_new)`` list: the lane's chunk of
    ``n_new`` query tokens sits at absolute positions ``q_start + t`` and
    its valid KV length is ``q_start + n_new`` (the chunk's own K/V are
    already scattered, exactly the state ``attn_block`` hands the kernel).
    Live lanes get shuffled page ids so the gather is genuinely indirect;
    the null page is poisoned so any dead-page leak breaks parity loudly.
    Returns (q [B, S, H, hd], cache, q_start [B], n_new [B]) with
    S = max(n_new, 1)."""
    import jax.numpy as jnp
    q_start = np.asarray([l[0] for l in lanes], np.int32)
    n_new = np.asarray([l[1] for l in lanes], np.int32)
    kv_len = q_start + n_new
    bsz, kvd = len(lanes), n_kv * hd
    s = max(1, int(n_new.max()))
    live = [-(-int(L) // page) if L else 0 for L in kv_len]
    n_tbl = n_tbl or max(max(live), 1) + 1          # slack dead tail slots
    n_pages = 1 + sum(live) + 2                     # null + live + spare
    kf = rng.standard_normal((n_pages, page, n_kv, hd)).astype(np.float32)
    vf = rng.standard_normal((n_pages, page, n_kv, hd)).astype(np.float32)
    kf[0] = vf[0] = poison
    ids = list(rng.permutation(np.arange(1, n_pages)))
    tbl = np.zeros((bsz, n_tbl), np.int32)
    for b in range(bsz):
        for j in range(live[b]):
            tbl[b, j] = ids.pop()
    cache = {"block_tbl": jnp.asarray(tbl)}
    if quantized:
        from repro.models.kvcache import quantize_kv
        kq, ks = quantize_kv(jnp.asarray(kf))
        vq, vs = quantize_kv(jnp.asarray(vf))
        cache.update(k_pages=kq.reshape(n_pages, page, kvd),
                     v_pages=vq.reshape(n_pages, page, kvd),
                     k_scale_pages=ks, v_scale_pages=vs)
    else:
        cache.update(k_pages=jnp.asarray(kf.reshape(n_pages, page, kvd)),
                     v_pages=jnp.asarray(vf.reshape(n_pages, page, kvd)))
    q = jnp.asarray(rng.standard_normal(
        (bsz, s, n_kv * gqa, hd)).astype(np.float32))
    return q, cache, jnp.asarray(q_start), jnp.asarray(n_new)


def ragged_reference(q, cache, q_start, n_new, *, n_kv, hd, window=None,
                     attn_softcap=None):
    """Reference for the ragged kernel: full-width gather + masked attend
    at absolute query positions. Rows past a lane's ``n_new`` compute
    garbage here (the kernel zeroes them) — compare valid rows only."""
    import jax.numpy as jnp
    from repro.models.attention import attend, paged_cache_read
    k_all, v_all = paged_cache_read(cache, jnp.float32, n_kv, hd)
    bsz, t = k_all.shape[:2]
    s = q.shape[1]
    kv_pos = jnp.broadcast_to(jnp.arange(t)[None], (bsz, t))
    q_pos = q_start[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
    return attend(q, k_all, v_all, q_positions=q_pos, kv_positions=kv_pos,
                  kv_valid_len=q_start + n_new, causal=True,
                  window=window, attn_softcap=attn_softcap)
