import os
import sys

# tests see ONE cpu device (the dry-run subprocess sets its own flags)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.models.config import ModelConfig  # noqa: E402


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running (subprocess compile-heavy) tests")


@pytest.fixture(scope="session")
def tiny_dense():
    return ModelConfig(name="t-dense", family="dense", n_layers=2,
                       d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                       vocab=128)


@pytest.fixture(scope="session")
def tiny_moe():
    return ModelConfig(name="t-moe", family="moe", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                       n_experts=4, topk=2, moe_pattern=(True,))


@pytest.fixture(scope="session")
def tiny_mamba():
    return ModelConfig(name="t-mamba", family="ssm", n_layers=2, d_model=64,
                       n_heads=0, n_kv_heads=0, head_dim=1, d_ff=0,
                       vocab=128, pattern=("mamba",), d_state=16,
                       ssm_headdim=16)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
