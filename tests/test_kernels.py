"""Pallas kernel validation: shape/dtype sweeps vs the jnp oracles

(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.packing import pack_codes
from repro.core.qconfig import QMCConfig
from repro.core.qtensor import quantize_qtensor
from repro.kernels import ops
from repro.kernels.qmm import qmm_pallas
from repro.kernels.ref import qmm_ref, unpack3b_ref
from repro.kernels.unpack3b import unpack3b_pallas


@pytest.mark.parametrize("m,k,n", [(8, 128, 128), (128, 256, 128),
                                   (16, 128, 384), (256, 384, 256)])
@pytest.mark.parametrize("rho", [0.1, 0.3])
def test_qmm_shapes(m, k, n, rho):
    key = jax.random.PRNGKey(m * 7 + n)
    w = jax.random.t(key, df=3.0, shape=(k, n))
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k))
    qt = quantize_qtensor(w, QMCConfig(rho=rho, granularity="subtile"))
    y_ref = qmm_ref(x, qt)
    y = qmm_pallas(x, qt, block_m=min(m, 128), interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_qmm_dtypes(dtype):
    w = jax.random.normal(jax.random.PRNGKey(0), (128, 128))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 128)).astype(dtype)
    qt = quantize_qtensor(w, QMCConfig(rho=0.25, granularity="subtile"))
    y = qmm_pallas(x, qt, block_m=8, interpret=True)
    y_ref = qmm_ref(x, qt)
    assert y.dtype == dtype
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=3e-2, rtol=3e-2)


def test_qmm_extreme_rho():
    """rho=0 (all inliers) and rho~1 (all outliers) still work."""
    w = jax.random.normal(jax.random.PRNGKey(0), (128, 128))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 128))
    for rho in (0.0, 0.99):
        qt = quantize_qtensor(w, QMCConfig(rho=rho, granularity="subtile"))
        y = qmm_pallas(x, qt, block_m=8, interpret=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(qmm_ref(x, qt)),
                                   atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("n", [1024, 2048, 8192])
def test_unpack3b(n):
    rng = np.random.default_rng(n)
    codes = rng.integers(-4, 4, size=n)
    packed = pack_codes(codes, 3)
    out = unpack3b_pallas(jnp.asarray(packed), n, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), codes)
    np.testing.assert_array_equal(np.asarray(unpack3b_ref(
        jnp.asarray(packed), n)), codes)


def test_ops_dispatch_fallback():
    """ops.qmm falls back to the oracle for non-tileable shapes."""
    w = jax.random.normal(jax.random.PRNGKey(0), (96, 160))  # not 128-align
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 96))
    qt = quantize_qtensor(w, QMCConfig(rho=0.3, granularity="subtile",
                                       subtile=(8, 32)))
    y = ops.qmm(x, qt, use_pallas=True)   # silently uses ref path
    np.testing.assert_allclose(np.asarray(y), np.asarray(qmm_ref(x, qt)),
                               atol=1e-4, rtol=1e-4)
