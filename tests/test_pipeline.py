"""The pipelined round loop (`serve/engine.py`, ``pipelined=True``).

Pins the dispatch/retire pipeline contract from the engine's module
docstring: pipelined decode is token-identical to the synchronous loop
for greedy, sampled and speculative lanes across slot counts; an EOS
landing during the one-round readback lag trims exactly the overrun
token's pages (refcounts conserved, nothing past the EOS ever emitted);
mutation rounds are barriers whose fused page-op flush dispatches only
against retired state; cost attribution still sums to exactly one step
dispatch per round; and retire-time emission timestamps keep
TTFT/inter-token latencies sane."""
import numpy as np
import pytest

from repro.obs import costs as obs_costs
from repro.serve import steps as serve_steps
from repro.serve.engine import Request, ServeEngine
from repro.serve.sampling import SamplingParams

PAGE = 8
MAX_LEN = 48


def _reqs(n=6, max_new=6, seed=3, vocab=64, eos_id=None, sampling=None):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(2, vocab, int(u)).astype(np.int32),
                    max_new_tokens=max_new, eos_id=eos_id,
                    sampling=sampling)
            for i, u in enumerate(rng.integers(4, 14, size=n))]


def _engine(cfg, params, *, slots, **kw):
    return ServeEngine(cfg, params, slots=slots, max_len=MAX_LEN,
                       page_size=PAGE, **kw)


def _pool_at_rest(eng):
    pool = eng._pool
    pool.check_tables()
    held = 0
    if eng.prefix_cache is not None:
        eng.prefix_cache.check_invariants()
        held = sum(1 for _ in eng.prefix_cache._nodes)
    assert pool.free_count == pool.n_pages - held


# ==========================================================================
# token parity: pipelined == sync across lane types and slot counts
# ==========================================================================
@pytest.mark.parametrize("slots", [1, 4, 8])
@pytest.mark.parametrize("lane", ["greedy", "sampled", "speculative"])
def test_pipelined_token_parity(serve_cfg, serve_params, slots, lane):
    kw = {}
    sp = None
    if lane == "sampled":
        sp = SamplingParams(temperature=0.8, top_k=8, top_p=0.9, seed=11)
    elif lane == "speculative":
        kw["speculative_k"] = 3
    sync = _engine(serve_cfg, serve_params, slots=slots, **kw)
    out_s = sync.run(_reqs(sampling=sp))
    pipe = _engine(serve_cfg, serve_params, slots=slots, pipelined=True,
                   **kw)
    out_p = pipe.run(_reqs(sampling=sp))
    assert [r.out_tokens for r in out_s] == [r.out_tokens for r in out_p]
    assert sync.stats.pipelined_rounds == 0
    assert "round/retire" not in sync.stats.phase_seconds
    if lane == "speculative":
        # drafting needs retired host history: verify rounds never
        # overlap (speculative greedy == greedy, so parity holds above)
        assert pipe.stats.pipelined_rounds == 0
    else:
        assert pipe.stats.pipelined_rounds > 0
        assert 0 < pipe.stats.pipeline_overlap <= 1
        assert pipe.stats.phase_seconds.get("round/retire", 0) > 0
        assert pipe.stats.phase_seconds.get("round/dispatch", 0) > 0
    # tokens_out / emission bookkeeping unchanged by the pipeline
    assert pipe.stats.tokens_out == sync.stats.tokens_out
    _pool_at_rest(pipe)


# ==========================================================================
# EOS during the lag: exactly the overrun token trimmed, never emitted
# ==========================================================================
def _probe_eos(cfg, params, max_new=8):
    """A token the greedy stream repeats mid-run — the first token whose
    first occurrence lands in [2, 6), so an EOS cut happens while the
    pipeline has a round in flight."""
    probe = _engine(cfg, params, slots=1)
    out = probe.run([Request(uid=0,
                             prompt=np.arange(2, 12, dtype=np.int32),
                             max_new_tokens=max_new)])
    toks = out[0].out_tokens
    for t in toks:
        if 2 <= toks.index(t) < 6:
            return t
    pytest.skip("greedy stream has no mid-run token to use as EOS")


@pytest.mark.parametrize("slots", [1, 4])
def test_eos_during_lag_trims_overrun(serve_cfg, serve_params, slots):
    eos = _probe_eos(serve_cfg, serve_params)
    reqs = lambda: [Request(uid=0, prompt=np.arange(2, 12, dtype=np.int32),
                            max_new_tokens=8, eos_id=eos)]
    sync = _engine(serve_cfg, serve_params, slots=slots)
    out_s = sync.run(reqs())
    pipe = _engine(serve_cfg, serve_params, slots=slots, pipelined=True)
    out_p = pipe.run(reqs())
    assert out_s[0].out_tokens == out_p[0].out_tokens
    assert out_p[0].out_tokens[-1] == eos
    # the single lane overran by exactly the one in-flight token —
    # budget/capacity finishes are predicted at dispatch, only the EOS
    # is not
    assert pipe.stats.lag_trimmed_tokens == 1
    assert pipe.stats.tokens_out == sync.stats.tokens_out
    _pool_at_rest(pipe)


def test_eos_during_lag_multi_lane(serve_cfg, serve_params):
    """Several lanes cutting at EOS mid-flight: parity + a clean pool."""
    eos = _probe_eos(serve_cfg, serve_params)
    sync = _engine(serve_cfg, serve_params, slots=4)
    out_s = sync.run(_reqs(max_new=8, eos_id=eos))
    pipe = _engine(serve_cfg, serve_params, slots=4, pipelined=True)
    out_p = pipe.run(_reqs(max_new=8, eos_id=eos))
    assert [r.out_tokens for r in out_s] == [r.out_tokens for r in out_p]
    _pool_at_rest(pipe)


# ==========================================================================
# barriers: mutation rounds drain first, flushes precede their step
# ==========================================================================
def test_barrier_rounds_flush_before_dispatch(serve_cfg, serve_params):
    """With more requests than slots, admission rounds interleave with
    pipelined decode. Every fused apply_page_ops flush must be followed
    by the step dispatch it serviced before any further flush (the
    flush-then-step pairing the sync engine guarantees), and the engine
    must still both pipeline and barrier."""
    calls = []
    eng = _engine(serve_cfg, serve_params, slots=2, pipelined=True)
    eng._ensure_pool()
    for name in ("step", "solo_step", "apply_page_ops"):
        real = getattr(eng._steps, name)

        def spy(*a, _real=real, _n=name, **k):
            calls.append("step" if _n != "apply_page_ops" else "flush")
            return _real(*a, **k)

        object.__setattr__(eng._steps, name, spy)
    out_p = eng.run(_reqs(n=6, max_new=6))
    sync = _engine(serve_cfg, serve_params, slots=2)
    out_s = sync.run(_reqs(n=6, max_new=6))
    assert [r.out_tokens for r in out_s] == [r.out_tokens for r in out_p]
    assert eng.stats.pipelined_rounds > 0
    assert eng.stats.pipeline_barriers > 0
    for i, c in enumerate(calls):
        if c == "flush":
            assert i + 1 < len(calls) and calls[i + 1] == "step", \
                f"flush at {i} not followed by its step: {calls}"
    _pool_at_rest(eng)


# ==========================================================================
# cost attribution: still exactly one attributed step dispatch per round
# ==========================================================================
def test_pipelined_one_dispatch_per_round(serve_cfg, serve_params):
    prev = obs_costs.enable_capture()
    try:
        eng = _engine(serve_cfg, serve_params, slots=4, pipelined=True)
        eng.run(_reqs())
    finally:
        obs_costs.enable_capture(prev)
    rep = eng.last_cost_report
    assert rep is not None
    step_rows = [r for r in rep.fns if r.fn in ("step", "solo_step")]
    assert sum(r.calls for r in step_rows) == eng.stats.rounds
    # capture mode makes step calls synchronous inside the wrapper; the
    # loop degrades gracefully but still accounts one dispatch per round
    assert rep.tokens_out == eng.stats.tokens_out


# ==========================================================================
# retire-time latency accounting
# ==========================================================================
def test_retire_time_latency_sane(serve_cfg, serve_params):
    eng = _engine(serve_cfg, serve_params, slots=4, pipelined=True)
    out = eng.run(_reqs())
    s = eng.stats
    assert len(s.ttft_s) == len(out)
    assert all(t >= 0 for t in s.ttft_s)
    assert all(g >= 0 for g in s.itl_s())
    # every emission stamped: one timestamp per emitted token per uid
    for r in out:
        assert len(s.emit_times[r.uid]) == len(r.out_tokens)


# ==========================================================================
# device-token carry never adds a compiled shape
# ==========================================================================
def test_carry_adds_no_compiled_shapes(serve_cfg, serve_params):
    eng = _engine(serve_cfg, serve_params, slots=4, pipelined=True)
    eng._ensure_pool()
    eng.run(_reqs())
    first = eng.stats.jit_compiles
    eng2 = _engine(serve_cfg, serve_params, slots=4, pipelined=True,
                   step_set=eng._steps)
    eng2.run(_reqs(seed=7))
    assert eng2.stats.jit_compiles == 0, \
        "pipelined carry retraced a warm step set"
    assert eng2.stats.pipelined_rounds > 0
    assert first >= 0
    # and the carry helper's contract directly: slot slicing only when
    # the previous round was batched
    import jax.numpy as jnp
    prev = jnp.arange(8, dtype=jnp.int32).reshape(4, 2)
    assert serve_steps.carry_decode_tokens(prev, None) is prev
    row = serve_steps.carry_decode_tokens(prev, 2)
    assert row.shape == (1, 2) and int(row[0, 0]) == 4
    solo_prev = prev[:1]
    assert serve_steps.carry_decode_tokens(solo_prev, 3) is solo_prev
