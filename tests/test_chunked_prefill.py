"""Chunked-prefill serving: scheduler invariants + engine semantics.

The refactor that left ONE ragged attention path also rebased prefill
onto fixed-size chunks that co-schedule with decode lanes. This file pins
the scheduling contract: the per-round chunk budget is never exceeded
after a round's first grant, first tokens arrive in FIFO admission order
for equal work, a lane preempted mid-prompt releases exactly the pages
its chunks wrote (refcount-clean pool at rest), prefix-cache hits prefill
only their chunked suffix with unchanged greedy outputs, decode lanes
keep emitting between a long prompt's chunks, and greedy decode is
token-identical across ANY chunk size (and to the pre-refactor
monolithic semantics via the legacy per-slot engine) with bitwise-
identical published KV pages.
"""
import jax
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.model import init_params
from repro.serve import engine as engine_mod
from repro.serve.engine import LegacyServeEngine, Request, ServeEngine
from repro.serve.scheduler import FifoScheduler, SchedulerConfig

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab=64)
CFG = ModelConfig(name="t", family="dense", **BASE)
CFG_INT8 = ModelConfig(name="t8", family="dense", kv_cache_quant=True,
                       **BASE)
PAGE = 8


@pytest.fixture(scope="module")
def params(serve_cfg, serve_params):
    assert serve_cfg == CFG
    return serve_params


@pytest.fixture(scope="module")
def params_int8(serve_cfg_int8, serve_params_int8):
    assert serve_cfg_int8 == CFG_INT8
    return serve_params_int8


def _reqs(n=4, lo=4, hi=14, max_new=5, seed=5, vocab=64):
    rng = np.random.default_rng(seed)
    return [Request(uid=i, prompt=rng.integers(2, vocab, int(L)).astype(
        np.int32), max_new_tokens=max_new)
        for i, L in enumerate(rng.integers(lo, hi, size=n))]


def _clone(reqs):
    return [Request(uid=r.uid, prompt=r.prompt,
                    max_new_tokens=r.max_new_tokens, eos_id=r.eos_id)
            for r in reqs]


# -------------------------------------------------------------------------
# budget invariant: never exceeded after the first chunk of a round
# -------------------------------------------------------------------------
class _RecordingScheduler(FifoScheduler):
    """Records (round, grant) pairs so the engine's real grant stream can
    be audited against the budget invariant."""
    rounds = None

    def start_round(self):
        super().start_round()
        type(self).rounds.append([])

    def grant_chunk(self, n_remaining):
        n = super().grant_chunk(n_remaining)
        if n:
            type(self).rounds[-1].append(n)
        return n


def test_chunk_budget_never_exceeded_after_first(params, monkeypatch):
    _RecordingScheduler.rounds = []
    monkeypatch.setattr(engine_mod, "FifoScheduler", _RecordingScheduler)
    budget = 12
    eng = ServeEngine(CFG, params, slots=4, max_len=64, page_size=PAGE,
                      chunk_tokens=PAGE, max_prefill_tokens=budget)
    eng.run(_reqs(n=6, lo=16, hi=30, max_new=3))
    rounds = [r for r in _RecordingScheduler.rounds if r]
    assert rounds, "no chunks were ever granted"
    for grants in rounds:
        # the first grant is budget-exempt (anti-deadlock); everything
        # after it must fit the round budget
        assert sum(grants[1:]) <= budget, grants
        assert all(g <= PAGE for g in grants)
    # the budget really throttled at least one round into multiple grants
    assert any(len(g) > 1 for g in rounds)
    assert eng.stats.prefill_chunks == sum(len(g) for g in rounds)


def test_wide_first_chunk_ignores_budget(params):
    """A chunk wider than the whole round budget still runs when it is
    the round's first grant — long prompts can never deadlock."""
    eng = ServeEngine(CFG, params, slots=2, max_len=64, page_size=PAGE,
                      chunk_tokens=32, max_prefill_tokens=8)
    reqs = _reqs(n=2, lo=30, hi=33, max_new=3)
    eng.run(reqs)
    assert all(r.done for r in reqs)


# -------------------------------------------------------------------------
# TTFT ordering under mixed decode+chunk rounds
# -------------------------------------------------------------------------
def test_ttft_follows_admission_order(params):
    """Equal-length prompts with a one-chunk-per-round budget: first
    tokens arrive strictly in FIFO admission (uid) order, even while
    earlier requests' decode lanes co-schedule with later chunks."""
    rng = np.random.default_rng(9)
    reqs = [Request(uid=i, prompt=rng.integers(2, 64, 24).astype(np.int32),
                    max_new_tokens=8) for i in range(4)]
    first_seen = []
    eng = ServeEngine(CFG, params, slots=2, max_len=64, page_size=PAGE,
                      chunk_tokens=PAGE, max_prefill_tokens=PAGE)
    eng.run(reqs, on_token=lambda s, tok, req:
            first_seen.append(req.uid) if req.uid not in first_seen
            else None)
    assert first_seen == [0, 1, 2, 3]
    assert len(eng.stats.ttft_s) == 4
    # chunked prefill interleaved with decode: tokens flowed to earlier
    # lanes while later prompts were still chunking
    assert eng.stats.decode_steps > 0


def test_decode_lanes_progress_between_chunks(params):
    """A long prompt's chunks co-schedule with an active decode lane:
    the decoder receives tokens BEFORE the long prompt's first token."""
    rng = np.random.default_rng(3)
    short = Request(uid=0, prompt=rng.integers(2, 64, 4).astype(np.int32),
                    max_new_tokens=12)
    long_ = Request(uid=1, prompt=rng.integers(2, 64, 48).astype(np.int32),
                    max_new_tokens=4)
    stream = []
    eng = ServeEngine(CFG, params, slots=2, max_len=64, page_size=PAGE,
                      chunk_tokens=PAGE, max_prefill_tokens=PAGE)
    eng.run([short, long_], on_token=lambda s, tok, req:
            stream.append(req.uid))
    first_long = stream.index(1)
    assert stream[:first_long].count(0) >= 3, stream
    assert short.done and long_.done


# -------------------------------------------------------------------------
# preemption mid-prompt: exactly the chunk-written pages come back
# -------------------------------------------------------------------------
def test_mid_prompt_preemption_is_refcount_clean(params):
    """A pool too small for a growing decoder + a chunking prompt forces
    preemption mid-prompt; the preempted lane releases exactly the pages
    its chunks wrote (plus adopted refs), outputs stay identical to the
    legacy engine, and the pool is empty at rest."""
    rng = np.random.default_rng(17)
    reqs = [Request(uid=0, prompt=rng.integers(2, 64, 8).astype(np.int32),
                    max_new_tokens=24),
            Request(uid=1, prompt=rng.integers(2, 64, 24).astype(np.int32),
                    max_new_tokens=4)]
    legacy = _clone(reqs)
    LegacyServeEngine(CFG, params, slots=2, max_len=32).run(legacy)
    eng = ServeEngine(CFG, params, slots=2, max_len=32, page_size=PAGE,
                      n_pages=5, chunk_tokens=PAGE)
    eng.run(reqs)
    assert eng.stats.preemptions >= 1
    assert [r.out_tokens for r in legacy] == [r.out_tokens for r in reqs]
    pool = eng._pool
    assert pool.free_count == pool.n_pages          # refcount-clean
    assert pool.pinned_count == 0
    pool.check_tables()                             # no stale mappings


# -------------------------------------------------------------------------
# prefix-cache hit + chunked suffix parity
# -------------------------------------------------------------------------
@pytest.mark.parametrize("cfg_name", ["fp32", "int8"])
def test_prefix_hit_chunked_suffix_parity(cfg_name, params, params_int8):
    """Tenants sharing a system prompt, with the uncached suffix prefilled
    in chunks smaller than the suffix: greedy outputs match the cache-off
    engine and only suffix tokens are prefilled for the followers."""
    cfg = CFG if cfg_name == "fp32" else CFG_INT8
    p = params if cfg_name == "fp32" else params_int8
    rng = np.random.default_rng(23)
    sys_prompt = rng.integers(2, 64, 24).astype(np.int32)
    reqs = [Request(uid=i, prompt=np.concatenate(
        [sys_prompt, rng.integers(2, 64, 18)]).astype(np.int32),
        max_new_tokens=5) for i in range(5)]
    off = _clone(reqs)
    ServeEngine(cfg, p, slots=3, max_len=64, page_size=PAGE,
                chunk_tokens=PAGE).run(off)
    on = _clone(reqs)
    eng = ServeEngine(cfg, p, slots=3, max_len=64, page_size=PAGE,
                      chunk_tokens=PAGE, prefix_cache=True)
    eng.run(on)
    assert [r.out_tokens for r in off] == [r.out_tokens for r in on]
    s = eng.stats
    assert s.cache_hits >= 4                        # every follower hits
    assert s.cache_hit_tokens >= 4 * 24
    assert s.prefill_token_reduction > 0.3
    # suffix (18+ tokens) really was chunked: more chunks than prompts
    assert s.prefill_chunks > s.prefills


# -------------------------------------------------------------------------
# any chunk size == monolithic == legacy, token for token; published KV
# pages bitwise-identical across chunk sizes
# -------------------------------------------------------------------------
@pytest.mark.parametrize("cfg_name", ["fp32", "int8"])
def test_chunk_size_invariance_tokens_and_pages(cfg_name, params,
                                                params_int8):
    cfg = CFG if cfg_name == "fp32" else CFG_INT8
    p = params if cfg_name == "fp32" else params_int8
    rng = np.random.default_rng(29)
    prompt = rng.integers(2, 64, 27).astype(np.int32)

    def run(chunk):
        req = Request(uid=0, prompt=prompt.copy(), max_new_tokens=4)
        eng = ServeEngine(cfg, p, slots=1, max_len=48, page_size=PAGE,
                          chunk_tokens=chunk, prefix_cache=True)
        eng.run([req])
        # published full-page prompt KV, in logical page order
        ids, n = eng.prefix_cache.match(prompt)
        assert n == (len(prompt) // PAGE) * PAGE
        pages = {}
        for key, grp in eng._arena.items():
            for name, leaf in grp["attn"].items():
                if name.endswith("_pages"):
                    pages[f"{key}/{name}"] = np.asarray(leaf[:, ids])
        return req.out_tokens, pages

    legacy_req = Request(uid=0, prompt=prompt.copy(), max_new_tokens=4)
    LegacyServeEngine(cfg, p, slots=1, max_len=48).run([legacy_req])
    ref_toks, ref_pages = run(64)                   # monolithic
    assert ref_toks == legacy_req.out_tokens        # pre-refactor parity
    for chunk in (1, 3, PAGE, PAGE + 3, 2 * PAGE):
        toks, pages = run(chunk)
        assert toks == ref_toks, chunk
        assert pages.keys() == ref_pages.keys()
        for name in pages:
            np.testing.assert_array_equal(pages[name], ref_pages[name],
                                          err_msg=f"chunk={chunk} {name}")


@pytest.mark.kernel
def test_chunk_size_invariance_through_kernel(params):
    """Same invariance with every step's attention on the ragged Pallas
    kernel, and the kernel really streamed fewer pages than full width."""
    reqs = _reqs(n=4, lo=10, hi=26, max_new=5, seed=31)
    ref = _clone(reqs)
    ServeEngine(CFG, params, slots=2, max_len=32, page_size=PAGE).run(ref)
    for chunk in (PAGE, 2 * PAGE):
        got = _clone(reqs)
        eng = ServeEngine(CFG, params, slots=2, max_len=32, page_size=PAGE,
                          chunk_tokens=chunk, paged_attention=True)
        eng.run(got)
        assert [r.out_tokens for r in ref] == [r.out_tokens for r in got]
        s = eng.stats
        assert 0 < s.kv_pages_live < s.kv_pages_full
        assert s.prefill_kv_pages_live > 0
        assert s.prefill_kv_pages_written > 0


# -------------------------------------------------------------------------
# step-shape bound: the compile surface is {1} + the pow2 width ladder
# (steps.width_ladder), never an unbounded bucket zoo
# -------------------------------------------------------------------------
def test_width_ladder_pinned():
    """The compiled-width set is pinned: pow2 rungs with a floor of 4 —
    the sub-8 rung serves short speculative verify steps (1 + k columns
    at k < 7 used to pad to 8) and short prefill tails alike."""
    from repro.serve.steps import width_ladder
    assert width_ladder(64) == (4, 8, 16, 32, 64)
    assert width_ladder(16) == (4, 8, 16)
    assert width_ladder(8) == (4, 8)
    assert width_ladder(4) == (4,)
    assert width_ladder(3) == (3,)
    assert width_ladder(1) == ()


def test_step_widths_bounded_to_ladder(params, monkeypatch):
    from repro.serve import steps as serve_steps
    chunk = 2 * PAGE
    eng = ServeEngine(CFG, params, slots=4, max_len=64, page_size=PAGE,
                      chunk_tokens=chunk)
    eng._ensure_pool()
    widths = set()
    real_step = eng._steps.step

    def spy(params_, toks, arena, start, n_new, samp):
        widths.add(toks.shape[1])
        return real_step(params_, toks, arena, start, n_new, samp)

    object.__setattr__(eng._steps, "step", spy)
    eng.run(_reqs(n=8, lo=4, hi=30, max_new=4, seed=37))
    ladder = serve_steps.width_ladder(chunk)
    assert widths <= {1} | set(ladder), widths
    # decode and the full-chunk rung are both exercised; narrower rungs
    # appear only when a round's widest grant fits one (sub-chunk
    # tails no longer pad all the way up to chunk)
    assert {1, chunk} <= widths, widths


# -------------------------------------------------------------------------
# hybrid (SSM) stacks: idle lanes in mixed rounds are state-neutral
# -------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 3, 7, 11])
def test_hybrid_chunked_idle_lane_state_neutral(seed):
    """A decode lane idling through another prompt's chunk rounds
    (n_new = 0 — hybrid stacks cannot co-schedule) must not advance its
    SSM/conv state on the padding token. The 17-token prompt forces a
    1-token final chunk, i.e. a C = 1 chunk round through mamba's s == 1
    recurrence — the path that once ignored ``valid_len`` and corrupted
    the idle lane (caught in review: divergent greedy tokens on 11/12
    seeds before the fix)."""
    cfg = ModelConfig(name="th", family="hybrid", pattern=("hybrid",),
                      d_state=16, ssm_headdim=32, **BASE)
    p = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    reqs = [Request(uid=0, prompt=rng.integers(2, 64, 5).astype(np.int32),
                    max_new_tokens=10),
            Request(uid=1, prompt=rng.integers(2, 64, 17).astype(np.int32),
                    max_new_tokens=4)]
    legacy = _clone(reqs)
    LegacyServeEngine(cfg, p, slots=2, max_len=32).run(legacy)
    got = _clone(reqs)
    ServeEngine(cfg, p, slots=2, max_len=32, page_size=PAGE,
                chunk_tokens=16).run(got)
    assert [r.out_tokens for r in legacy] == [r.out_tokens for r in got]


# -------------------------------------------------------------------------
# in-flight dedup rebased onto chunk boundaries: followers wait for the
# leader's prefill to complete, then alias fully-written pages only
# -------------------------------------------------------------------------
def test_dedup_waits_for_chunking_leader(params):
    rng = np.random.default_rng(41)
    shared = rng.integers(2, 64, 24).astype(np.int32)
    reqs = [Request(uid=i, prompt=shared.copy(), max_new_tokens=4)
            for i in range(3)]
    legacy = _clone(reqs)
    LegacyServeEngine(CFG, params, slots=3, max_len=48).run(legacy)
    eng = ServeEngine(CFG, params, slots=3, max_len=48, page_size=PAGE,
                      chunk_tokens=PAGE)       # leader needs 3 chunks
    eng.run(reqs)
    assert eng.stats.dedup_hits == 2
    # whole-prompt hit: the final token recomputes, so 23 of 24 tokens
    # come from the leader's pages per follower
    assert eng.stats.cache_hit_tokens == 2 * 23
    # followers never re-prefilled the shared pages
    assert eng.stats.prefill_tokens == 24 + 2 * 1  # leader + recomputes
    assert [r.out_tokens for r in legacy] == [r.out_tokens for r in reqs]
