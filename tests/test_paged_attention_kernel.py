"""Differential harness for the ragged Pallas paged-attention kernel.

Parity sweeps of ``kernels/paged_attention.py`` (interpret mode — the real
kernel body runs on CPU) against the XLA reference path
(``paged_cache_read`` + ``attend``): the decode view across page sizes,
GQA ratios, KV dtypes and ragged per-lane lengths (len 0 / len < page /
page-boundary / parked-on-null-page lanes), and the multi-query (ragged)
view across chunk lengths {1, sub-page, page-boundary, multi-page} x GQA
x KV dtype, with causal-mask edges at arbitrary chunk-start positions.
Two hypothesis properties: physical page placement is invisible (bitwise),
and splitting a prompt into ANY chunking yields bitwise-identical final
KV pages (and outputs to fp roundoff) vs one-shot prefill. Also pins the
null-page aliasing guard: a corrupted block table raises instead of
silently attending garbage.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from conftest import (SERVE_BASE, make_paged_case, make_ragged_case,
                      paged_reference, ragged_reference)
from repro.kernels.paged_attention import (paged_decode_attention,
                                           ragged_paged_attention,
                                           shard_compatible)
from repro.models.config import ModelConfig
from repro.serve.paged_kv import PageAccountingError, PagedKVPool

CFG = ModelConfig(name="t", family="dense", **SERVE_BASE)
N_KV, HD = 2, 16
TOL = dict(atol=3e-6, rtol=3e-6)


def _seqs(page):
    """Ragged lengths: parked lane (0), sub-page, page-boundary, boundary
    +/- 1, and a multi-page tail."""
    return (0, 1, page - 1, page, page + 1, 2 * page, 3 * page)


def _run(q, cache, seq, **kw):
    return paged_decode_attention(q, cache, seq, n_kv=N_KV, head_dim=HD,
                                  **kw)


# -------------------------------------------------------------------------
# the parity sweep
# -------------------------------------------------------------------------
@pytest.mark.kernel
@pytest.mark.parametrize("quantized", [False, True], ids=["fp32", "int8kv"])
@pytest.mark.parametrize("gqa", [1, 2, 4])
@pytest.mark.parametrize("page", [8, 16])
def test_kernel_matches_reference_gather(page, gqa, quantized):
    rng = np.random.default_rng(page * 10 + gqa + quantized)
    q, cache, seq = make_paged_case(rng, page=page, n_kv=N_KV, gqa=gqa,
                                    hd=HD, quantized=quantized,
                                    seq_lens=_seqs(page))
    out = _run(q, cache, seq)
    ref = paged_reference(q, cache, seq, n_kv=N_KV, hd=HD)
    act = np.asarray(seq) > 0
    np.testing.assert_allclose(np.asarray(out)[act], np.asarray(ref)[act],
                               **TOL)
    # parked lanes (all-null table, seq 0) emit exactly zero — they never
    # see the poisoned null page the reference averages garbage over
    assert np.all(np.asarray(out)[~act] == 0.0)


@pytest.mark.kernel
@pytest.mark.parametrize("window,softcap", [(4, None), (None, 30.0),
                                            (4, 30.0)])
def test_kernel_window_and_softcap(window, softcap):
    rng = np.random.default_rng(17)
    q, cache, seq = make_paged_case(rng, page=8, gqa=2, hd=HD,
                                    seq_lens=_seqs(8))
    out = _run(q, cache, seq, window=window, attn_softcap=softcap)
    ref = paged_reference(q, cache, seq, n_kv=N_KV, hd=HD, window=window,
                          attn_softcap=softcap)
    act = np.asarray(seq) > 0
    np.testing.assert_allclose(np.asarray(out)[act], np.asarray(ref)[act],
                               **TOL)


@pytest.mark.kernel
def test_kernel_rejects_multi_token_queries():
    rng = np.random.default_rng(3)
    q, cache, seq = make_paged_case(rng, page=8, gqa=2, hd=HD,
                                    seq_lens=(8, 16))
    q2 = jnp.concatenate([q, q], axis=1)            # S=2: prefill shape
    with pytest.raises(ValueError):
        _run(q2, cache, seq)


# -------------------------------------------------------------------------
# ragged (multi-query) sweep: chunk lengths x GQA x dtype
# -------------------------------------------------------------------------
def _chunk_lanes(page):
    """(q_start, n_new) lanes covering the chunked-prefill shapes: a dead
    lane, a 1-token chunk (decode / whole-prompt-hit recompute), sub-page
    and page-boundary chunks from position 0, chunks starting mid-page
    and at a page boundary (the suffix-after-prefix-hit edge), and a
    multi-page chunk."""
    return ((0, 0),                      # idle lane
            (0, 1), (2 * page, 1),       # 1-token chunks
            (0, page - 1),               # sub-page
            (0, page),                   # page boundary
            (3, page),                   # chunk starts mid-page
            (page, page + 1),            # starts at a page boundary
            (1, 3 * page))               # multi-page


def _assert_ragged_parity(q, cache, q_start, n_new, **kw):
    out = np.asarray(ragged_paged_attention(
        q, cache, q_start, n_new.astype(jnp.int32) + q_start,
        n_kv=N_KV, head_dim=HD, **kw))
    ref = np.asarray(ragged_reference(q, cache, q_start, n_new,
                                      n_kv=N_KV, hd=HD, **kw))
    for b, n in enumerate(np.asarray(n_new)):
        if n:
            np.testing.assert_allclose(out[b, :n], ref[b, :n], **TOL)
        # rows past the lane's chunk (and whole idle lanes) are exactly 0
        assert np.all(out[b, n:] == 0.0), (b, n)


@pytest.mark.kernel
@pytest.mark.parametrize("quantized", [False, True], ids=["fp32", "int8kv"])
@pytest.mark.parametrize("gqa", [1, 2, 4])
@pytest.mark.parametrize("page", [8, 16])
def test_ragged_kernel_matches_reference_gather(page, gqa, quantized):
    rng = np.random.default_rng(100 + page * 10 + gqa + quantized)
    q, cache, q_start, n_new = make_ragged_case(
        rng, page=page, n_kv=N_KV, gqa=gqa, hd=HD, quantized=quantized,
        lanes=_chunk_lanes(page))
    _assert_ragged_parity(q, cache, q_start, n_new)


@pytest.mark.kernel
@pytest.mark.parametrize("q_block", [1, 2, 16])
def test_ragged_kernel_q_block_sizes(q_block):
    """The q-block grid axis is a pure tiling choice — any block size
    matches the reference."""
    rng = np.random.default_rng(41)
    q, cache, q_start, n_new = make_ragged_case(
        rng, page=8, n_kv=N_KV, gqa=2, hd=HD, lanes=_chunk_lanes(8))
    out = np.asarray(ragged_paged_attention(
        q, cache, q_start, q_start + n_new.astype(jnp.int32),
        n_kv=N_KV, head_dim=HD, q_block=q_block))
    ref = np.asarray(ragged_reference(q, cache, q_start, n_new,
                                      n_kv=N_KV, hd=HD))
    for b, n in enumerate(np.asarray(n_new)):
        if n:
            np.testing.assert_allclose(out[b, :n], ref[b, :n], **TOL)


@pytest.mark.kernel
@pytest.mark.parametrize("window,softcap", [(4, None), (None, 30.0),
                                            (4, 30.0)])
def test_ragged_kernel_window_and_softcap(window, softcap):
    rng = np.random.default_rng(43)
    q, cache, q_start, n_new = make_ragged_case(
        rng, page=8, n_kv=N_KV, gqa=2, hd=HD, lanes=_chunk_lanes(8))
    _assert_ragged_parity(q, cache, q_start, n_new, window=window,
                          attn_softcap=softcap)


@pytest.mark.kernel
def test_ragged_causal_edge_at_chunk_start():
    """The first query of a chunk starting mid-page must attend exactly
    its q_start + 1 causally-visible positions — no leakage from the
    chunk's own later tokens sharing its page."""
    rng = np.random.default_rng(7)
    page, start, n = 8, 5, 6             # chunk [5, 11) spans a boundary
    q, cache, q_start, n_new = make_ragged_case(
        rng, page=page, n_kv=N_KV, gqa=2, hd=HD, lanes=((start, n),))
    out = ragged_paged_attention(q, cache, q_start, q_start + n_new,
                                 n_kv=N_KV, head_dim=HD)
    # recompute each chunk row as a 1-token decode at its position: the
    # decode view masks strictly by seq, so equality proves the ragged
    # causal mask admits exactly positions <= q_start + t per row
    for t in range(n):
        one = paged_decode_attention(
            q[:, t:t + 1], cache, jnp.asarray([start + t + 1], jnp.int32),
            n_kv=N_KV, head_dim=HD)
        np.testing.assert_array_equal(np.asarray(one[0, 0]),
                                      np.asarray(out[0, t]))


# -------------------------------------------------------------------------
# hypothesis: any chunking == one-shot prefill, bit for bit
# -------------------------------------------------------------------------
@pytest.mark.kernel
@pytest.mark.parametrize("quantized", [False, True], ids=["fp32", "int8kv"])
def test_chunk_splitting_invariance(quantized):
    """Scattering a prompt's K/V chunk-by-chunk (``paged_cache_write``)
    and attending each chunk through the ragged kernel yields bitwise-
    identical arena pages vs one-shot prefill, for ANY chunking — the KV
    state the memory co-design charges is chunking-invariant. Per-token
    outputs agree to fp32 roundoff (~1e-7: XLA reassociates the score
    matmul's reduction differently per traced chunk width — no kernel
    can pin that across shapes), which is why greedy TOKEN identity, not
    logit-bit identity, is the end-to-end contract
    (``tests/test_chunked_prefill.py`` pins it through the engine)."""
    pytest.importorskip(
        "hypothesis", reason="property test needs hypothesis")
    import hypothesis.strategies as st
    from hypothesis import given, settings

    from repro.models.attention import paged_cache_write

    page, L, hd, gqa = 8, 20, HD, 2
    rng = np.random.default_rng(11)
    n_pages = 1 + -(-L // page) + 1
    kvd = N_KV * hd
    k_tok = rng.standard_normal((1, L, N_KV, hd)).astype(np.float32)
    v_tok = rng.standard_normal((1, L, N_KV, hd)).astype(np.float32)
    q_tok = jnp.asarray(rng.standard_normal(
        (1, L, N_KV * gqa, hd)).astype(np.float32))
    tbl = np.zeros((1, n_pages - 1), np.int32)
    tbl[0, : -(-L // page)] = np.arange(1, -(-L // page) + 1)

    def fresh_cache():
        c = {"block_tbl": jnp.asarray(tbl)}
        if quantized:
            c.update(
                k_pages=jnp.zeros((n_pages, page, kvd), jnp.int8),
                v_pages=jnp.zeros((n_pages, page, kvd), jnp.int8),
                k_scale_pages=jnp.zeros((n_pages, page, N_KV),
                                        jnp.bfloat16),
                v_scale_pages=jnp.zeros((n_pages, page, N_KV),
                                        jnp.bfloat16))
        else:
            c.update(k_pages=jnp.zeros((n_pages, page, kvd), jnp.float32),
                     v_pages=jnp.zeros((n_pages, page, kvd), jnp.float32))
        return c

    def prefill(chunks):
        cache = fresh_cache()
        outs = []
        s0 = 0
        for n in chunks:
            positions = jnp.asarray([list(range(s0, s0 + n))], jnp.int32)
            cache = paged_cache_write(
                cache, jnp.asarray(k_tok[:, s0:s0 + n]),
                jnp.asarray(v_tok[:, s0:s0 + n]), positions,
                valid_len=jnp.asarray([s0 + n], jnp.int32))
            o = ragged_paged_attention(
                q_tok[:, s0:s0 + n], cache,
                jnp.asarray([s0], jnp.int32),
                jnp.asarray([s0 + n], jnp.int32), n_kv=N_KV, head_dim=hd)
            outs.append(np.asarray(o[0]))
            s0 += n
        return cache, np.concatenate(outs, axis=0)

    ref_cache, ref_out = prefill([L])

    @settings(max_examples=8, deadline=None)
    @given(st.lists(st.integers(1, L), min_size=1, max_size=L))
    def check(sizes):
        chunks, total = [], 0
        for n in sizes:                  # normalize to an exact chunking
            n = min(n, L - total)
            if n <= 0:
                break
            chunks.append(n)
            total += n
        if total < L:
            chunks.append(L - total)
        cache, out = prefill(chunks)
        for name in ref_cache:
            np.testing.assert_array_equal(np.asarray(cache[name]),
                                          np.asarray(ref_cache[name]),
                                          err_msg=name)
        np.testing.assert_allclose(out, ref_out, atol=1e-5, rtol=1e-5)

    check()


# -------------------------------------------------------------------------
# hypothesis: physical page placement is invisible
# -------------------------------------------------------------------------
@pytest.mark.kernel
def test_block_table_permutation_invariance():
    pytest.importorskip(
        "hypothesis", reason="property test needs hypothesis")
    import hypothesis.strategies as st
    from hypothesis import given, settings

    rng = np.random.default_rng(23)
    q, cache, seq = make_paged_case(rng, page=8, gqa=2, hd=HD,
                                    seq_lens=_seqs(8))
    base = np.asarray(_run(q, cache, seq))
    n_pages = cache["k_pages"].shape[0]

    @settings(max_examples=15, deadline=None)
    @given(st.permutations(list(range(1, n_pages))))
    def check(perm):
        # relocate page i -> mapping[i] (null page 0 stays put) and
        # rewrite the tables to match: outputs must be bit-identical
        mapping = np.concatenate([[0], np.asarray(perm)])
        inv = np.argsort(mapping)
        moved = {"block_tbl": jnp.asarray(
            mapping[np.asarray(cache["block_tbl"])])}
        for name, leaf in cache.items():
            if name.endswith("_pages"):
                moved[name] = jnp.asarray(np.asarray(leaf)[inv])
        out = np.asarray(_run(q, moved, seq))
        np.testing.assert_array_equal(out, base)

    check()


# -------------------------------------------------------------------------
# null-page aliasing guard (host-side): corruption is loud, not silent
# -------------------------------------------------------------------------
def _pool(**kw):
    return PagedKVPool(CFG, n_pages=8, page=8, max_slots=2,
                       max_pages_per_seq=4, **kw)


def test_corrupted_table_null_in_live_region_raises():
    pool = _pool()
    pool.ensure(0, 20)                               # 3 live pages
    pool.block_tables[0, 1] = 0                      # corrupt: null aliased
    with pytest.raises(PageAccountingError):
        pool.check_tables()
    with pytest.raises(PageAccountingError):         # guard runs on every
        pool.install_tables(pool.init_arena())       # table install


def test_corrupted_table_stale_tail_raises():
    pool = _pool()
    pool.ensure(0, 10)                               # 2 live pages
    pool.block_tables[0, 3] = 5                      # ghost page past live
    with pytest.raises(PageAccountingError):
        pool.check_tables()


def test_corrupted_table_swapped_mapping_raises():
    pool = _pool()
    a = pool.ensure(0, 10)
    b = pool.ensure(1, 10)
    pool.block_tables[0, 0] = b[0]                   # points at slot 1's KV
    with pytest.raises(PageAccountingError):
        pool.check_tables()
    assert a[0] != b[0]


def test_adopt_rejects_null_page():
    pool = _pool()
    pool.ensure(0, 10)
    with pytest.raises(PageAccountingError):
        pool.adopt(1, [0])


def test_clean_tables_pass():
    pool = _pool()
    pool.ensure(0, 20)
    pool.ensure(1, 5)
    pool.check_tables()                              # no raise
    pool.free_slot(0)
    pool.check_tables()


# -------------------------------------------------------------------------
# mesh gate: geometries the shard-local kernel cannot honor are refused
# -------------------------------------------------------------------------
def test_shard_compatible_gate():
    class _Mesh:
        axis_names = ("data", "model")
        devices = np.empty((2, 2), dtype=object)
    assert shard_compatible(None, 33, 2)             # 1-device: anything
    assert shard_compatible(_Mesh(), 32, 2)          # 32 % 2, 2 % 2
    assert not shard_compatible(_Mesh(), 33, 2)      # pages don't divide
    assert not shard_compatible(_Mesh(), 32, 3)      # heads don't divide
