"""Differential harness for the Pallas paged-attention decode kernel.

Parity sweep of ``kernels/paged_attention.py`` (interpret mode — the real
kernel body runs on CPU) against the XLA reference path
(``paged_cache_read`` + ``attend``) across page sizes, GQA ratios, KV
dtypes and ragged per-lane lengths (len 0 / len < page / page-boundary /
parked-on-null-page lanes), plus a hypothesis property: permuting which
physical arena pages hold the data (and the block tables with them) is
output-invariant, bit for bit. Also pins the null-page aliasing guard:
a corrupted block table raises instead of silently attending garbage.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from conftest import SERVE_BASE, make_paged_case, paged_reference
from repro.kernels.paged_attention import (paged_decode_attention,
                                           shard_compatible)
from repro.models.config import ModelConfig
from repro.serve.paged_kv import PageAccountingError, PagedKVPool

CFG = ModelConfig(name="t", family="dense", **SERVE_BASE)
N_KV, HD = 2, 16
TOL = dict(atol=3e-6, rtol=3e-6)


def _seqs(page):
    """Ragged lengths: parked lane (0), sub-page, page-boundary, boundary
    +/- 1, and a multi-page tail."""
    return (0, 1, page - 1, page, page + 1, 2 * page, 3 * page)


def _run(q, cache, seq, **kw):
    return paged_decode_attention(q, cache, seq, n_kv=N_KV, head_dim=HD,
                                  **kw)


# -------------------------------------------------------------------------
# the parity sweep
# -------------------------------------------------------------------------
@pytest.mark.kernel
@pytest.mark.parametrize("quantized", [False, True], ids=["fp32", "int8kv"])
@pytest.mark.parametrize("gqa", [1, 2, 4])
@pytest.mark.parametrize("page", [8, 16])
def test_kernel_matches_reference_gather(page, gqa, quantized):
    rng = np.random.default_rng(page * 10 + gqa + quantized)
    q, cache, seq = make_paged_case(rng, page=page, n_kv=N_KV, gqa=gqa,
                                    hd=HD, quantized=quantized,
                                    seq_lens=_seqs(page))
    out = _run(q, cache, seq)
    ref = paged_reference(q, cache, seq, n_kv=N_KV, hd=HD)
    act = np.asarray(seq) > 0
    np.testing.assert_allclose(np.asarray(out)[act], np.asarray(ref)[act],
                               **TOL)
    # parked lanes (all-null table, seq 0) emit exactly zero — they never
    # see the poisoned null page the reference averages garbage over
    assert np.all(np.asarray(out)[~act] == 0.0)


@pytest.mark.kernel
@pytest.mark.parametrize("window,softcap", [(4, None), (None, 30.0),
                                            (4, 30.0)])
def test_kernel_window_and_softcap(window, softcap):
    rng = np.random.default_rng(17)
    q, cache, seq = make_paged_case(rng, page=8, gqa=2, hd=HD,
                                    seq_lens=_seqs(8))
    out = _run(q, cache, seq, window=window, attn_softcap=softcap)
    ref = paged_reference(q, cache, seq, n_kv=N_KV, hd=HD, window=window,
                          attn_softcap=softcap)
    act = np.asarray(seq) > 0
    np.testing.assert_allclose(np.asarray(out)[act], np.asarray(ref)[act],
                               **TOL)


@pytest.mark.kernel
def test_kernel_rejects_multi_token_queries():
    rng = np.random.default_rng(3)
    q, cache, seq = make_paged_case(rng, page=8, gqa=2, hd=HD,
                                    seq_lens=(8, 16))
    q2 = jnp.concatenate([q, q], axis=1)            # S=2: prefill shape
    with pytest.raises(ValueError):
        _run(q2, cache, seq)


# -------------------------------------------------------------------------
# hypothesis: physical page placement is invisible
# -------------------------------------------------------------------------
@pytest.mark.kernel
def test_block_table_permutation_invariance():
    pytest.importorskip(
        "hypothesis", reason="property test needs hypothesis")
    import hypothesis.strategies as st
    from hypothesis import given, settings

    rng = np.random.default_rng(23)
    q, cache, seq = make_paged_case(rng, page=8, gqa=2, hd=HD,
                                    seq_lens=_seqs(8))
    base = np.asarray(_run(q, cache, seq))
    n_pages = cache["k_pages"].shape[0]

    @settings(max_examples=15, deadline=None)
    @given(st.permutations(list(range(1, n_pages))))
    def check(perm):
        # relocate page i -> mapping[i] (null page 0 stays put) and
        # rewrite the tables to match: outputs must be bit-identical
        mapping = np.concatenate([[0], np.asarray(perm)])
        inv = np.argsort(mapping)
        moved = {"block_tbl": jnp.asarray(
            mapping[np.asarray(cache["block_tbl"])])}
        for name, leaf in cache.items():
            if name.endswith("_pages"):
                moved[name] = jnp.asarray(np.asarray(leaf)[inv])
        out = np.asarray(_run(q, moved, seq))
        np.testing.assert_array_equal(out, base)

    check()


# -------------------------------------------------------------------------
# null-page aliasing guard (host-side): corruption is loud, not silent
# -------------------------------------------------------------------------
def _pool(**kw):
    return PagedKVPool(CFG, n_pages=8, page=8, max_slots=2,
                       max_pages_per_seq=4, **kw)


def test_corrupted_table_null_in_live_region_raises():
    pool = _pool()
    pool.ensure(0, 20)                               # 3 live pages
    pool.block_tables[0, 1] = 0                      # corrupt: null aliased
    with pytest.raises(PageAccountingError):
        pool.check_tables()
    with pytest.raises(PageAccountingError):         # guard runs on every
        pool.install_tables(pool.init_arena())       # table install


def test_corrupted_table_stale_tail_raises():
    pool = _pool()
    pool.ensure(0, 10)                               # 2 live pages
    pool.block_tables[0, 3] = 5                      # ghost page past live
    with pytest.raises(PageAccountingError):
        pool.check_tables()


def test_corrupted_table_swapped_mapping_raises():
    pool = _pool()
    a = pool.ensure(0, 10)
    b = pool.ensure(1, 10)
    pool.block_tables[0, 0] = b[0]                   # points at slot 1's KV
    with pytest.raises(PageAccountingError):
        pool.check_tables()
    assert a[0] != b[0]


def test_adopt_rejects_null_page():
    pool = _pool()
    pool.ensure(0, 10)
    with pytest.raises(PageAccountingError):
        pool.adopt(1, [0])


def test_clean_tables_pass():
    pool = _pool()
    pool.ensure(0, 20)
    pool.ensure(1, 5)
    pool.check_tables()                              # no raise
    pool.free_slot(0)
    pool.check_tables()


# -------------------------------------------------------------------------
# mesh gate: geometries the shard-local kernel cannot honor are refused
# -------------------------------------------------------------------------
def test_shard_compatible_gate():
    class _Mesh:
        axis_names = ("data", "model")
        devices = np.empty((2, 2), dtype=object)
    assert shard_compatible(None, 33, 2)             # 1-device: anything
    assert shard_compatible(_Mesh(), 32, 2)          # 32 % 2, 2 % 2
    assert not shard_compatible(_Mesh(), 33, 2)      # pages don't divide
    assert not shard_compatible(_Mesh(), 32, 3)      # heads don't divide
