"""Batched page-ops, the solo-lane step and the serving weight plan
(`serve/engine.py` + `serve/steps.py:apply_page_ops`/`solo_step`).

The engine queues every COW copy / state reset / table update of a round
host-side and flushes them in ONE fused jit dispatch before the step.
These tests pin the contract: the fused path is token-identical to the
legacy one-dispatch-per-op path, strictly cheaper in host↔device round
trips, and conserves page refcounts (every live page's refcount equals
its slot mappings plus its prefix-cache hold; free pages are refcount 0).
Same file covers the B=1 solo-lane fast path and the one-time weight
execution plan (`core.serving_quant.build_exec_weights`) — both new ways
a round can reach the device, both required to be greedy-token-exact."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.qconfig import QMCConfig
from repro.core.serving_quant import quantize_for_serving
from repro.serve import steps as serve_steps
from repro.serve.engine import Request, ServeEngine

PAGE = 8
SLOTS = 4
MAX_LEN = 48


def _reqs(n=6, sys_len=24, max_new=5, seed=3, vocab=64):
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(2, vocab, sys_len)
    return [Request(uid=i,
                    prompt=np.concatenate(
                        [sys_prompt,
                         rng.integers(2, vocab, int(u))]).astype(np.int32),
                    max_new_tokens=max_new)
            for i, u in enumerate(rng.integers(4, 12, size=n))]


def _engine(cfg, params, *, step_set=None, **kw):
    return ServeEngine(cfg, params, slots=SLOTS, max_len=MAX_LEN,
                       page_size=PAGE, step_set=step_set, **kw)


def _legacy_steps(cfg):
    """The same step set the engine would build, with the fused flush and
    the solo lane stripped — forcing the one-dispatch-per-op path."""
    full = serve_steps.build_paged_steps(
        cfg, page=PAGE, n_pages=serve_steps.default_n_pages(
            SLOTS, MAX_LEN // PAGE),
        max_slots=SLOTS, max_pages_per_seq=MAX_LEN // PAGE)
    return dataclasses.replace(full, apply_page_ops=None, solo_step=None)


def _check_refcounts(eng):
    pool = eng._pool
    pool.check_tables()
    held = set()
    if eng.prefix_cache is not None:
        eng.prefix_cache.check_invariants()
        held = set(eng.prefix_cache._nodes)
    mapped = {}
    for pages in pool.slot_pages:
        for pid in pages:
            mapped[pid] = mapped.get(pid, 0) + 1
    for pid in range(1, pool.n_pages + 1):
        want = mapped.get(pid, 0) + (1 if pid in held else 0)
        assert pool.ref[pid] == want, \
            f"page {pid}: refcount {pool.ref[pid]} != " \
            f"{mapped.get(pid, 0)} mappings + {pid in held} cache hold"
        assert (pid in pool._free_set) == (want == 0)


def test_fused_page_ops_token_parity_and_fewer_round_trips(
        serve_cfg, serve_params):
    """Fused vs sequential page-ops on the shared-prefix workload:
    identical tokens, fewer device table rebuilds, refcounts conserved
    on both engines."""
    fused = _engine(serve_cfg, serve_params, prefix_cache=True)
    out_f = fused.run(_reqs())
    legacy = _engine(serve_cfg, serve_params, prefix_cache=True,
                     step_set=_legacy_steps(serve_cfg))
    out_l = legacy.run(_reqs())

    assert [r.out_tokens for r in out_f] == [r.out_tokens for r in out_l]
    assert fused.stats.page_op_flushes > 0
    assert legacy.stats.page_op_flushes == 0
    # the fused engine uploads tables only on mutation rounds; the
    # legacy path re-installs per admission event
    assert fused.stats.device_tables_rebuilds <= \
        legacy.stats.device_tables_rebuilds
    assert fused.stats.cache_hits > 0
    _check_refcounts(fused)
    _check_refcounts(legacy)


def test_fused_flush_batches_cow_copies(serve_cfg, serve_params):
    """A fully-cached prompt restarts mid-page (the last prompt token
    must be recomputed for its logit), writing into a shared page — the
    COW copy must ride the fused flush, not its own dispatch, and end
    with conserved refcounts."""
    rng = np.random.default_rng(11)
    base = rng.integers(2, 64, 16).astype(np.int32)   # 2 full pages of 8
    eng = _engine(serve_cfg, serve_params, prefix_cache=True)
    eng.run([Request(uid=0, prompt=base, max_new_tokens=4)])
    eng.run([Request(uid=1, prompt=base.copy(), max_new_tokens=4)])
    s = eng.stats
    assert s.cow_copies > 0
    assert s.page_copy_calls == s.cow_copies
    # every queued op was absorbed by a fused flush: ops batched counts
    # copies + resets + one table rebuild per flush, and no flush ran
    # without work or a dirty table
    assert s.page_ops_batched >= s.page_op_flushes + s.cow_copies
    _check_refcounts(eng)


def test_solo_step_parity(serve_cfg, serve_params):
    """A single in-flight request decodes through the B=1 solo lane —
    token-identical to the full-width batch step, and no dead-lane
    sentinel ever surfaces through on_token (the solo scatter used to
    fill dead lanes with vocab id 0, indistinguishable from a real
    emission; they now carry DEAD_TOKEN = -1 and must never escape)."""
    from repro.serve.sampling import DEAD_TOKEN
    prompt = np.arange(2, 12, dtype=np.int32)
    streamed = []
    solo = _engine(serve_cfg, serve_params)
    out_s = solo.run([Request(uid=0, prompt=prompt, max_new_tokens=6)],
                     on_token=lambda s, t, r: streamed.append(int(t)))
    batch = _engine(serve_cfg, serve_params,
                    step_set=_legacy_steps(serve_cfg))
    out_b = batch.run([Request(uid=0, prompt=prompt, max_new_tokens=6)])
    assert out_s[0].out_tokens == out_b[0].out_tokens
    assert solo.stats.solo_rounds > 0
    assert batch.stats.solo_rounds == 0
    assert DEAD_TOKEN not in streamed
    assert streamed == out_s[0].out_tokens


def test_solo_pipelined_parity(serve_cfg, serve_params):
    """The B=1 solo lane participates in the device-token carry: a solo
    pipelined run (carry is a passthrough — prev round's [1, C] output
    feeds the next solo step directly) and a batched pipelined run
    (legacy step set, carry slices the lane's row) both match the plain
    sync solo run token for token."""
    prompt = np.arange(2, 12, dtype=np.int32)
    mk = lambda: [Request(uid=0, prompt=prompt, max_new_tokens=8)]
    sync = _engine(serve_cfg, serve_params)
    out_sync = sync.run(mk())
    solo = _engine(serve_cfg, serve_params, pipelined=True)
    out_solo = solo.run(mk())
    batch = _engine(serve_cfg, serve_params, pipelined=True,
                    step_set=_legacy_steps(serve_cfg))
    out_batch = batch.run(mk())
    assert out_sync[0].out_tokens == out_solo[0].out_tokens
    assert out_sync[0].out_tokens == out_batch[0].out_tokens
    assert solo.stats.solo_rounds > 0
    assert solo.stats.pipelined_rounds > 0
    assert batch.stats.solo_rounds == 0
    assert batch.stats.pipelined_rounds > 0
    _check_refcounts(solo)
    _check_refcounts(batch)


def test_weight_plan_parity(serve_cfg, serve_params):
    """The one-time exec-weight lowering is greedy-token-identical to
    per-call stream compute, and a dense tree passes through untouched."""
    from repro.core.qtensor import QTensor
    from repro.core.qtensor_sharded import ShardedQTensor
    qparams = quantize_for_serving(
        serve_params, QMCConfig(rho=0.3, granularity="subtile"),
        tp_shards=1, min_dim=64)
    q_leaves = [l for l in jax.tree_util.tree_leaves(
        qparams, is_leaf=lambda x: isinstance(
            x, (QTensor, ShardedQTensor)))
        if isinstance(l, (QTensor, ShardedQTensor))]
    assert q_leaves, "config too small to quantize — test is vacuous"

    planned = _engine(serve_cfg, qparams)
    out_p = planned.run(_reqs())
    streamed = _engine(serve_cfg, qparams, weight_plan=False)
    out_s = streamed.run(_reqs())
    assert [r.out_tokens for r in out_p] == [r.out_tokens for r in out_s]
    # the plan lowered every stream leaf; dense engines pay nothing
    assert planned._exec_params is not None
    assert not any(isinstance(l, (QTensor, ShardedQTensor))
                   for l in jax.tree_util.tree_leaves(
                       planned._exec_params,
                       is_leaf=lambda x: isinstance(
                           x, (QTensor, ShardedQTensor))))
    dense = _engine(serve_cfg, serve_params)
    assert dense._exec_params is serve_params


def test_pure_decode_rounds_skip_flush(serve_cfg, serve_params):
    """Rounds that neither allocated, COWed, nor reset anything must not
    dispatch apply_page_ops at all: flush count stays well below round
    count on a decode-heavy run."""
    eng = _engine(serve_cfg, serve_params)
    eng.run([Request(uid=i, prompt=np.arange(2, 8, dtype=np.int32),
                     max_new_tokens=8) for i in range(3)])
    s = eng.stats
    assert s.page_op_flushes < s.rounds
    assert s.rounds > 4
