"""Unit + property tests for the quantizer layer (Algorithm 1 pieces)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev)")
import hypothesis.extra.numpy as hnp  # noqa: E402
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core import qmc as qmclib
from repro.core.noise import perturb_codes
from repro.core.qconfig import NoiseModel, QMCConfig
from repro.core.quantizers import (expected_noise_mse, fake_quant,
                                   minmax_scale, mse_scale_search,
                                   noise_aware_scale_search, qrange,
                                   quantize_codes, rtn_quantize)

finite_floats = st.floats(-10.0, 10.0, allow_nan=False,
                          allow_infinity=False, width=32)


@settings(deadline=None, max_examples=25)
@given(hnp.arrays(np.float32, hnp.array_shapes(min_dims=2, max_dims=2,
                                               min_side=4, max_side=64),
                  elements=finite_floats),
       st.integers(2, 8))
def test_fake_quant_error_bound(w, bits):
    """|w - Q(w)| <= scale/2 for in-range values with minmax scaling."""
    s = minmax_scale(jnp.asarray(w), bits)
    deq = fake_quant(jnp.asarray(w), s, bits)
    err = np.abs(np.asarray(deq) - w)
    bound = np.broadcast_to(np.asarray(s), w.shape) * 0.5 + 1e-6
    # values at the negative clip edge can exceed scale/2 by one step
    assert np.all(err <= bound * 2 + 1e-5)


@settings(deadline=None, max_examples=25)
@given(hnp.arrays(np.float32, (16, 32), elements=finite_floats),
       st.integers(2, 6))
def test_codes_in_range(w, bits):
    s = minmax_scale(jnp.asarray(w), bits)
    q = np.asarray(quantize_codes(jnp.asarray(w), s, bits))
    lo, hi = qrange(bits)
    assert q.min() >= lo and q.max() <= hi


def test_mse_search_beats_minmax():
    key = jax.random.PRNGKey(0)
    w = jax.random.t(key, df=3.0, shape=(256, 128))  # heavy tails
    bits = 3
    s_mm = minmax_scale(w, bits)
    s_opt = mse_scale_search(w, bits)
    e_mm = float(jnp.sum(jnp.square(w - fake_quant(w, s_mm, bits))))
    e_opt = float(jnp.sum(jnp.square(w - fake_quant(w, s_opt, bits))))
    assert e_opt <= e_mm * 1.0001


def test_noise_aware_scale_smaller_and_better_under_noise():
    """Eq. 5-7: the noise term s^2*N*p pushes the optimal scale down, and

    the resulting expected distortion under noise must be <= the
    noise-blind optimum's."""
    key = jax.random.PRNGKey(1)
    w = jax.random.t(key, df=4.0, shape=(512, 64))
    noise = NoiseModel(cell_bits=3, p_minus=0.05, p_plus=0.05)
    s_blind = mse_scale_search(w, 3)
    s_aware = noise_aware_scale_search(w, 3, noise)
    assert float(jnp.mean(s_aware)) <= float(jnp.mean(s_blind)) + 1e-7
    l_blind = float(expected_noise_mse(w, s_blind, 3, noise))
    l_aware = float(expected_noise_mse(w, s_aware, 3, noise))
    assert l_aware <= l_blind * 1.0001


def test_qmc_beats_rtn_on_heavy_tails():
    key = jax.random.PRNGKey(2)
    w = jax.random.t(key, df=2.5, shape=(512, 256))
    cfg = QMCConfig(rho=0.3)
    res = qmclib.qmc_quantize(w, cfg)
    e_qmc = float(qmclib.quantization_mse(w, res.w_hat))
    e_rtn = float(qmclib.quantization_mse(w, rtn_quantize(w, 4)))
    assert e_qmc < e_rtn


def test_qmc_mse_decreases_with_rho():
    key = jax.random.PRNGKey(3)
    w = jax.random.t(key, df=3.0, shape=(256, 256))
    errs = []
    for rho in (0.05, 0.2, 0.4):
        res = qmclib.qmc_quantize(w, QMCConfig(rho=rho))
        errs.append(float(qmclib.quantization_mse(w, res.w_hat)))
    assert errs[0] > errs[1] > errs[2]


def test_merge_identity():
    """Step 4: scatter(W_in*, W_out*) covers every position exactly once."""
    key = jax.random.PRNGKey(4)
    w = jax.random.normal(key, (128, 128))
    res = qmclib.qmc_quantize(w, QMCConfig(rho=0.25))
    in_zero = np.asarray(res.codes_in)[np.asarray(res.outlier_mask)]
    out_zero = np.asarray(res.codes_out)[~np.asarray(res.outlier_mask)]
    assert np.all(in_zero == 0) and np.all(out_zero == 0)


def test_noise_aware_robustness_end_to_end():
    """Paper's core claim: under ReRAM noise, noise-aware scales lose less

    accuracy (MSE proxy) than noise-blind scales, averaged over draws."""
    key = jax.random.PRNGKey(5)
    w = jax.random.t(key, df=3.0, shape=(512, 128))
    cfg = QMCConfig(rho=0.3, cell_bits=3)
    import dataclasses
    noisy_cfg = dataclasses.replace(cfg)  # same; noise from cfg.noise
    res_aware = qmclib.qmc_quantize(w, cfg, noise_aware=True)
    res_blind = qmclib.qmc_quantize(w, cfg, noise_aware=False)
    e_aware = e_blind = 0.0
    for i in range(8):
        k = jax.random.PRNGKey(100 + i)
        e_aware += float(qmclib.quantization_mse(
            w, qmclib.apply_reram_noise(k, res_aware, cfg)))
        e_blind += float(qmclib.quantization_mse(
            w, qmclib.apply_reram_noise(k, res_blind, noisy_cfg)))
    assert e_aware <= e_blind * 1.001


def test_compression_ratio_matches_paper():
    cfg = QMCConfig(rho=0.3, bits_in=3, bits_out=5)
    assert abs(cfg.avg_bits - 3.6) < 1e-9
    assert abs(cfg.compression_vs_fp16 - 4.444444) < 1e-3
