"""Sharded paged serving: the unified step-builder layer, mesh sharding

specs for the paged arena, sharded-vs-unsharded greedy parity (subprocess
with a forced 4-device host platform), in-flight prompt dedup, and the
per-shard DSE traffic split."""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.launch import sharding as shd
from repro.memsys.workload import (kv_traffic_paged, make_traffic,
                                   shard_serve_traffic)
from repro.models.config import ModelConfig
from repro.models.model import init_params
from repro.serve import steps as serve_steps
from repro.serve.engine import LegacyServeEngine, Request, ServeEngine

ROOT = os.path.join(os.path.dirname(__file__), "..")

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab=64)
CFG = ModelConfig(name="t", family="dense", **BASE)
CFG_HYBRID = ModelConfig(name="th", family="hybrid", pattern=("hybrid",),
                         d_state=16, ssm_headdim=32, **BASE)


def _params(cfg):
    return init_params(cfg, jax.random.PRNGKey(0))


def _clone(reqs):
    return [Request(uid=r.uid, prompt=r.prompt,
                    max_new_tokens=r.max_new_tokens, eos_id=r.eos_id)
            for r in reqs]


# -------------------------------------------------------------------------
# paged arena sharding specs (no multi-device requirement: specs only)
# -------------------------------------------------------------------------
class FakeLeaf:
    def __init__(self, shape):
        self.shape = shape
        self.ndim = len(shape)


class FakeMesh:
    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape, dtype=object)


MESH22 = FakeMesh((2, 2), ("data", "model"))


def test_paged_arena_specs():
    # [G, n_pages, page, kv_dim]: pages on data, fused kv on model
    assert tuple(shd.paged_cache_spec(
        "b0/attn/k_pages", FakeLeaf((2, 32, 16, 64)), MESH22)) == \
        (None, "data", None, "model")
    # int8 scales: head dim on model when divisible
    assert tuple(shd.paged_cache_spec(
        "b0/attn/k_scale_pages", FakeLeaf((2, 32, 16, 2)), MESH22)) == \
        (None, "data", None, "model")
    # non-divisible page count / head count replicate
    assert tuple(shd.paged_cache_spec(
        "b0/attn/v_pages", FakeLeaf((2, 33, 16, 63)), MESH22)) == \
        (None, None, None, None)
    # block tables replicate (any shard resolves any position)
    assert tuple(shd.paged_cache_spec(
        "b0/attn/block_tbl", FakeLeaf((2, 8, 4)), MESH22)) == ()
    # dense mamba state: batch on dp when divisible
    assert tuple(shd.paged_cache_spec(
        "b0/mamba/ssm", FakeLeaf((2, 8, 4, 16, 16)), MESH22)) == \
        (None, "data", None, None, None)


# -------------------------------------------------------------------------
# one builder layer: engine and launch path share PagedServeSteps
# -------------------------------------------------------------------------
def test_engine_accepts_prebuilt_steps_and_matches_legacy():
    """The launch/serve.py flow: steps built through serve.steps, handed

    to the engine — tokens identical to the legacy per-slot engine."""
    params = _params(CFG)
    rng = np.random.default_rng(5)
    reqs = [Request(uid=i, prompt=rng.integers(2, 64, int(L)).astype(
        np.int32), max_new_tokens=5)
        for i, L in enumerate(rng.integers(4, 14, size=6))]
    legacy = _clone(reqs)
    LegacyServeEngine(CFG, params, slots=4, max_len=32).run(legacy)
    step_set = serve_steps.build_paged_steps(
        CFG, None, page=8, n_pages=16, max_slots=4, max_pages_per_seq=4)
    paged = _clone(reqs)
    ServeEngine(CFG, params, slots=4, max_len=32, page_size=8, n_pages=16,
                step_set=step_set).run(paged)
    assert [r.out_tokens for r in legacy] == [r.out_tokens for r in paged]


def test_engine_rejects_mismatched_steps():
    step_set = serve_steps.build_paged_steps(
        CFG, None, page=8, n_pages=16, max_slots=4, max_pages_per_seq=4)
    with pytest.raises(ValueError):
        ServeEngine(CFG, _params(CFG), slots=4, max_len=32, page_size=16,
                    step_set=step_set)        # page 16 != built-for 8


def test_sharded_builder_requires_params_struct():
    class _M:   # only truthiness is checked before params_struct
        pass
    with pytest.raises(ValueError):
        serve_steps.build_paged_steps(CFG, _M(), None, page=8, n_pages=16,
                                      max_slots=4, max_pages_per_seq=4)


# -------------------------------------------------------------------------
# in-flight dedup (pending-prefill table)
# -------------------------------------------------------------------------
def _identical_requests(n=4, length=20, seed=3, max_new=5):
    rng = np.random.default_rng(seed)
    shared = rng.integers(2, 64, size=length).astype(np.int32)
    return [Request(uid=i, prompt=shared.copy(), max_new_tokens=max_new)
            for i in range(n)]


def test_inflight_dedup_aliases_identical_prompts():
    params = _params(CFG)
    legacy = _identical_requests()
    LegacyServeEngine(CFG, params, slots=4, max_len=48).run(legacy)
    reqs = _identical_requests()
    eng = ServeEngine(CFG, params, slots=4, max_len=48, page_size=8)
    eng.run(reqs)
    # 3 followers alias the leader's two full pages (20 tokens, page 8)
    assert eng.stats.dedup_hits == 3
    assert eng.stats.cache_hit_tokens == 3 * 16
    assert eng.stats.prefill_tokens < 4 * 20
    assert [r.out_tokens for r in reqs] == [r.out_tokens for r in legacy]


def test_inflight_dedup_off_prefills_everything():
    params = _params(CFG)
    reqs = _identical_requests()
    eng = ServeEngine(CFG, params, slots=4, max_len=48, page_size=8,
                      inflight_dedup=False)
    eng.run(reqs)
    assert eng.stats.dedup_hits == 0
    assert eng.stats.prefill_tokens == 4 * 20


def test_radix_match_takes_precedence_over_dedup():
    """With the prefix cache on, the leader publishes its full pages at

    admission, so followers hit the index (equal coverage) — the
    pending-prefill table only upgrades strictly-better matches."""
    eng = ServeEngine(CFG, _params(CFG), slots=4, max_len=48, page_size=8,
                      prefix_cache=True)
    eng.run(_identical_requests())
    assert eng.stats.cache_hits == 3
    assert eng.stats.dedup_hits == 0


def test_inflight_dedup_sub_page_prompts_miss():
    """Prompts shorter than a page own no full page to alias."""
    eng = ServeEngine(CFG, _params(CFG), slots=4, max_len=48, page_size=8)
    eng.run(_identical_requests(length=6))
    assert eng.stats.dedup_hits == 0


def test_inflight_dedup_forced_on_hybrid_raises():
    with pytest.raises(NotImplementedError):
        ServeEngine(CFG_HYBRID, _params(CFG_HYBRID), slots=2, max_len=32,
                    inflight_dedup=True)


def test_hybrid_auto_disables_dedup():
    eng = ServeEngine(CFG_HYBRID, _params(CFG_HYBRID), slots=2, max_len=32)
    assert eng._dedup is False


# -------------------------------------------------------------------------
# per-shard DSE traffic
# -------------------------------------------------------------------------
def test_shard_serve_traffic_split():
    base = make_traffic(CFG, "qmc", seq_len=64)
    paged = kv_traffic_paged(CFG, [24, 40], page=16)
    batched = paged.apply(base)
    per_dev = shard_serve_traffic(batched, data_shards=2, model_shards=2)
    assert per_dev.weight_bits == pytest.approx(batched.weight_bits / 2)
    assert per_dev.kv_bits == pytest.approx(batched.kv_bits / 4)
    assert per_dev.act_bits == pytest.approx(batched.act_bits / 2)
    # capacity accounting splits with TP only
    assert per_dev.total_cells == pytest.approx(batched.total_cells / 2)
    assert "shard_d2m2" in per_dev.name


# -------------------------------------------------------------------------
# sharded-vs-unsharded greedy parity (forced 4-device host platform)
# -------------------------------------------------------------------------
PARITY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json
import jax, numpy as np
from repro.launch import mesh as meshlib
from repro.models.config import ModelConfig
from repro.models.model import init_params
from repro.serve.engine import Request, ServeEngine
from repro.core.qconfig import QMCConfig
from repro.core.serving_quant import quantize_for_serving
from repro.core.qtensor_sharded import ShardedQTensor

assert len(jax.devices()) == 4, jax.devices()
BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab=64)
CFG = ModelConfig(name="t", family="dense", **BASE)
CFG8 = ModelConfig(name="t8", family="dense", kv_cache_quant=True, **BASE)
CFGQ = ModelConfig(name="tq", family="dense", n_layers=2, d_model=128,
                   n_heads=8, n_kv_heads=2, d_ff=256, vocab=128)

def requests(cfg, n=4, seed=5, max_new=4):
    rng = np.random.default_rng(seed)
    return [Request(uid=i, prompt=rng.integers(2, cfg.vocab,
                                               size=int(L)).astype(np.int32),
                    max_new_tokens=max_new)
            for i, L in enumerate(rng.integers(4, 14, size=n))]

def run(cfg, params, mesh, paged_attention=False, chunk=None):
    reqs = requests(cfg)
    eng = ServeEngine(cfg, params, slots=4, max_len=32, page_size=8,
                      n_pages=15, mesh=mesh,   # 15+1 null: splits on data
                      chunk_tokens=chunk,
                      paged_attention=paged_attention)
    eng.run(reqs)
    return [r.out_tokens for r in reqs]

m1 = meshlib.make_mesh((1, 1), ("data", "model"))
m4 = meshlib.make_mesh((2, 2), ("data", "model"))
out = {}
for label, cfg in (("fp32", CFG), ("int8kv", CFG8)):
    p = init_params(cfg, jax.random.PRNGKey(0))
    ref, one, four = run(cfg, p, None), run(cfg, p, m1), run(cfg, p, m4)
    out[label] = {"nomesh_eq_m1": ref == one, "m1_eq_m4": one == four,
                  "tokens": sum(len(t) for t in ref)}
    # ragged Pallas paged-attention kernel, shard-local on the 2x2 mesh
    # (pages over data with the flash-decoding softmax merge, KV heads
    # over model): token-identical to the unsharded reference gather,
    # for monolithic AND chunked prefill (chunks co-schedule with
    # decode lanes inside the sharded step)
    kern = run(cfg, p, m4, paged_attention=True)
    out[label]["kernel_m4_eq_ref"] = kern == ref
    chunked = run(cfg, p, m4, paged_attention=True, chunk=8)
    out[label]["chunked_kernel_m4_eq_ref"] = chunked == ref
# QMC serving format: quantize-after-shard at TP=2, same weights both runs
pq = quantize_for_serving(init_params(CFGQ, jax.random.PRNGKey(0)),
                          QMCConfig(rho=0.3, granularity="subtile"),
                          tp_shards=2, min_dim=64)
n_sqt = sum(isinstance(l, ShardedQTensor)
            for l in jax.tree_util.tree_leaves(
                pq, is_leaf=lambda x: isinstance(x, ShardedQTensor)))
one, four = run(CFGQ, pq, m1), run(CFGQ, pq, m4)
out["sqt"] = {"m1_eq_m4": one == four, "n_sharded_qtensors": n_sqt,
              "tokens": sum(len(t) for t in one)}
print("RESULT" + json.dumps(out))
"""


@pytest.mark.slow
@pytest.mark.kernel
def test_sharded_greedy_parity_4dev():
    """Greedy decode on a forced 4-device (2 data x 2 model) host mesh is

    token-identical to the 1-device engine — dense fp32 KV, int8 KV, and
    ShardedQTensor (QMC serving format) weights with the sharded arena,
    plus the shard-local Pallas paged-attention kernel on the same mesh."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", PARITY_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1500)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads([l for l in proc.stdout.splitlines()
                      if l.startswith("RESULT")][0][len("RESULT"):])
    for label in ("fp32", "int8kv"):
        assert out[label]["nomesh_eq_m1"], out
        assert out[label]["m1_eq_m4"], out
        assert out[label]["kernel_m4_eq_ref"], out
        assert out[label]["chunked_kernel_m4_eq_ref"], out
        assert out[label]["tokens"] > 0
    assert out["sqt"]["n_sharded_qtensors"] >= 6, out
    assert out["sqt"]["m1_eq_m4"], out
