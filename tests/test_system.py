"""End-to-end system behaviour: train -> PTQ (all methods) -> evaluate ->

serve. This is the paper's full pipeline on a synthetic-corpus SLM."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.apply import quantize_model
from repro.core.qconfig import QMCConfig
from repro.data.synthetic import SyntheticCorpus
from repro.models.config import ModelConfig
from repro.models.model import forward
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import TrainConfig, train

CFG = ModelConfig(name="sys", family="dense", n_layers=2, d_model=128,
                  n_heads=4, n_kv_heads=2, d_ff=256, vocab=128)


@pytest.fixture(scope="module")
def trained():
    tc = TrainConfig(steps=120, global_batch=16, seq_len=64,
                     log_every=1000, warmup=10)
    return train(CFG, tc, AdamWConfig(lr=2e-3), log_fn=lambda s: None)


def _ppl(params, corpus, n=4):
    tot, cnt = 0.0, 0
    for b in corpus.heldout_ppl_batches(n, 16, 64):
        logits, _, _ = forward(CFG, params, jnp.asarray(b["tokens"]))
        logits = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, jnp.asarray(
            b["labels"])[..., None], -1)[..., 0]
        tot += float(jnp.sum(lse - gold))
        cnt += b["labels"].size
    return float(np.exp(tot / cnt))


def test_full_pipeline_ordering(trained):
    """The paper's Table-2 ordering on our trained SLM:

    fp16 <= QMC < RTN-INT4 in PPL (QMC close to fp16)."""
    corpus: SyntheticCorpus = trained["corpus"]
    params = trained["params"]
    ppl_fp = _ppl(params, corpus)
    qmc = quantize_model(params, method="qmc",
                         qmc=QMCConfig(rho=0.3), min_dim=64)
    rtn = quantize_model(params, method="rtn4", min_dim=64)
    ppl_qmc = _ppl(qmc, corpus)
    ppl_rtn = _ppl(rtn, corpus)
    assert ppl_fp <= ppl_qmc * 1.02
    assert ppl_qmc < ppl_rtn
    # QMC stays within a reasonable envelope of fp16
    assert ppl_qmc < ppl_fp * 1.5


def test_noise_robustness_pipeline(trained):
    """Under simulated ReRAM noise, noise-aware QMC degrades less than a

    noise-blind variant of the same format (paper §3.4)."""
    corpus: SyntheticCorpus = trained["corpus"]
    params = trained["params"]
    deltas = {"aware": [], "blind": []}
    for i in range(3):
        key = jax.random.PRNGKey(50 + i)
        q_aware = quantize_model(params, method="qmc",
                                 qmc=QMCConfig(rho=0.3, cell_bits=3),
                                 noise_key=key, noise_aware=True,
                                 min_dim=64)
        q_blind = quantize_model(params, method="qmc",
                                 qmc=QMCConfig(rho=0.3, cell_bits=3),
                                 noise_key=key, noise_aware=False,
                                 min_dim=64)
        deltas["aware"].append(_ppl(q_aware, corpus, n=2))
        deltas["blind"].append(_ppl(q_blind, corpus, n=2))
    assert np.mean(deltas["aware"]) <= np.mean(deltas["blind"]) * 1.02


def test_serve_trained_model(trained):
    from repro.serve.engine import Request, ServeEngine
    corpus: SyntheticCorpus = trained["corpus"]
    b = corpus.sample_batch(3, 12, step=5_000_000)
    reqs = [Request(uid=i, prompt=b["tokens"][i], max_new_tokens=8)
            for i in range(3)]
    eng = ServeEngine(CFG, trained["params"], slots=2, max_len=32)
    eng.run(reqs)
    assert all(len(r.out_tokens) == 8 for r in reqs)
    assert eng.stats.tokens_per_s > 0
