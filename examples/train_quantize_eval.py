"""End-to-end driver: train a ~100M-param SLM for a few hundred steps on

the synthetic corpus, PTQ it with every method, and report held-out PPL —
the paper's Table-2 pipeline at laptop scale.

  PYTHONPATH=src python examples/train_quantize_eval.py [--steps 300]
  (use --small for a fast demo model)
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import QMCConfig, quantize_model
from repro.models.config import ModelConfig
from repro.models.model import forward
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import TrainConfig, train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--small", action="store_true")
args = ap.parse_args()

if args.small:
    cfg = ModelConfig(name="demo-20m", family="dense", n_layers=4,
                      d_model=256, n_heads=8, n_kv_heads=4, d_ff=512,
                      vocab=512)
else:
    # ~100M params: 12 x (d=768, ff=2048) + 32k vocab
    cfg = ModelConfig(name="demo-100m", family="dense", n_layers=12,
                      d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
                      vocab=32768)

print(f"[1/3] training {cfg.name} "
      f"({cfg.param_count()/1e6:.0f}M params) for {args.steps} steps...")
tc = TrainConfig(steps=args.steps, global_batch=16, seq_len=128,
                 log_every=25, warmup=20,
                 ckpt_dir="artifacts/example_ckpt", ckpt_every=100,
                 resume=True)
out = train(cfg, tc, AdamWConfig(lr=1.5e-3))
params, corpus = out["params"], out["corpus"]


def ppl(p):
    tot, cnt = 0.0, 0
    for b in corpus.heldout_ppl_batches(3, 8, 128):
        logits, _, _ = forward(cfg, p, jnp.asarray(b["tokens"]))
        logits = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, jnp.asarray(
            b["labels"])[..., None], -1)[..., 0]
        tot += float(jnp.sum(lse - gold))
        cnt += b["labels"].size
    return float(np.exp(tot / cnt))


print("[2/3] post-training quantization (all methods)...")
rows = [("fp16", params, 1.0)]
rows.append(("rtn-int4", quantize_model(params, "rtn4"), 4.0))
rows.append(("mxint4", quantize_model(params, "mx4"), 16 / 4.25))
qmc = QMCConfig(rho=0.3, cell_bits=3)
rows.append(("qmc (no noise)", quantize_model(params, "qmc", qmc=qmc),
             16 / 3.6))
rows.append(("qmc (3b-MLC noise)",
             quantize_model(params, "qmc", qmc=qmc,
                            noise_key=jax.random.PRNGKey(5)), 16 / 3.6))

print("[3/3] held-out perplexity:")
print(f"{'method':22s} {'ppl':>8s} {'compression':>12s}")
for name, p, comp in rows:
    print(f"{name:22s} {ppl(p):8.3f} {comp:11.2f}x")
