"""Quickstart: quantize a model with QMC and see the accuracy/compression

trade-off in ~a minute on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import reduced_config
from repro.core import QMCConfig, quantize_model
from repro.core.apply import model_bits_per_weight
from repro.models.model import forward, init_params

# 1. Build a small model (any of the 14 registered archs shrinks the same
#    way; try "gemma2-2b", "mamba2-370m", "jamba-1.5-large-398b", ...).
cfg = reduced_config("stablelm-1.6b")
params = init_params(cfg, jax.random.PRNGKey(0))
tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
logits_fp, _, _ = forward(cfg, params, tokens)

# 2. Run Algorithm 1 (outlier-aware robust quantization) over the weights.
qmc = QMCConfig(rho=0.3, bits_in=3, bits_out=5, cell_bits=3)
qparams = quantize_model(params, method="qmc", qmc=qmc, min_dim=64)
logits_q, _, _ = forward(cfg, qparams, tokens)

# 3. Compare against plain INT4 rounding and simulated ReRAM read noise.
rparams = quantize_model(params, method="rtn4", min_dim=64)
logits_r, _, _ = forward(cfg, rparams, tokens)
nparams = quantize_model(params, method="qmc", qmc=qmc,
                         noise_key=jax.random.PRNGKey(7), min_dim=64)
logits_n, _, _ = forward(cfg, nparams, tokens)


def drift(a, b):
    return float(jnp.mean(jnp.abs(a - b)) / (jnp.mean(jnp.abs(a)) + 1e-9))


print(f"model: {cfg.name} ({sum(l.size for l in jax.tree_util.tree_leaves(params)):,} params)")
print(f"avg bits/weight QMC : {model_bits_per_weight(params, 'qmc', qmc):.2f} "
      f"(={16/qmc.avg_bits:.2f}x compression on quantized layers)")
print(f"logit drift  QMC            : {drift(logits_fp, logits_q):.4f}")
print(f"logit drift  RTN-INT4       : {drift(logits_fp, logits_r):.4f}")
print(f"logit drift  QMC+ReRAMnoise : {drift(logits_fp, logits_n):.4f}")
assert drift(logits_fp, logits_q) < drift(logits_fp, logits_r), \
    "QMC should beat plain INT4 rounding"
print("OK: QMC < RTN drift, as the paper claims.")
