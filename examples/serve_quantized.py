"""Batched serving with QMC deployment-format weights (ShardedQTensor):

the paper's edge-inference scenario. Requests stream through the paged
continuous-batching engine — all active slots decode in one jit'd step
against the shared paged KV pool, while weights live in the dual-stream
packed format and are dequantized at the matmul (the Model Weight
Controller path). The legacy per-slot engine runs as the baseline.

  PYTHONPATH=src python examples/serve_quantized.py
"""
import time

import jax
import numpy as np

from repro.configs import reduced_config
from repro.core.qconfig import QMCConfig
from repro.core.serving_quant import quantize_for_serving
from repro.models.model import init_params
from repro.serve.engine import LegacyServeEngine, Request, ServeEngine

cfg = reduced_config("qwen2.5-1.5b")
params = init_params(cfg, jax.random.PRNGKey(0))

print("quantizing weights to the QMC serving format (rho=0.3, 3b/5b)...")
t0 = time.monotonic()
qparams = quantize_for_serving(params,
                               QMCConfig(rho=0.3, granularity="subtile"),
                               tp_shards=1, min_dim=64)
print(f"  done in {time.monotonic()-t0:.1f}s")

rng = np.random.default_rng(0)
requests = [Request(uid=i,
                    prompt=rng.integers(2, cfg.vocab, size=12).astype(
                        np.int32),
                    max_new_tokens=12)
            for i in range(6)]

for name, p, engine_cls in (
        ("fp32 legacy", params, LegacyServeEngine),
        ("fp32 paged", params, ServeEngine),
        ("QMC paged", qparams, ServeEngine)):
    reqs = [Request(uid=r.uid, prompt=r.prompt,
                    max_new_tokens=r.max_new_tokens) for r in requests]
    eng = engine_cls(cfg, p, slots=3, max_len=32)
    eng.run(reqs)
    s = eng.stats
    print(f"{name:12s}: {s.tokens_out} tokens, {s.prefills} prefills, "
          f"{s.decode_steps} decode calls, {s.tokens_per_s:.1f} tok/s")
    print(f"   first output: {reqs[0].out_tokens}")
