"""Memory-system co-design exploration (paper §3.3.3, Eq. 3-4): sweep the

MRAM-channel x ReRAM-bank design space for a full-size SLM, print the
feasible frontier and the chosen configuration, and compare the deployment
against the Jetson-class LPDDR5 baseline and eMEMs.

  PYTHONPATH=src python examples/codesign_dse.py --arch hymba-1.5b
"""
import argparse
import itertools

from repro.configs import get_config
from repro.core.qconfig import QMCConfig
from repro.memsys import (MemSystemConfig, dse, evaluate_conventional,
                          evaluate_hetero, make_traffic)

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="hymba-1.5b")
ap.add_argument("--seq", type=int, default=1024)
ap.add_argument("--budget", type=float, default=8.0)
args = ap.parse_args()

cfg = get_config(args.arch)
qc = QMCConfig(rho=0.3, cell_bits=3)
traffic = make_traffic(cfg, "qmc", seq_len=args.seq, qmc=qc)

print(f"== DSE for {args.arch} ({cfg.param_count()/1e9:.2f}B params, "
      f"seq={args.seq}, budget={args.budget}W) ==")
print(f"{'mram_ch':>8s} {'reram_bk':>9s} {'power_W':>8s} {'lat_ms':>8s} "
      f"feasible")
for ch, banks in itertools.product((1, 2, 4, 8, 14), (1, 2, 4, 8, 12)):
    sc = MemSystemConfig(mram_channels=ch, reram_banks=banks,
                         power_budget_w=args.budget)
    r = evaluate_hetero(traffic, sc)
    print(f"{ch:8d} {banks:9d} {r.power_w:8.2f} {r.latency_s*1e3:8.3f} "
          f"{'yes' if r.feasible else 'NO'}")

best = dse(traffic, power_budget_w=args.budget)
r_best = evaluate_hetero(traffic, best)
print(f"\nchosen: mram_channels={best.mram_channels}, "
      f"reram_banks={best.reram_banks} -> "
      f"{r_best.latency_s*1e3:.3f} ms/token, "
      f"{r_best.energy_j*1e3:.2f} mJ/token")

base = evaluate_conventional(
    make_traffic(cfg, "fp16", seq_len=args.seq, legacy_flash=True),
    MemSystemConfig())
em = evaluate_hetero(make_traffic(cfg, "emems_mram", seq_len=args.seq),
                     dse(make_traffic(cfg, "emems_mram",
                                      seq_len=args.seq)))
print(f"\nvs FP16/LPDDR5 : {base.latency_s/r_best.latency_s:6.2f}x "
      f"latency, {base.energy_j/r_best.energy_j:6.2f}x energy, "
      f"{base.capacity_cells/r_best.capacity_cells:6.2f}x memory cells")
print(f"vs eMEMs-MRAM  : {em.latency_s/r_best.latency_s:6.2f}x latency, "
      f"{em.energy_j/r_best.energy_j:6.2f}x energy, "
      f"{em.capacity_cells/r_best.capacity_cells:6.2f}x memory cells")
