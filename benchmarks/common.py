"""Shared benchmark infrastructure: cached trained SLMs + eval metrics.

Absolute WikiText numbers need the original pretrained checkpoints (not
available offline); the benchmarks therefore train small same-family models
on the deterministic synthetic corpus and validate the paper's RELATIVE
claims (method orderings, noise robustness, rho trade-off, system ratios).
"""
from __future__ import annotations

import os
import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt as ckpt_lib
from repro.data.synthetic import CorpusConfig, SyntheticCorpus
from repro.models.config import ModelConfig
from repro.models.model import forward, init_params
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import TrainConfig, train

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                   "bench_models")

# Small same-family stand-ins for the paper's evaluation SLMs.
BENCH_MODELS = {
    "qwen-like-dense": ModelConfig(
        name="qwen-like-dense", family="dense", n_layers=4, d_model=256,
        n_heads=8, n_kv_heads=2, d_ff=512, vocab=512, qkv_bias=True),
    "hymba-like-hybrid": ModelConfig(
        name="hymba-like-hybrid", family="hybrid", n_layers=2, d_model=192,
        n_heads=6, n_kv_heads=2, d_ff=384, vocab=512,
        pattern=("hybrid", "hybrid_local"), window=32,
        d_state=16, ssm_headdim=32),
    "mamba-like-ssm": ModelConfig(
        name="mamba-like-ssm", family="ssm", n_layers=4, d_model=192,
        n_heads=0, n_kv_heads=0, head_dim=1, d_ff=0, vocab=512,
        pattern=("mamba",), d_state=16, ssm_headdim=32),
}

TRAIN_STEPS = 300


def get_trained(name: str) -> Tuple[ModelConfig, Dict, SyntheticCorpus]:
    """Train (or load cached) a benchmark SLM."""
    cfg = BENCH_MODELS[name]
    corpus = SyntheticCorpus(CorpusConfig(vocab=cfg.vocab, seed=41))
    ckdir = os.path.join(ART, name)
    params = init_params(cfg, jax.random.PRNGKey(0))
    if ckpt_lib.latest_step(ckdir) == TRAIN_STEPS:
        restored, _ = ckpt_lib.restore(
            jax.eval_shape(lambda: {"params": params}), ckdir)
        return cfg, restored["params"], corpus
    tc = TrainConfig(steps=TRAIN_STEPS, global_batch=16, seq_len=64,
                     log_every=100, warmup=20, seed=40)
    out = train(cfg, tc, AdamWConfig(lr=2e-3), log_fn=lambda s: None)
    os.makedirs(ckdir, exist_ok=True)
    ckpt_lib.save({"params": out["params"]}, ckdir, TRAIN_STEPS)
    return cfg, out["params"], SyntheticCorpus(
        CorpusConfig(vocab=cfg.vocab, seed=41))


def heldout_ppl(cfg: ModelConfig, params, corpus: SyntheticCorpus,
                n_batches: int = 4) -> float:
    tot, cnt = 0.0, 0
    for b in corpus.heldout_ppl_batches(n_batches, 16, 64):
        logits, _, _ = forward(cfg, params, jnp.asarray(b["tokens"]))
        logits = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, -1)
        gold = jnp.take_along_axis(
            logits, jnp.asarray(b["labels"])[..., None], -1)[..., 0]
        tot += float(jnp.sum(lse - gold))
        cnt += b["labels"].size
    return float(np.exp(tot / cnt))


def cloze_accuracy(cfg: ModelConfig, params, corpus: SyntheticCorpus,
                   n: int = 64) -> float:
    """Synthetic 'reasoning' probe: recall the document's topic marker."""
    probe = corpus.cloze_batch(n, seq=48)
    logits, _, _ = forward(cfg, params, jnp.asarray(probe["tokens"]))
    pred = np.asarray(jnp.argmax(logits[:, -1], -1))
    return float((pred == probe["answers"]).mean())


class Timer:
    def __enter__(self):
        self.t0 = time.monotonic()
        return self

    def __exit__(self, *a):
        self.us = (time.monotonic() - self.t0) * 1e6


def emit(name: str, us: float, derived: str):
    print(f"{name},{us:.0f},{derived}")
