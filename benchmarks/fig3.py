"""Paper Fig. 3: outlier ratio rho vs PPL and vs normalized energy/latency.

Claims: PPL improves monotonically with rho; latency is U-shaped with a
sweet spot near rho=0.3 (MRAM becomes the bottleneck above it); energy is
nearly flat.
"""
from __future__ import annotations

import dataclasses

import jax

from benchmarks.common import Timer, emit, get_trained, heldout_ppl
from repro.configs import get_config
from repro.core.apply import quantize_model
from repro.core.qconfig import QMCConfig
from repro.memsys import dse, evaluate_hetero, make_traffic

RHOS = (0.1, 0.2, 0.3, 0.4, 0.5)


def run(model="hymba-like-hybrid", sys_model="hymba-1.5b"):
    cfg, params, corpus = get_trained(model)
    sys_cfg = get_config(sys_model)
    rows = []
    base = None
    for rho in RHOS:
        qc = QMCConfig(rho=rho, cell_bits=3)
        with Timer() as t:
            q = quantize_model(params, "qmc", qmc=qc,
                               noise_key=jax.random.PRNGKey(9), min_dim=64)
            ppl = heldout_ppl(cfg, q, corpus)
            traffic = make_traffic(sys_cfg, "qmc", seq_len=1024, qmc=qc)
            best = dse(traffic, cell_bits=3)
            r = evaluate_hetero(traffic, best)
        if base is None:
            base = r
        emit(f"fig3/rho{rho}", t.us,
             f"ppl={ppl:.3f};norm_energy={r.energy_j/base.energy_j:.3f};"
             f"norm_latency={r.latency_s/base.latency_s:.3f};"
             f"mram_ch={best.mram_channels};reram_banks={best.reram_banks}")
        rows.append((rho, ppl, r.energy_j, r.latency_s))
    # validation: PPL monotone non-increasing in rho (within tolerance)
    ppls = [r[1] for r in rows]
    mono = all(ppls[i + 1] <= ppls[i] * 1.03 for i in range(len(ppls) - 1))
    emit("fig3/ppl_monotone_in_rho", 0, f"holds={mono}")
    return rows


if __name__ == "__main__":
    run()
