"""Paper Table 2: FP16 / RTN-INT4 / MXINT4 / QMC(3b-MLC) / QMC(2b-MLC).

Validation targets (relative, per DESIGN.md §7): QMC >= MXINT4 > RTN on
quality; QMC-2b >= QMC-3b under noise (lower BER); compression 4.44x vs 4x.
"""
from __future__ import annotations

import jax

from benchmarks.common import (Timer, cloze_accuracy, emit, get_trained,
                               heldout_ppl)
from repro.core.apply import quantize_model
from repro.core.qconfig import MXConfig, QMCConfig


def run(models=("qwen-like-dense", "hymba-like-hybrid", "mamba-like-ssm")):
    rows = []
    for mname in models:
        cfg, params, corpus = get_trained(mname)
        variants = {
            "fp16": lambda: params,
            "rtn_int4": lambda: quantize_model(params, "rtn4", min_dim=64),
            "mxint4": lambda: quantize_model(params, "mx4", min_dim=64),
            "qmc_3bit_mlc": lambda: quantize_model(
                params, "qmc", qmc=QMCConfig(rho=0.3, cell_bits=3),
                noise_key=jax.random.PRNGKey(5), min_dim=64),
            "qmc_2bit_mlc": lambda: quantize_model(
                params, "qmc", qmc=QMCConfig(rho=0.3, cell_bits=2),
                noise_key=jax.random.PRNGKey(5), min_dim=64),
        }
        comp = {"fp16": 1.0, "rtn_int4": 4.0, "mxint4": 16 / 4.25,
                "qmc_3bit_mlc": 16 / 3.6, "qmc_2bit_mlc": 16 / 3.6}
        for vname, make in variants.items():
            with Timer() as t:
                q = make()
                ppl = heldout_ppl(cfg, q, corpus)
                acc = cloze_accuracy(cfg, q, corpus)
            derived = (f"model={mname};ppl={ppl:.3f};cloze={acc:.3f};"
                       f"compression={comp[vname]:.2f}x")
            emit(f"table2/{mname}/{vname}", t.us, derived)
            rows.append((mname, vname, ppl, acc, comp[vname]))
    return rows


def validate(rows):
    """Assert the paper's ordering claims hold."""
    ok = []
    by = {(m, v): (p, a) for m, v, p, a, _ in rows}
    for m in {r[0] for r in rows}:
        fp = by[(m, "fp16")][0]
        rtn = by[(m, "rtn_int4")][0]
        mx = by[(m, "mxint4")][0]
        q3 = by[(m, "qmc_3bit_mlc")][0]
        q2 = by[(m, "qmc_2bit_mlc")][0]
        ok.append(("qmc<=mx", m, q2 <= mx * 1.05 or q3 <= mx * 1.05))
        ok.append(("mx<=rtn", m, mx <= rtn * 1.05))
        ok.append(("qmc~fp16", m, min(q2, q3) <= fp * 1.35))
        ok.append(("2b<=3b(noise)", m, q2 <= q3 * 1.05))
    return ok


if __name__ == "__main__":
    validate(run())
