"""Hardware-adaptation ablation: scalar (paper-faithful) vs (8,128)-subtile

(TPU deployment) outlier granularity at equal average bits — quantifies the
accuracy cost of restructuring Eq. 1 for TPU vector memory (DESIGN.md §2).
"""
from __future__ import annotations

import jax

from benchmarks.common import Timer, emit, get_trained, heldout_ppl
from repro.core.apply import quantize_model
from repro.core.qconfig import QMCConfig


def run(models=("qwen-like-dense", "hymba-like-hybrid")):
    rows = []
    for mname in models:
        cfg, params, corpus = get_trained(mname)
        ppl_fp = heldout_ppl(cfg, params, corpus)
        for rho in (0.1, 0.3):
            for gran in ("scalar", "subtile"):
                qc = QMCConfig(rho=rho, granularity=gran)
                with Timer() as t:
                    q = quantize_model(params, "qmc", qmc=qc, min_dim=64)
                    ppl = heldout_ppl(cfg, q, corpus)
                emit(f"granularity/{mname}/rho{rho}/{gran}", t.us,
                     f"ppl={ppl:.3f};fp16={ppl_fp:.3f};"
                     f"delta_vs_fp16={ppl - ppl_fp:+.3f}")
                rows.append((mname, rho, gran, ppl))
    return rows


if __name__ == "__main__":
    run()
