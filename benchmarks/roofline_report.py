"""Render the roofline table from the dry-run artifacts (§Roofline input)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_records(mesh: str = None):
    recs = []
    for p in sorted(glob.glob(os.path.join(ART, "*.json"))):
        r = json.load(open(p))
        if r.get("ok") and (mesh is None or r["mesh"] == mesh):
            recs.append(r)
    return recs


def run():
    recs = load_records()
    if not recs:
        emit("roofline/none", 0, "no dry-run artifacts; run "
             "python -m repro.launch.dryrun --all first")
        return []
    for r in recs:
        roof = r["roofline"]
        emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
             + (f"/{r['serve_weights']}" if "decode" in r["shape"]
                or "500k" in r["shape"] else ""),
             r.get("compile_s", 0) * 1e6,
             f"t_compute={roof['t_compute']:.3e}s;"
             f"t_memory={roof['t_memory']:.3e}s;"
             f"t_collective={roof['t_collective']:.3e}s;"
             f"bottleneck={roof['bottleneck']};"
             f"useful_flops_ratio={roof['useful_flops_ratio']:.3f};"
             f"roofline_fraction={roof['roofline_fraction']:.4f}")
    return recs


if __name__ == "__main__":
    run()
