"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run table2 fig4

Each row prints ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (fig3, fig4, granularity, kernels,
                            roofline_report, serving, table2, table3,
                            table4)
    suites = {
        "serving": serving.run,     # legacy vs paged engine throughput
        "table2": table2.run,       # FP16/RTN/MXINT4/QMC quality
        "table3": table3.run,       # AWQ/GPTQ/QMC(no-noise)
        "fig3": fig3.run,           # rho sweep: PPL + energy/latency
        "fig4": fig4.run,           # system energy/latency/memory
        "table4": table4.run,       # co-design vs eMEMs
        "granularity": granularity.run,    # scalar vs subtile ablation
        "kernels": kernels.run,     # qmm + unpack3b microbench
        "roofline": roofline_report.run,   # dry-run roofline table
    }
    wanted = sys.argv[1:] or list(suites)
    print("name,us_per_call,derived")
    failed = []
    for name in wanted:
        try:
            suites[name]()
        except Exception as e:  # noqa: BLE001 - report and continue
            failed.append(name)
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}")
            traceback.print_exc()
    if failed:
        raise SystemExit(f"benchmark suites failed: {failed}")


if __name__ == "__main__":
    main()
