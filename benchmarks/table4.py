"""Paper Table 4: co-design comparison vs eMEMs (normalized to QMC).

eMEMs-MRAM: homogeneous INT4 in MRAM (no noise, expensive cells);
eMEMs-ReRAM: homogeneous INT4 in 3-bit MLC ReRAM (dense, noisy, RTN with
no noise-aware scales -> worst quality). Paper: energy 0.96x/1.35x,
latency 1.9x, capacity 1.82x/0.61x, PPL 20.93/24.71 vs QMC 12.77.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Timer, emit, get_trained, heldout_ppl
from repro.configs import get_config
from repro.core.apply import quantize_model
from repro.core.noise import perturb_weights
from repro.core.qconfig import NoiseModel, QMCConfig
from repro.core.quantizers import minmax_scale
from repro.memsys import dse, evaluate_hetero, make_traffic

SEQ = 1024


def _rtn_noisy(params, key, min_dim=64):
    """eMEMs-ReRAM quality model: RTN INT4 + MLC read noise, no noise-aware

    scale optimization."""
    from repro.core.apply import is_quantizable, path_str
    import jax.tree_util as jtu
    noise = NoiseModel.for_mode(3)
    flat, treedef = jtu.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        if not is_quantizable(path_str(path), leaf, min_dim=min_dim):
            out.append(leaf)
            continue
        key, sub = jax.random.split(key)
        s = minmax_scale(leaf, 4)
        from repro.core.quantizers import fake_quant
        deq = fake_quant(leaf, s, 4)
        out.append(perturb_weights(sub, deq, jnp.broadcast_to(
            s, leaf.shape), 4, noise).astype(leaf.dtype))
    return jtu.tree_unflatten(treedef, out)


def run(model="hymba-like-hybrid", sys_model="hymba-1.5b"):
    cfg, params, corpus = get_trained(model)
    sys_arch = get_config(sys_model)
    with Timer() as t:
        # quality
        ppl_qmc = heldout_ppl(cfg, quantize_model(
            params, "qmc", qmc=QMCConfig(rho=0.3, cell_bits=3),
            noise_key=jax.random.PRNGKey(3), min_dim=64), corpus)
        ppl_em_m = heldout_ppl(cfg, quantize_model(
            params, "rtn4", min_dim=64), corpus)
        ppl_em_r = heldout_ppl(cfg, _rtn_noisy(
            params, jax.random.PRNGKey(3)), corpus)
        # system
        t_q = make_traffic(sys_arch, "qmc", seq_len=SEQ,
                           qmc=QMCConfig(rho=0.3, cell_bits=3))
        r_q = evaluate_hetero(t_q, dse(t_q, cell_bits=3))
        t_m = make_traffic(sys_arch, "emems_mram", seq_len=SEQ)
        r_m = evaluate_hetero(t_m, dse(t_m, cell_bits=3))
        t_r = make_traffic(sys_arch, "emems_reram", seq_len=SEQ)
        r_r = evaluate_hetero(t_r, dse(t_r, cell_bits=3))
    for name, r, ppl in (("qmc", r_q, ppl_qmc),
                         ("emems_mram", r_m, ppl_em_m),
                         ("emems_reram", r_r, ppl_em_r)):
        emit(f"table4/{name}", t.us / 3,
             f"norm_energy={r.energy_j/r_q.energy_j:.2f}x;"
             f"norm_latency={r.latency_s/r_q.latency_s:.2f}x;"
             f"norm_capacity={r.capacity_cells/r_q.capacity_cells:.2f}x;"
             f"ppl={ppl:.3f}")
    # the ordering claims
    emit("table4/quality_order", 0,
         f"qmc<emems_mram<emems_reram holds="
         f"{ppl_qmc < ppl_em_m <= ppl_em_r * 1.02}")
    return dict(qmc=(r_q, ppl_qmc), emems_mram=(r_m, ppl_em_m),
                emems_reram=(r_r, ppl_em_r))


if __name__ == "__main__":
    run()
