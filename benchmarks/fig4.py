"""Paper Fig. 4: system energy / latency / memory for Hymba-1.5B.

FP16 / RTN / AWQ / GPTQ / MXINT4 on the Jetson-class LPDDR5 system vs QMC
(2/3-bit MLC) on the heterogeneous NVM system. Targets: ~11x energy,
~12.5x latency, 6.3-7.3x memory cells vs FP16; ~2-3x vs AWQ/GPTQ.
"""
from __future__ import annotations

from benchmarks.common import Timer, emit
from repro.configs import get_config
from repro.core.qconfig import QMCConfig
from repro.memsys import (MemSystemConfig, dse, evaluate_conventional,
                          evaluate_hetero, make_traffic)

SEQ = 1024


def run(arch="hymba-1.5b"):
    cfg = get_config(arch)
    sys_cfg = MemSystemConfig()
    rows = {}
    with Timer() as t:
        for m in ("fp16", "rtn4", "awq", "gptq", "mx4"):
            legacy = m == "fp16"
            rows[m] = evaluate_conventional(
                make_traffic(cfg, m, seq_len=SEQ, legacy_flash=legacy),
                sys_cfg, legacy_flash=legacy)
        for cell_bits, name in ((3, "qmc_3bit"), (2, "qmc_2bit")):
            qc = QMCConfig(rho=0.3, cell_bits=cell_bits)
            traffic = make_traffic(cfg, "qmc", seq_len=SEQ, qmc=qc)
            rows[name] = evaluate_hetero(traffic,
                                         dse(traffic, cell_bits=cell_bits))
    base = rows["fp16"]
    for name, r in rows.items():
        emit(f"fig4/{arch}/{name}", t.us / len(rows),
             f"energy_mJ={r.energy_j*1e3:.2f};latency_ms="
             f"{r.latency_s*1e3:.3f};cells_MBeq="
             f"{r.capacity_cells/8/1024**2:.0f};"
             f"vs_fp16_energy={base.energy_j/r.energy_j:.2f}x;"
             f"vs_fp16_latency={base.latency_s/r.latency_s:.2f}x;"
             f"vs_fp16_cells={base.capacity_cells/r.capacity_cells:.2f}x")
    # weights-only energy view (paper's 10.98x counts the weight path)
    t_fp = make_traffic(cfg, "fp16", seq_len=SEQ)
    qc = QMCConfig(rho=0.3, cell_bits=3)
    t_q = make_traffic(cfg, "qmc", seq_len=SEQ, qmc=qc)
    from repro.memsys import devices as dv
    e_fp = t_fp.weight_bits * (dv.LPDDR5.read_energy_pj_per_bit
                               + dv.E_NETWORK_PJ_PER_BIT)
    e_q = (t_q.weight_bits_inlier * (dv.RERAM_3B.read_energy_pj_per_bit
                                     + dv.E_NETWORK_PJ_PER_BIT)
           + t_q.weight_bits_outlier * (dv.MRAM.read_energy_pj_per_bit
                                        + dv.E_NETWORK_PJ_PER_BIT))
    emit(f"fig4/{arch}/weights_only_energy", 0,
         f"vs_fp16={e_fp/e_q:.2f}x (paper: 10.98x)")
    return rows


if __name__ == "__main__":
    run()
