"""Kernel microbenchmarks: qmm (dual-stream dequant matmul) and unpack3b.

On this CPU container the Pallas kernels run in interpret mode (Python), so
wall-clock numbers are NOT TPU performance — the meaningful derived metrics
are the XLA-fallback throughput and the kernel's VMEM working set / bytes
streamed per tile (the structural quantities the TPU roofline uses).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.packing import pack_codes
from repro.core.qconfig import QMCConfig
from repro.core.qtensor import quantize_qtensor
from repro.kernels import ops
from repro.kernels.ref import qmm_ref


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.monotonic()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.monotonic() - t0) / iters * 1e6


def run():
    cfgq = QMCConfig(rho=0.3, granularity="subtile")
    for m, k, n in ((128, 512, 512), (256, 1024, 1024)):
        w = jax.random.normal(jax.random.PRNGKey(0), (k, n))
        x = jax.random.normal(jax.random.PRNGKey(1), (m, k),
                              dtype=jnp.bfloat16)
        qt = quantize_qtensor(w, cfgq)
        ref = jax.jit(lambda a, q=qt: qmm_ref(a, q))
        us_ref = _time(ref, x)
        flops = 2 * m * k * n
        # structural kernel quantities (per 128x128x128 tile step)
        vmem_kb = (128 * 128 * 4 + 2 * 8 * 128 + 2 * 128 * 4
                   + 128 * 128 * 4) / 1024
        bytes_w_packed = qt.nbytes_container()
        bytes_w_bf16 = k * n * 2
        emit(f"kernels/qmm_{m}x{k}x{n}/xla_ref", us_ref,
             f"gflops={flops/us_ref/1e3:.2f};"
             f"w_bytes_packed={bytes_w_packed};w_bytes_bf16={bytes_w_bf16};"
             f"stream_reduction={bytes_w_bf16/bytes_w_packed:.2f}x;"
             f"vmem_per_step_kb={vmem_kb:.0f}")
    # interpret-mode correctness timing (not perf) on one small shape
    w = jax.random.normal(jax.random.PRNGKey(0), (256, 256))
    x = jax.random.normal(jax.random.PRNGKey(1), (128, 256))
    qt = quantize_qtensor(w, cfgq)
    t0 = time.monotonic()
    y = ops.qmm(x, qt, use_pallas=True)
    us = (time.monotonic() - t0) * 1e6
    err = float(jnp.max(jnp.abs(y - qmm_ref(x, qt))))
    emit("kernels/qmm_128x256x256/pallas_interpret", us,
         f"max_err_vs_ref={err:.2e};mode=interpret(correctness-only)")

    codes = np.random.default_rng(0).integers(-4, 4, size=65536)
    packed = jnp.asarray(pack_codes(codes, 3))
    ref3 = jax.jit(lambda p: ops.unpack3b(p, 65536))
    us3 = _time(ref3, packed)
    emit("kernels/unpack3b_65536/xla_ref", us3,
         f"codes_per_s={65536/us3*1e6:.3g};"
         f"bytes_in={packed.nbytes};bytes_out={65536*4}")


if __name__ == "__main__":
    run()
