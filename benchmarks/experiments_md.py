"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts.

Usage: PYTHONPATH=src python -m benchmarks.experiments_md > tables.md
(The narrative sections of EXPERIMENTS.md are hand-written; this module
keeps the big tables reproducible.)
"""
from __future__ import annotations

import glob
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _load(d):
    out = []
    for p in sorted(glob.glob(os.path.join(ROOT, "artifacts", d,
                                           "*.json"))):
        out.append(json.load(open(p)))
    return out


def dryrun_table() -> str:
    recs = _load("dryrun")
    lines = ["| arch | shape | mesh | status | compile s | HLO coll. ops |"
             " arg GiB/dev | temp GiB/dev |",
             "|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        mem = r.get("memory", {})
        chips = 512 if "2x16" in r["mesh"] else 256
        arg = mem.get("argument_bytes", 0) / 1024 ** 3
        tmp = mem.get("temp_bytes", 0) / 1024 ** 3
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{'OK' if r.get('ok') else 'FAIL'} | "
            f"{r.get('compile_s', 0):.1f} | "
            f"{r.get('collectives', {}).get('count', 0)} | "
            f"{arg:.2f} | {tmp:.2f} |")
    return "\n".join(lines)


def roofline_table() -> str:
    recs = [r for r in _load("dryrun_cal") if r.get("ok")]
    lines = ["| arch | shape | t_compute s | t_memory s | t_collective s |"
             " bottleneck | MODEL_FLOPS/HLO | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"])):
        f = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {f['t_compute']:.3e} | "
            f"{f['t_memory']:.3e} | {f['t_collective']:.3e} | "
            f"{f['bottleneck']} | {f['useful_flops_ratio']:.3f} | "
            f"{f['roofline_fraction']:.4f} |")
    return "\n".join(lines)


def hillclimb_tables() -> str:
    out = []
    for p in sorted(glob.glob(os.path.join(ROOT, "artifacts", "hillclimb",
                                           "*.json"))):
        rec = json.load(open(p))
        out.append(f"\n**{rec['cell']}** "
                   f"({rec['plan']['arch']} × {rec['plan']['shape']}, "
                   f"weights={rec['plan']['serve_weights']})\n")
        out.append("| variant | t_compute | t_memory | t_collective |"
                   " roofline frac |")
        out.append("|---|---|---|---|---|")
        for v, r in rec["results"].items():
            f = r.get("roofline")
            if not f:
                out.append(f"| {v} | — | — | — | {r.get('error')} |")
                continue
            out.append(f"| {v} | {f['t_compute']:.3e} | "
                       f"{f['t_memory']:.3e} | {f['t_collective']:.3e} | "
                       f"{f['roofline_fraction']:.4f} |")
    return "\n".join(out)


if __name__ == "__main__":
    print("## Dry-run table\n")
    print(dryrun_table())
    print("\n## Roofline table (single pod, calibrated)\n")
    print(roofline_table())
    print("\n## Hillclimb tables\n")
    print(hillclimb_tables())
