"""Serving throughput: legacy per-slot engine vs paged continuous batching,
plus the shared-system-prompt multi-tenant prefix-cache workload, the QMC
serving-format (quantized-weights) engine variant, and the sharded paged
engine on a forced multi-device host mesh.

Runs a fixed synthetic workload through both engines at slots ∈ {1, 4, 8},
prints the standard ``name,us_per_call,derived`` CSV rows, and writes
``BENCH_serving.json`` with tokens/s and p50/p95 per-token decode latency
per configuration, plus the memsys paged/prefix KV traffic summaries the
§4 DSE consumes. The prefix-cache section runs N tenants whose prompts
share one system prompt and reports hit rate, prefill-token reduction and
tokens/s with the cache on vs off. The weights section compares dense fp32
against the QMC deployment format (the paper's configuration). The sharded
section re-runs the paged engine in a subprocess under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` on a (2 data, 2
model) mesh — token parity with the single-device engine plus the
per-shard Eq. (3)/(4) traffic split. The phase-breakdown section splits
each configuration's wall clock into host / device / compile shares from
the engine's phase accounting (``repro.obs``) for fp32-vs-qmc decode and
cached-vs-uncached prefill. The cost-attribution section re-runs the
fp32-vs-qmc decode pair under ``obs.costs`` capture: per step width it
reports measured wall seconds against the XLA-cost roofline bound
(drift, arithmetic intensity) plus the Eq. (3)/(4) *modeled* bytes /
energy / latency per token — the measured-vs-modeled bridge open
roadmap item 1 is judged against. The speculative section runs
self-speculative greedy decode at k ∈ {2, 4} against the plain greedy
baseline: acceptance rate, tokens/s (paired-ratio vs greedy), token
parity, plus a sampled row (temperature > 0 through the fused
in-jit sampling head). The pipeline section runs a decode-heavy
workload through the sync round loop vs ``pipelined=True`` (dispatch/
retire overlap with on-device token carry): paired tokens/s ratio,
token parity, host-blocked wall share on both sides, and the overlap /
barrier / lag-trim counters.

  PYTHONPATH=src python -m benchmarks.serving

``BENCH_SERVING_OUT=path`` redirects the JSON; ``BENCH_SECTIONS=a,b``
runs only the named sections (CI's drift check runs a fast subset).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import jax
import numpy as np

from repro.core.qconfig import QMCConfig
from repro.core.serving_quant import quantize_for_serving
from repro.memsys.workload import (kv_traffic_chunked, kv_traffic_paged,
                                   kv_traffic_prefix, make_traffic,
                                   shard_serve_traffic)
from repro.models.config import ModelConfig
from repro.models.model import init_params
from repro.obs import costs as obs_costs
from repro.serve.engine import LegacyServeEngine, Request, ServeEngine
from repro.serve.sampling import SamplingParams

OUT = os.environ.get(
    "BENCH_SERVING_OUT",
    os.path.join(os.path.dirname(__file__), "..", "BENCH_serving.json"))


def _enabled(section: str) -> bool:
    """BENCH_SECTIONS=a,b limits the run to the named sections (default:
    all) — CI's warn-only drift step runs a fast subset this way."""
    sel = os.environ.get("BENCH_SECTIONS")
    if not sel:
        return True
    return section in {s.strip() for s in sel.split(",") if s.strip()}

CFG_KW = dict(name="serve-bench", family="dense", n_layers=2,
              d_model=128, n_heads=8, n_kv_heads=2, d_ff=256, vocab=256)
# Steady-state runs per measurement; the fastest (min-wall) run is
# reported, the timeit convention: on a shared CPU host the lower
# envelope is the repeatable number, the mean is scheduler noise. The
# ratio gates (weights qmc-vs-fp32, prefix-cache speedup) compare two
# ~50 ms walls — single-shot ratios swing ±15% run to run.
REPEATS = int(os.environ.get("BENCH_REPEATS", "3"))
CFG = ModelConfig(**CFG_KW)
N_REQ = 8
MAX_NEW = 16
MAX_LEN = 64
PAGE = 16
SYS_PROMPT_LEN = 32               # shared multi-tenant prefix (2 pages)


def _requests(seed: int = 7):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(2, CFG.vocab,
                                        size=int(L)).astype(np.int32),
                    max_new_tokens=MAX_NEW)
            for i, L in enumerate(rng.integers(8, 24, size=N_REQ))]


def _tenant_requests(seed: int = 11):
    """N tenants: one shared system prompt + a short unique user turn."""
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(2, CFG.vocab, SYS_PROMPT_LEN)
    return [Request(uid=i,
                    prompt=np.concatenate(
                        [sys_prompt,
                         rng.integers(2, CFG.vocab, int(L))]
                    ).astype(np.int32),
                    max_new_tokens=MAX_NEW)
            for i, L in enumerate(rng.integers(6, 14, size=N_REQ))]


def _pcts(lat):
    """p50/p95 of a latency sample list; zeros (not a crash) when the
    sample is empty — callers mark those sections degenerate."""
    lat = np.asarray(lat, dtype=float).ravel()
    if lat.size == 0:
        return 0.0, 0.0
    return (float(np.percentile(lat, 50)), float(np.percentile(lat, 95)))


def _engine_row(eng, out) -> dict:
    toks = sum(len(r.out_tokens) for r in out)
    lat = eng.stats.per_token_latencies()
    p50, p95 = _pcts(lat)
    return {"tokens": toks, "tokens_per_s": toks / eng.stats.wall_s,
            "wall_s": eng.stats.wall_s, "decode_calls":
            eng.stats.decode_steps, "prefills": eng.stats.prefills,
            "p50_token_latency_us": p50 * 1e6,
            "p95_token_latency_us": p95 * 1e6,
            "latency_samples": len(lat),
            "degenerate": len(lat) == 0,
            "preemptions": eng.stats.preemptions,
            "pages_peak": eng.stats.pages_peak}


def _measure(engine_cls, params, slots: int, **kw):
    # warm-up run pays every jit compile; then REPEATS steady-state runs,
    # fastest wall reported (see REPEATS above)
    engine_cls(CFG, params, slots=slots, max_len=MAX_LEN, **kw).run(
        _requests())
    eng, out = None, None
    for _ in range(REPEATS):
        e = engine_cls(CFG, params, slots=slots, max_len=MAX_LEN, **kw)
        o = e.run(_requests())
        if eng is None or e.stats.wall_s < eng.stats.wall_s:
            eng, out = e, o
    return _engine_row(eng, out)


def _paired_ratio(make_a, make_b, reqs_fn):
    """Median of per-pair throughput ratios b/a over REPEATS interleaved
    run pairs. The two configurations execute back-to-back inside each
    pair, so slow host drift (frequency scaling, neighbour load — the
    dominant noise on ~50 ms walls) hits both sides of a pair about
    equally and cancels in the ratio; the median then rejects the odd
    pair a burst split. Independent min-walls do not get that
    cancellation. Returns ((eng_a, res_a), (eng_b, res_b), ratio) where
    each (eng, res) is that side's fastest run."""
    best = [None, None]
    ratios = []
    for r in range(REPEATS):
        tps = [0.0, 0.0]
        order = (0, 1) if r % 2 == 0 else (1, 0)   # cancel ordering bias
        for i in order:
            eng = (make_a, make_b)[i]()
            res = eng.run(reqs_fn())
            toks = sum(len(rq.out_tokens) for rq in res)
            tps[i] = toks / eng.stats.wall_s
            if best[i] is None or eng.stats.wall_s < best[i][0].stats.wall_s:
                best[i] = (eng, res)
        ratios.append(tps[1] / max(tps[0], 1e-9))
    return best[0], best[1], float(np.median(ratios))


def run() -> dict:
    params = init_params(CFG, jax.random.PRNGKey(0))
    results = {"config": {"model": CFG.name, "n_requests": N_REQ,
                          "max_new_tokens": MAX_NEW, "max_len": MAX_LEN,
                          "page": PAGE, "repeats": REPEATS}}
    if _enabled("slots"):
        results["slots"] = {}
        for slots in (1, 4, 8):
            legacy = _measure(LegacyServeEngine, params, slots)
            paged = _measure(ServeEngine, params, slots, page_size=PAGE)
            speedup = paged["tokens_per_s"] / max(legacy["tokens_per_s"],
                                                  1e-9)
            results["slots"][str(slots)] = {"legacy": legacy,
                                            "paged": paged,
                                            "speedup": speedup}
            print(f"serving/legacy_s{slots},"
                  f"{legacy['p50_token_latency_us']:.0f},"
                  f"{legacy['tokens_per_s']:.1f}tok/s")
            print(f"serving/paged_s{slots},"
                  f"{paged['p50_token_latency_us']:.0f},"
                  f"{paged['tokens_per_s']:.1f}tok/s "
                  f"speedup={speedup:.2f}x")
    if _enabled("paged_kv_traffic"):
        # batch-dependent KV stream once every request is full-length
        lens = [len(r.prompt) + MAX_NEW for r in _requests()]
        t = kv_traffic_paged(CFG, lens, page=PAGE)
        results["paged_kv_traffic"] = {
            "n_pages": t.n_pages,
            "kv_bits_per_step": t.kv_bits_per_step,
            "frag_bits_per_step": t.frag_bits_per_step,
            "utilization": t.utilization}
    if _enabled("prefix_cache"):
        results["prefix_cache"] = {
            "sys_prompt_len": SYS_PROMPT_LEN,
            "slots": {str(s): _measure_prefix(params, s) for s in (4, 8)}}
    if _enabled("weights"):
        results["weights"] = _measure_weights(params)
    if _enabled("paged_attention"):
        results["paged_attention"] = _measure_paged_attention(params)
    if _enabled("chunked_prefill"):
        results["chunked_prefill"] = _measure_chunked(params)
    if _enabled("phase_breakdown"):
        results["phase_breakdown"] = _measure_phases(params)
    if _enabled("cost_attribution"):
        results["cost_attribution"] = _measure_costs(params)
    if _enabled("speculative"):
        results["speculative"] = _measure_speculative(params)
    if _enabled("pipeline"):
        results["pipeline"] = _measure_pipeline(params)
    if _enabled("sharded"):
        results["sharded"] = _measure_sharded()
    with open(OUT, "w") as f:
        json.dump(results, f, indent=2)
    print(f"serving/json,0,{os.path.abspath(OUT)}")
    return results


def _measure_prefix(params, slots: int) -> dict:
    """Shared-system-prompt tenants, prefix cache on vs off."""
    out = {}
    # warm-up pays jit compiles; the measured engines start with an
    # initially empty index (intra-batch sharing only). Interleaved
    # paired runs so the speedup ratio cancels host drift.
    for on in (False, True):
        ServeEngine(CFG, params, slots=slots, max_len=MAX_LEN,
                    page_size=PAGE, prefix_cache=on).run(_tenant_requests())

    def mk(on):
        return lambda: ServeEngine(CFG, params, slots=slots,
                                   max_len=MAX_LEN, page_size=PAGE,
                                   prefix_cache=on)
    best_off, best_on, speedup = _paired_ratio(mk(False), mk(True),
                                               _tenant_requests)
    for label, (eng, res) in (("off", best_off), ("on", best_on)):
        toks = sum(len(r.out_tokens) for r in res)
        s = eng.stats
        out[label] = {
            "tokens": toks, "tokens_per_s": toks / s.wall_s,
            "prefill_tokens": s.prefill_tokens,
            "prefill_tokens_padded": s.prefill_tokens_padded,
            "prompt_tokens": s.prompt_tokens,
            "hit_rate": s.hit_rate,
            "prefill_token_reduction": s.prefill_token_reduction,
            "cache_hits": s.cache_hits,
            "cow_copies": s.cow_copies,
            "tables_rebuilds": s.device_tables_rebuilds,
            "page_op_flushes": s.page_op_flushes,
            "page_op_round_trips_saved": s.page_op_round_trips_saved,
            "solo_rounds": s.solo_rounds}
    out["prefill_speedup"] = speedup
    # DSE views. "cold": the measured batch's prefill WRITES (the first
    # tenant publishes, the rest hit). "steady": residency once the
    # prefix is resident — every tenant aliases the shared pages,
    # including the publisher, whose copy IS the shared set (listing it
    # as a miss would double-count those pages).
    reqs = _tenant_requests()
    prompt_lens = [len(r.prompt) for r in reqs]
    sys_cached = (SYS_PROMPT_LEN // PAGE) * PAGE
    cold = kv_traffic_prefix(
        CFG, prompt_lens, [0] + [sys_cached] * (len(reqs) - 1), page=PAGE)
    steady = kv_traffic_prefix(
        CFG, prompt_lens, [sys_cached] * len(reqs), page=PAGE)
    out["dse"] = {
        "hit_rate": cold.hit_rate,
        "prefill_write_bits": cold.prefill_write_bits,
        "prefill_write_bits_nocache": cold.prefill_write_bits_nocache,
        "saved_prefill_write_bits": cold.saved_prefill_write_bits,
        "resident_bits": steady.resident_bits,
        "resident_bits_nocache": steady.resident_bits_nocache,
        "n_pages": steady.n_pages,
        "n_pages_nocache": steady.n_pages_nocache}
    print(f"serving/prefix_s{slots},0,"
          f"hit={out['on']['hit_rate']:.2f} "
          f"prefill_reduction={out['on']['prefill_token_reduction']:.2f} "
          f"speedup={speedup:.2f}x")
    return out


def _measure_weights(params) -> dict:
    """Dense fp32 vs QMC serving-format weights through the paged engine —
    the paper's deployment configuration (eMEM-resident quantized weights
    feeding the bandwidth-bound decode loop) tracked alongside dense."""
    qparams = quantize_for_serving(
        params, QMCConfig(rho=0.3, granularity="subtile"), tp_shards=1,
        min_dim=64)
    # warm-up pays jit compiles (shared: both variants lower to the same
    # dense step via the exec-weight plan) + the qmc plan build
    for p in (params, qparams):
        ServeEngine(CFG, p, slots=4, max_len=MAX_LEN,
                    page_size=PAGE).run(_requests())
    best_f, best_q, ratio = _paired_ratio(
        lambda: ServeEngine(CFG, params, slots=4, max_len=MAX_LEN,
                            page_size=PAGE),
        lambda: ServeEngine(CFG, qparams, slots=4, max_len=MAX_LEN,
                            page_size=PAGE),
        _requests)
    out = {"fp32": _engine_row(*best_f), "qmc": _engine_row(*best_q),
           "qmc_vs_fp32_tokens_per_s": ratio}
    print(f"serving/weights_qmc_s4,"
          f"{out['qmc']['p50_token_latency_us']:.0f},"
          f"{out['qmc']['tokens_per_s']:.1f}tok/s "
          f"vs_fp32={out['qmc_vs_fp32_tokens_per_s']:.2f}x")
    return out


def _measure_paged_attention(params) -> dict:
    """Pallas page-table kernel vs the XLA full-width reference gather.

    Records token parity, tokens/s (interpret-mode kernel on CPU — the
    wall-clock column is meaningful on a TPU backend only), and the
    gather-work split the kernel changes: live pages actually streamed vs
    the full block-table width the reference materializes, counted by the
    engine per decode step AND charged by the DSE
    (``kv_traffic_paged(live_only=...)``) so the two accounts are shown
    side by side."""
    def timed(**kw):
        # warm-up pays jit compiles; the timed second run also supplies
        # the parity tokens and gather-work counters (no extra runs)
        ServeEngine(CFG, params, slots=4, max_len=MAX_LEN,
                    page_size=PAGE, **kw).run(_requests())
        eng = ServeEngine(CFG, params, slots=4, max_len=MAX_LEN,
                          page_size=PAGE, **kw)
        res = eng.run(_requests())
        p50, p95 = _pcts(eng.stats.per_token_latencies())
        row = {"tokens": sum(len(r.out_tokens) for r in res),
               "tokens_per_s": eng.stats.tokens_per_s,
               "decode_calls": eng.stats.decode_steps,
               "p50_token_latency_us": p50 * 1e6,
               "p95_token_latency_us": p95 * 1e6}
        return row, [r.out_tokens for r in res], eng
    out = {}
    out["reference"], ref_toks, _ = timed()
    out["kernel"], kern_toks, eng = timed(paged_attention=True)
    s = eng.stats
    out["token_parity"] = ref_toks == kern_toks
    out["gather_work"] = {
        "kv_pages_live": s.kv_pages_live,
        "kv_pages_full_width": s.kv_pages_full,
        "live_fraction": s.kv_pages_live / max(s.kv_pages_full, 1)}
    # DSE view at the moment every request is full length
    lens = [len(r.prompt) + MAX_NEW for r in _requests()]
    mpps = eng.max_pages_per_seq
    live = kv_traffic_paged(CFG, lens, page=PAGE)
    wide = kv_traffic_paged(CFG, lens, page=PAGE, live_only=False,
                            max_pages_per_seq=mpps)
    out["dse"] = {
        "kv_bits_per_step_live": live.kv_bits_per_step,
        "kv_bits_per_step_full_width": wide.kv_bits_per_step,
        "dead_page_bits_per_step": (wide.kv_bits_per_step
                                    - live.kv_bits_per_step)}
    print(f"serving/paged_attention_s4,"
          f"{out['kernel']['p50_token_latency_us']:.0f},"
          f"parity={out['token_parity']} "
          f"live_pages={s.kv_pages_live}/{s.kv_pages_full} "
          f"({1 - out['gather_work']['live_fraction']:.0%} gather saved)")
    return out


CHUNK = 16


def _mixed_requests(seed: int = 17):
    """Long-prompt + short-decode interactive mix: the workload where
    monolithic prefill stalls in-flight decodes (TTFT/ITL jitter)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(N_REQ):
        long_ = i % 4 == 0                 # every 4th request: long prompt
        L = 44 if long_ else int(rng.integers(4, 10))
        reqs.append(Request(
            uid=i, prompt=rng.integers(2, CFG.vocab, L).astype(np.int32),
            max_new_tokens=MAX_NEW))
    return reqs


def _measure_chunked(params) -> dict:
    """Chunked vs monolithic prefill through the ONE ragged step.

    Both modes run the same unified step (ragged Pallas kernel); only the
    chunk width differs — monolithic covers any prompt in one chunk,
    chunked splits long prompts so decode lanes keep emitting between
    chunks. Reports TTFT and ITL p50/p95 for the mixed workload, the
    live-gather page counts the prefill chunks streamed (engine counters)
    and the matching chunk-granular Eq. (3)/(4) DSE account."""
    out = {}
    toks = {}
    for label, chunk in (("monolithic", None), ("chunked", CHUNK)):
        ServeEngine(CFG, params, slots=4, max_len=MAX_LEN, page_size=PAGE,
                    chunk_tokens=chunk, paged_attention=True).run(
            _mixed_requests())         # warm-up pays the jit compiles
        eng = ServeEngine(CFG, params, slots=4, max_len=MAX_LEN,
                          page_size=PAGE, chunk_tokens=chunk,
                          paged_attention=True)
        res = eng.run(_mixed_requests())
        s = eng.stats
        ttft50, ttft95 = _pcts(s.ttft_s)
        # ITL from per-request emission timestamps: a decode lane's true
        # gap between consecutive tokens, including rounds it sat out
        # while a co-scheduled prefill chunk ran — the jitter chunking is
        # supposed to bound. The round-averaged step latency (wall/tokens
        # per round) is kept alongside: it hides exactly that jitter.
        itl = s.itl_s()
        itl50, itl95 = _pcts(itl)
        ravg50, ravg95 = _pcts(s.per_token_latencies())
        toks[label] = [r.out_tokens for r in res]
        out[label] = {
            "tokens": sum(len(r.out_tokens) for r in res),
            "tokens_per_s": s.tokens_per_s,
            "prefill_chunks": s.prefill_chunks,
            "ttft_p50_us": ttft50 * 1e6, "ttft_p95_us": ttft95 * 1e6,
            "itl_p50_us": itl50 * 1e6, "itl_p95_us": itl95 * 1e6,
            "itl_samples": len(itl),
            "itl_round_avg_p50_us": ravg50 * 1e6,
            "itl_round_avg_p95_us": ravg95 * 1e6,
            "prefill_kv_pages_live": s.prefill_kv_pages_live,
            "prefill_kv_pages_written": s.prefill_kv_pages_written}
    out["token_parity"] = toks["monolithic"] == toks["chunked"]
    # chunk-granular DSE view of the same prompts (page-for-page with the
    # engine counters — pinned by tests/test_memsys.py)
    lens = [len(r.prompt) for r in _mixed_requests()]
    t_chunk = [kv_traffic_chunked(CFG, L, chunk=CHUNK, page=PAGE)
               for L in lens]
    out["dse"] = {
        "kv_pages_read": sum(t.kv_pages_read for t in t_chunk),
        "kv_pages_written": sum(t.kv_pages_written for t in t_chunk),
        "kv_pages_read_monolithic": sum(t.kv_pages_read_monolithic
                                        for t in t_chunk),
        "prefill_kv_bits": sum(t.kv_read_bits + t.kv_write_bits
                               for t in t_chunk)}
    print(f"serving/chunked_prefill_c{CHUNK},"
          f"{out['chunked']['itl_p95_us']:.0f},"
          f"parity={out['token_parity']} "
          f"ttft_p95={out['chunked']['ttft_p95_us']:.0f}us"
          f"(mono {out['monolithic']['ttft_p95_us']:.0f}us) "
          f"chunk_pages={out['chunked']['prefill_kv_pages_live']}")
    return out


def _phase_row(cold, eng) -> dict:
    """Phase shares of one warm run + compile attribution from the cold
    run that preceded it (same engine geometry, fresh jit cache)."""
    s = eng.stats
    wall = max(s.wall_s, 1e-9)
    cold_wall = max(cold.stats.wall_s, 1e-9)
    return {
        "wall_s": s.wall_s, "rounds": s.rounds,
        "tokens_per_s": s.tokens_per_s,
        "host_s": s.host_seconds(), "device_s": s.device_seconds(),
        "host_share": s.host_seconds() / wall,
        "device_share": s.device_seconds() / wall,
        "phase_seconds": {k: round(v, 6)
                          for k, v in sorted(s.phase_seconds.items())},
        "adopt_calls": s.adopt_calls,
        "page_copy_calls": s.page_copy_calls,
        "device_tables_rebuilds": s.device_tables_rebuilds,
        "jit_compiles_warm": s.jit_compiles,
        "cold_jit_compiles": cold.stats.jit_compiles,
        "cold_compile_s": cold.stats.jit_compile_s,
        "cold_compile_share": cold.stats.jit_compile_s / cold_wall}


def _measure_phases(params) -> dict:
    """Where a round's wall time goes: host bookkeeping vs device step vs
    jit compilation, from the engine's always-on ``phase_seconds``
    accounting (no tracer needed). Two comparisons the open roadmap items
    hinge on: fp32-vs-qmc decode (is the qmc slowdown device math or host
    overhead?) and cached-vs-uncached multi-tenant prefill (how much of
    the prefix-cache regression is adopt/COW/table host round trips?)."""
    def pair(p, reqs_fn, **kw):
        cold = ServeEngine(CFG, p, slots=4, max_len=MAX_LEN,
                           page_size=PAGE, **kw)
        cold.run(reqs_fn())            # pays the jit compiles
        eng = ServeEngine(CFG, p, slots=4, max_len=MAX_LEN,
                          page_size=PAGE, **kw)
        eng.run(reqs_fn())             # steady state
        return _phase_row(cold, eng)

    qparams = quantize_for_serving(
        params, QMCConfig(rho=0.3, granularity="subtile"), tp_shards=1,
        min_dim=64)
    out = {"decode": {"fp32": pair(params, _requests),
                      "qmc": pair(qparams, _requests)},
           "prefill": {"uncached": pair(params, _tenant_requests),
                       "cached": pair(params, _tenant_requests,
                                      prefix_cache=True)}}
    d, p = out["decode"], out["prefill"]
    print(f"serving/phases_decode_s4,0,"
          f"fp32_host={d['fp32']['host_share']:.0%} "
          f"qmc_host={d['qmc']['host_share']:.0%} "
          f"qmc_device={d['qmc']['device_share']:.0%}")
    print(f"serving/phases_prefix_s4,0,"
          f"uncached_host={p['uncached']['host_share']:.0%} "
          f"cached_host={p['cached']['host_share']:.0%} "
          f"adopts={p['cached']['adopt_calls']} "
          f"tbl_rebuilds={p['cached']['device_tables_rebuilds']}")
    return out


def _measure_costs(params) -> dict:
    """fp32-vs-qmc decode under ``obs.costs`` capture at the same slot
    count: per step width, measured wall seconds against the XLA-cost
    roofline bound (drift / roofline fraction / arithmetic intensity)
    plus the Eq. (3)/(4) modeled bytes/energy/latency per token from the
    run's own engine counters — measured and modeled side by side.

    Fresh engines per label: capture keys on call shapes each TracedJit
    wrapper has seen, so it fires even over the lru-warm jit cache; the
    warm-up engine absorbs the compiles so the measured engine's
    per-shape wall tables are steady state."""
    qparams = quantize_for_serving(
        params, QMCConfig(rho=0.3, granularity="subtile"), tp_shards=1,
        min_dim=64)
    prev = obs_costs.enable_capture()
    try:
        out = {}
        for label, p in (("fp32", params), ("qmc", qparams)):
            ServeEngine(CFG, p, slots=4, max_len=MAX_LEN,
                        page_size=PAGE).run(_requests())
            eng = ServeEngine(CFG, p, slots=4, max_len=MAX_LEN,
                              page_size=PAGE)
            eng.run(_requests())
            out[label] = eng.last_cost_report.to_dict()
    finally:
        obs_costs.enable_capture(prev)
    ratio = (out["qmc"]["modeled"]["bytes_per_token"]
             / max(out["fp32"]["modeled"]["bytes_per_token"], 1e-9))
    out["qmc_vs_fp32_modeled_bytes_per_token"] = ratio
    step_rows = [r for r in out["qmc"]["fns"] if r["fn"] == "step"]
    frac = max((r["roofline_fraction"] for r in step_rows), default=0.0)
    print(f"serving/cost_attr_s4,0,"
          f"qmc_vs_fp32_modeled_bytes={ratio:.3f}x "
          f"qmc_step_roofline_frac={frac:.2e} "
          f"qmc_modeled="
          f"{out['qmc']['modeled']['bytes_per_token'] / 1e3:.1f}KB/tok")
    return out


def _spec_requests(seed: int = 23):
    """Workload for prompt-lookup speculation: half the prompts carry a
    repeated n-gram (the draft's bread and butter — instruction templates,
    code, quoted context), half are uniform-random (its worst case), so
    the acceptance rate is a blend rather than a best-case headline."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(N_REQ):
        if i % 2 == 0:
            core = rng.integers(2, CFG.vocab, 5)
            prompt = np.tile(core, 4)[:18]
        else:
            prompt = rng.integers(2, CFG.vocab, int(rng.integers(8, 24)))
        reqs.append(Request(uid=i, prompt=prompt.astype(np.int32),
                            max_new_tokens=MAX_NEW))
    return reqs


def _measure_speculative(params) -> dict:
    """Jitted sampling head + self-speculative greedy decode.

    Greedy baseline vs self-speculative at k ∈ {2, 4}: acceptance rate,
    verify-round counts, tokens/s, and token parity (speculative greedy
    must be token-identical to plain greedy at every k — acceptance only
    changes WHEN tokens appear, never WHICH). The headline ratio
    ``tokens_per_s_vs_greedy`` comes from interleaved k=4/greedy pairs
    (see ``_paired_ratio``); on this tiny CPU model the verify rung costs
    about as much as the C=1 decode step it replaces, so the ratio mostly
    reflects acceptance — on a bandwidth-bound edge target the verify
    step rereads the weights once for 1+k tokens and the same acceptance
    buys real speedup. A sampled row (temperature>0 through the fused
    sampling head) tracks the sampling epilogue's overhead vs greedy."""
    def mk(k):
        return lambda: ServeEngine(CFG, params, slots=4, max_len=MAX_LEN,
                                   page_size=PAGE, speculative_k=k)
    # warm-up pays the jit compiles (C=1 decode plus each verify rung)
    for k in (0, 2, 4):
        mk(k)().run(_spec_requests())
    best_g, best_k4, ratio = _paired_ratio(mk(0), mk(4), _spec_requests)
    g_eng, g_res = best_g
    g_toks = [r.out_tokens for r in g_res]
    out = {"greedy": _engine_row(g_eng, g_res)}
    for k in (2, 4):
        if k == 4:
            eng, res = best_k4
        else:
            eng = mk(k)()
            res = eng.run(_spec_requests())
        s = eng.stats
        out[f"k{k}"] = {
            "tokens": sum(len(r.out_tokens) for r in res),
            "tokens_per_s": s.tokens_per_s,
            "rounds": s.rounds,
            "spec_rounds": s.spec_rounds,
            "draft_tokens": s.spec_draft_tokens,
            "accepted_tokens": s.spec_accepted_tokens,
            "acceptance_rate": s.spec_acceptance_rate,
            "token_parity_vs_greedy":
                [r.out_tokens for r in res] == g_toks}
    out["tokens_per_s_vs_greedy"] = ratio
    # sampled path: same engine geometry, fused sampling head active
    sp = SamplingParams(temperature=0.8, top_k=16, top_p=0.95, seed=7)
    ServeEngine(CFG, params, slots=4, max_len=MAX_LEN, page_size=PAGE,
                sampling=sp).run(_spec_requests())
    eng = ServeEngine(CFG, params, slots=4, max_len=MAX_LEN,
                      page_size=PAGE, sampling=sp)
    res = eng.run(_spec_requests())
    out["sampled"] = {"temperature": sp.temperature, "top_k": sp.top_k,
                      "top_p": sp.top_p,
                      "tokens": sum(len(r.out_tokens) for r in res),
                      "tokens_per_s": eng.stats.tokens_per_s}
    print(f"serving/speculative_s4,0,"
          f"k4_accept={out['k4']['acceptance_rate']:.2f} "
          f"parity={out['k4']['token_parity_vs_greedy']} "
          f"vs_greedy={ratio:.2f}x")
    return out


def _pipeline_requests(seed: int = 31):
    """Decode-heavy workload: short prompts, long generations — the
    steady-state regime where round N's host planning can hide behind
    round N-1's device step + readback."""
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(2, CFG.vocab,
                                        int(L)).astype(np.int32),
                    max_new_tokens=32)
            for i, L in enumerate(rng.integers(4, 10, size=N_REQ))]


def _measure_pipeline(params) -> dict:
    """Sync vs pipelined round loop at slots=8 on the decode-heavy mix.

    The paired ratio is the headline (``tokens_per_s_vs_sync``); the
    per-side rows split each wall into host-blocked vs device share —
    pipelining is supposed to move ``block_until_ready`` wait out of
    the host-blocked column, so the pipelined side's
    ``host_blocked_share`` should drop even when the CPU backend's
    tokens/s gain is modest (device work and host work contend for the
    same cores here; on an accelerator the overlap is real
    concurrency). Token parity is asserted per run — the pipeline is a
    scheduling change, never a decoding change."""
    def mk(pipelined):
        return lambda: ServeEngine(CFG, params, slots=8, max_len=MAX_LEN,
                                   page_size=PAGE, pipelined=pipelined)
    for p in (False, True):            # warm-up pays the jit compiles
        mk(p)().run(_pipeline_requests())
    best_s, best_p, ratio = _paired_ratio(mk(False), mk(True),
                                          _pipeline_requests)

    def row(eng, res):
        s = eng.stats
        wall = max(s.wall_s, 1e-9)
        return {"tokens": sum(len(r.out_tokens) for r in res),
                "tokens_per_s": s.tokens_per_s,
                "rounds": s.rounds,
                "host_s": s.host_seconds(),
                "host_blocked_share": s.host_seconds() / wall,
                "device_s": s.device_seconds(),
                "pipelined_rounds": s.pipelined_rounds,
                "pipeline_overlap": s.pipeline_overlap,
                "pipeline_barriers": s.pipeline_barriers,
                "lag_trimmed_tokens": s.lag_trimmed_tokens}

    out = {"sync": row(*best_s), "pipelined": row(*best_p),
           "token_parity": ([r.out_tokens for r in best_s[1]]
                            == [r.out_tokens for r in best_p[1]]),
           "tokens_per_s_vs_sync": ratio}
    print(f"serving/pipeline_s8,0,"
          f"vs_sync={ratio:.2f}x "
          f"parity={out['token_parity']} "
          f"overlap={out['pipelined']['pipeline_overlap']:.0%} "
          f"host_share={out['sync']['host_blocked_share']:.0%}"
          f"->{out['pipelined']['host_blocked_share']:.0%} "
          f"trimmed={out['pipelined']['lag_trimmed_tokens']}")
    return out


_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json
import jax, numpy as np
from repro.launch import mesh as meshlib
from repro.models.config import ModelConfig
from repro.models.model import init_params
from repro.serve.engine import Request, ServeEngine

# the parent injects its OWN config + workload, so the subprocess can
# never drift from what the in-process sections measured
spec = json.loads(os.environ["BENCH_SHARDED_SPEC"])
CFG = ModelConfig(**spec["cfg"])

def requests():
    return [Request(uid=i, prompt=np.asarray(p, np.int32),
                    max_new_tokens=spec["max_new"])
            for i, p in enumerate(spec["prompts"])]

params = init_params(CFG, jax.random.PRNGKey(0))
out = {}
toks = {}
for label, mesh in (("1dev", None),
                    ("mesh2x2", meshlib.make_mesh((2, 2),
                                                  ("data", "model")))):
    # one engine, two runs: mesh step sets are built per engine (only the
    # mesh=None builders are lru-shared), so a fresh engine would pay its
    # jit compiles inside the timed run — reuse the warmed engine instead
    # (stats reset per run(), and with no prefix cache no state carries)
    eng = ServeEngine(CFG, params, slots=8, max_len=spec["max_len"],
                      page_size=spec["page"], mesh=mesh)
    eng.run(requests())               # warm-up pays jit compiles
    reqs = requests()
    eng.run(reqs)
    toks[label] = [r.out_tokens for r in reqs]
    out[label] = {"tokens_per_s": eng.stats.tokens_per_s,
                  "decode_calls": eng.stats.decode_steps}
out["token_parity"] = toks["1dev"] == toks["mesh2x2"]
print("RESULT" + json.dumps(out))
"""


def _measure_sharded() -> dict:
    """Paged engine on a forced 4-device host mesh (subprocess: the forced

    device count must be set before jax initializes) + the per-shard
    DSE traffic split for the mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    env["BENCH_SHARDED_SPEC"] = json.dumps({
        "cfg": CFG_KW, "page": PAGE, "max_len": MAX_LEN,
        "max_new": MAX_NEW,
        "prompts": [r.prompt.tolist() for r in _requests()]})
    try:
        proc = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT],
                              env=env, capture_output=True, text=True,
                              timeout=1200)
        line = [l for l in proc.stdout.splitlines()
                if l.startswith("RESULT")]
        if proc.returncode != 0 or not line:
            return {"error": proc.stderr[-2000:]}
        out = json.loads(line[0][len("RESULT"):])
    except subprocess.TimeoutExpired:
        return {"error": "sharded subprocess timed out"}
    print(f"serving/sharded_2x2,0,"
          f"{out['mesh2x2']['tokens_per_s']:.1f}tok/s "
          f"parity={out['token_parity']}")
    # per-shard Eq.(3)/(4) streams: what ONE device of the (2,2) mesh pulls
    base = make_traffic(CFG, "qmc", seq_len=MAX_LEN)
    lens = [len(r.prompt) + MAX_NEW for r in _requests()]
    paged = kv_traffic_paged(CFG, lens, page=PAGE)
    per_dev = shard_serve_traffic(paged.apply(base), data_shards=2,
                                  model_shards=2)
    out["per_shard_dse"] = {
        "name": per_dev.name,
        "weight_bits_per_step": per_dev.weight_bits,
        "kv_bits_per_step": per_dev.kv_bits,
        "act_bits_per_step": per_dev.act_bits,
        "aggregate_kv_bits_per_step": paged.kv_bits_per_step}
    return out


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
