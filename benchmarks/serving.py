"""Serving throughput: legacy per-slot engine vs paged continuous batching.

Runs a fixed synthetic workload through both engines at slots ∈ {1, 4, 8},
prints the standard ``name,us_per_call,derived`` CSV rows, and writes
``BENCH_serving.json`` with tokens/s and p50/p95 per-token decode latency
per configuration, plus the memsys paged-KV traffic summary the §4 DSE
consumes.

  PYTHONPATH=src python -m benchmarks.serving
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from repro.memsys.workload import kv_traffic_paged
from repro.models.config import ModelConfig
from repro.models.model import init_params
from repro.serve.engine import LegacyServeEngine, Request, ServeEngine

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_serving.json")

CFG = ModelConfig(name="serve-bench", family="dense", n_layers=2,
                  d_model=128, n_heads=8, n_kv_heads=2, d_ff=256, vocab=256)
N_REQ = 8
MAX_NEW = 16
MAX_LEN = 64
PAGE = 16


def _requests(seed: int = 7):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(2, CFG.vocab,
                                        size=int(L)).astype(np.int32),
                    max_new_tokens=MAX_NEW)
            for i, L in enumerate(rng.integers(8, 24, size=N_REQ))]


def _pcts(lat):
    if not lat:
        return 0.0, 0.0
    return (float(np.percentile(lat, 50)), float(np.percentile(lat, 95)))


def _measure(engine_cls, params, slots: int, **kw):
    # warm-up run pays every jit compile; second run is steady state
    engine_cls(CFG, params, slots=slots, max_len=MAX_LEN, **kw).run(
        _requests())
    eng = engine_cls(CFG, params, slots=slots, max_len=MAX_LEN, **kw)
    out = eng.run(_requests())
    toks = sum(len(r.out_tokens) for r in out)
    p50, p95 = _pcts(eng.stats.per_token_latencies())
    return {"tokens": toks, "tokens_per_s": toks / eng.stats.wall_s,
            "wall_s": eng.stats.wall_s, "decode_calls":
            eng.stats.decode_steps, "prefills": eng.stats.prefills,
            "p50_token_latency_us": p50 * 1e6,
            "p95_token_latency_us": p95 * 1e6,
            "preemptions": eng.stats.preemptions,
            "pages_peak": eng.stats.pages_peak}


def run() -> dict:
    params = init_params(CFG, jax.random.PRNGKey(0))
    results = {"config": {"model": CFG.name, "n_requests": N_REQ,
                          "max_new_tokens": MAX_NEW, "max_len": MAX_LEN,
                          "page": PAGE},
               "slots": {}}
    for slots in (1, 4, 8):
        legacy = _measure(LegacyServeEngine, params, slots)
        paged = _measure(ServeEngine, params, slots, page_size=PAGE)
        speedup = paged["tokens_per_s"] / max(legacy["tokens_per_s"], 1e-9)
        results["slots"][str(slots)] = {"legacy": legacy, "paged": paged,
                                        "speedup": speedup}
        print(f"serving/legacy_s{slots},"
              f"{legacy['p50_token_latency_us']:.0f},"
              f"{legacy['tokens_per_s']:.1f}tok/s")
        print(f"serving/paged_s{slots},"
              f"{paged['p50_token_latency_us']:.0f},"
              f"{paged['tokens_per_s']:.1f}tok/s "
              f"speedup={speedup:.2f}x")
    # batch-dependent KV stream at the moment every request is full-length
    lens = [len(r.prompt) + MAX_NEW for r in _requests()]
    t = kv_traffic_paged(CFG, lens, page=PAGE)
    results["paged_kv_traffic"] = {
        "n_pages": t.n_pages,
        "kv_bits_per_step": t.kv_bits_per_step,
        "frag_bits_per_step": t.frag_bits_per_step,
        "utilization": t.utilization}
    with open(OUT, "w") as f:
        json.dump(results, f, indent=2)
    print(f"serving/json,0,{os.path.abspath(OUT)}")
    return results


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
