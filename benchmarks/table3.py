"""Paper Table 3: AWQ / GPTQ / QMC(no-noise), algorithm-only comparison.

Claim: data-free QMC matches or beats the calibration-based methods; and —
the paper's §1 deployability point — GPTQ/AWQ need per-layer activation
capture (which breaks on new architectures), QMC does not. Our SSM/hybrid
models exercise exactly that: taps work here because we built them, but QMC
needs none.
"""
from __future__ import annotations

import jax

from benchmarks.common import (Timer, cloze_accuracy, emit, get_trained,
                               heldout_ppl)
from repro.core.apply import quantize_model
from repro.core.qconfig import AWQConfig, GPTQConfig, QMCConfig
from repro.models.model import forward


def _capture_taps(cfg, params, corpus):
    taps = {}
    b = corpus.sample_batch(8, 48, step=123)
    forward(cfg, params, jax.numpy.asarray(b["tokens"]), taps=taps,
            scan_layers=False)
    return taps


def run(models=("qwen-like-dense", "hymba-like-hybrid")):
    rows = []
    for mname in models:
        cfg, params, corpus = get_trained(mname)
        taps = _capture_taps(cfg, params, corpus)
        variants = {
            "awq": lambda: quantize_model(params, "awq", taps=taps,
                                          awq=AWQConfig(bits=4),
                                          min_dim=64),
            "gptq": lambda: quantize_model(params, "gptq", taps=taps,
                                           gptq=GPTQConfig(bits=4),
                                           min_dim=64),
            "qmc_no_noise": lambda: quantize_model(
                params, "qmc", qmc=QMCConfig(rho=0.3), noise_key=None,
                min_dim=64),
        }
        for vname, make in variants.items():
            with Timer() as t:
                q = make()
                ppl = heldout_ppl(cfg, q, corpus)
                acc = cloze_accuracy(cfg, q, corpus)
            emit(f"table3/{mname}/{vname}", t.us,
                 f"model={mname};ppl={ppl:.3f};cloze={acc:.3f};"
                 f"calibration={'none' if 'qmc' in vname else 'required'}")
            rows.append((mname, vname, ppl, acc))
    return rows


if __name__ == "__main__":
    run()
